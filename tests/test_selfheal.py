"""Self-healing runtime tests (DESIGN.md §12).

Three healing loops under test, each with its own determinism contract:

* watchdog — a divergence (injected NaN / lr spike) is DETECTED within the
  check cadence, the pipeline rolls back to the last consistent snapshot,
  backs the lr off and quarantines the poisoned ring slots; with
  ``lr_backoff=1.0`` the healed run is BIT-IDENTICAL to a fault-free run
  (replay determinism is the rollback correctness proof);
* elastic — a permanently dead walk shard is reassigned to survivors
  mid-run and, because walk RNG is vertex-keyed (shard-count invariant),
  the ring and final phi stay bit-identical to the fault-free k-shard run;
* ingest SLO — under deadline pressure the driver degrades (full →
  no_finetune → detect_only), carries the skipped re-walk as debt, and
  pays it on the next non-degraded drain.

The chaos sweep at the end composes all three under a randomized,
seed-logged fault schedule (CI nightly runs it with REPRO_CHAOS_SEED).
"""

import dataclasses
import os
import tempfile

import numpy as np
import pytest

from repro.core.api import EmbedConfig, make_walk_plan
from repro.core.dsgl import DSGLConfig
from repro.core.mpgp import (compact_assignment, mpgp_partition,
                             reassign_dead_shard, rejoin_shard)
from repro.graph.csr import (build_partitioned_csr, reassign_partitioned_csr)
from repro.graph.delta import EdgeBatch, validate_edge_batch
from repro.graph.generators import rmat_graph
from repro.runtime.faults import FaultInjector, LivenessProbe
from repro.runtime.health import (DivergenceError, HealthConfig,
                                  HealthMonitor)
from repro.runtime.ingest import IngestConfig, IngestDriver
from repro.runtime.trainer import StreamingEmbedPipeline


def _plan(seed=3, dim=16):
    cfg = dataclasses.replace(EmbedConfig(dim=dim, seed=seed),
                              rng_mode="vertex")
    policy, spec, rounds = make_walk_plan(cfg)
    return policy, spec, rounds, DSGLConfig(dim=dim, seed=seed)


def _pipeline(graph, **kw):
    policy, spec, rounds, dsgl = _plan()
    return StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl, **kw)


def _batches(n, seed, num_nodes=128, k=6):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        e = rng.integers(0, num_nodes, size=(k, 2))
        out.append(EdgeBatch(insert=e[e[:, 0] != e[:, 1]]))
    return out


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(128, 7, seed=7)


@pytest.fixture(scope="module")
def reference(graph):
    """Fault-free single-dispatch run: bit-identity target."""
    p = _pipeline(graph)
    p.run()
    phi_in, phi_out = p.embeddings()
    return {"pipe": p, "phi_in": phi_in, "phi_out": phi_out,
            "walks": np.asarray(p.ring.walks).copy()}


@pytest.fixture(scope="module")
def part4(graph):
    return mpgp_partition(graph, 4, tau_weight="degree").assignment


@pytest.fixture(scope="module")
def reference4(graph, part4):
    """Fault-free k=4 sharded run: target for the elastic tests."""
    p = _pipeline(graph, assignment=part4, num_shards=4)
    p.run()
    phi_in, phi_out = p.embeddings()
    return {"phi_in": phi_in, "phi_out": phi_out,
            "walks": np.asarray(p.ring.walks).copy()}


# ---------------------------------------------------------------------------
# HealthMonitor unit behaviour
# ---------------------------------------------------------------------------


class TestHealthMonitor:
    def _stats(self, loss=1.0, nonfinite=0, loss_nonfinite=0, update=0.1):
        return {"nonfinite": nonfinite, "loss_nonfinite": loss_nonfinite,
                "loss_sum": loss, "update_norm": update, "phi_norm": 1.0}

    def test_cadence_is_step_keyed(self):
        mon = HealthMonitor(HealthConfig(check_every=10))
        assert not mon.due(0, 5)          # [0,5) crosses no multiple of 10
        assert mon.due(5, 5)              # [5,10) crosses 10
        assert mon.due(8, 20)
        # Replay from the same step re-checks the same window.
        assert mon.due(5, 5) and mon.due(5, 5)

    def test_nonfinite_raises_immediately(self):
        mon = HealthMonitor(HealthConfig())
        with pytest.raises(DivergenceError) as ei:
            mon.observe(self._stats(nonfinite=3), step=1, count=1,
                        slots=np.array([0, 1]))
        assert ei.value.report.kind == "nonfinite"
        assert ei.value.report.nonfinite == 3

    def test_loss_spike_gated_by_warmup(self):
        mon = HealthMonitor(HealthConfig(spike_factor=4.0, warmup_checks=3))
        # During warmup a spike only inflates the EMA, never raises.
        for s in range(3):
            mon.observe(self._stats(loss=100.0 if s == 1 else 1.0),
                        step=s + 1, count=1, slots=np.zeros(1, np.int64))
        for s in range(3, 8):             # settle the EMA back down
            mon.observe(self._stats(loss=1.0), step=s + 1, count=1,
                        slots=np.zeros(1, np.int64))
        with pytest.raises(DivergenceError) as ei:
            mon.observe(self._stats(loss=1e3), step=9, count=1,
                        slots=np.zeros(1, np.int64))
        assert ei.value.report.kind == "loss_spike"
        assert ei.value.report.detection_steps >= 1

    def test_loss_ema_is_chunk_size_invariant(self):
        a = HealthMonitor(HealthConfig())
        b = HealthMonitor(HealthConfig())
        a.observe(self._stats(loss=2.0), step=1, count=1,
                  slots=np.zeros(1, np.int64))
        b.observe(self._stats(loss=8.0), step=4, count=4,
                  slots=np.zeros(1, np.int64))
        assert a.loss_ema == pytest.approx(b.loss_ema)

    def test_rollback_budget_exhausts(self):
        mon = HealthMonitor(HealthConfig(max_rollbacks=2))
        assert not mon.exhausted()
        mon.note_rollback(restored_step=0, lr_scale=0.5, quarantined=4)
        mon.note_rollback(restored_step=0, lr_scale=0.25, quarantined=4)
        assert mon.exhausted()
        rep = mon.report()
        assert rep["rollbacks"] == 2 and rep["quarantined_slots"] == 8


# ---------------------------------------------------------------------------
# Watchdog in the training path
# ---------------------------------------------------------------------------


class TestWatchdogPipeline:
    def test_checked_path_is_bit_identical(self, graph, reference):
        """Attaching the watchdog must not perturb training math."""
        p = _pipeline(graph, health=HealthMonitor(HealthConfig()))
        p.run()
        a_in, a_out = p.embeddings()
        assert np.array_equal(a_in, reference["phi_in"])
        assert np.array_equal(a_out, reference["phi_out"])
        rep = p.health.report()
        assert rep["checks"] > 0 and rep["detections"] == 0

    @pytest.mark.parametrize("site,kind", [("phi_nan", "nonfinite"),
                                           ("lr_spike", "update_spike")])
    def test_divergence_rolls_back_and_converges(self, graph, tmp_path,
                                                 site, kind):
        # The lr-spike site blows the chunk update norm up ~1e6x while the
        # (saturating) loss barely doubles — armed via update_spike_factor.
        mon = HealthMonitor(HealthConfig(check_every=1, warmup_checks=2,
                                         spike_factor=4.0,
                                         update_spike_factor=50.0,
                                         lr_backoff=0.5))
        p = _pipeline(graph, health=mon)
        faults = FaultInjector(inject_plan={site: [4]})
        res = p.run(ckpt_root=str(tmp_path / site), ckpt_every_rounds=1,
                    faults=faults)
        rep = res["health"]
        assert rep["detections"] == 1 and rep["rollbacks"] == 1
        assert rep["detection_kinds"] == [kind]
        assert res["lr_scale"] == pytest.approx(0.5)
        assert rep["quarantined_slots"] > 0
        phi_in, _ = p.embeddings()
        assert np.isfinite(phi_in).all()

    def test_rollback_restores_bit_identical_state(self, graph, reference,
                                                   tmp_path):
        """The rollback property test: with lr_backoff=1.0 the healed run
        must land EXACTLY on the fault-free trajectory — snapshot restore,
        quarantine re-walk and chunk replay are all deterministic."""
        mon = HealthMonitor(HealthConfig(check_every=1, lr_backoff=1.0))
        p = _pipeline(graph, health=mon)
        faults = FaultInjector(inject_plan={"phi_nan": [3]})
        res = p.run(ckpt_root=str(tmp_path / "heal"), ckpt_every_rounds=1,
                    faults=faults)
        assert res["health"]["rollbacks"] == 1
        a_in, a_out = p.embeddings()
        assert np.array_equal(a_in, reference["phi_in"])
        assert np.array_equal(a_out, reference["phi_out"])
        assert np.array_equal(np.asarray(p.ring.walks), reference["walks"])

    def test_rollback_budget_reraises(self, graph, tmp_path):
        mon = HealthMonitor(HealthConfig(check_every=1, max_rollbacks=1))
        p = _pipeline(graph, health=mon)
        # Two separate poisonings; only one rollback is budgeted.
        faults = FaultInjector(inject_plan={"phi_nan": [3, 4]})
        with pytest.raises(DivergenceError):
            p.run(ckpt_root=str(tmp_path / "budget"), ckpt_every_rounds=1,
                  faults=faults)

    def test_resume_persists_lr_backoff(self, graph, tmp_path):
        mon = HealthMonitor(HealthConfig(check_every=1, lr_backoff=0.5))
        p = _pipeline(graph, health=mon)
        root = str(tmp_path / "persist")
        p.run(ckpt_root=root, ckpt_every_rounds=1,
              faults=FaultInjector(inject_plan={"phi_nan": [3]}))
        assert p._lr_scale == pytest.approx(0.5)
        policy, spec, _, dsgl = _plan()
        q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)
        assert q._lr_scale == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Elastic shard reconfiguration: partition + CSR layers
# ---------------------------------------------------------------------------


class TestReassignment:
    def test_reassign_dead_shard_empties_it(self, graph, part4):
        new = reassign_dead_shard(graph, part4, 1, num_parts=4)
        assert (new != 1).all()
        survivors = part4 != 1
        assert np.array_equal(new[survivors], part4[survivors])

    def test_compact_assignment(self, graph, part4):
        new = reassign_dead_shard(graph, part4, 1, num_parts=4)
        comp, old_of_new = compact_assignment(new, 1, num_parts=4)
        assert comp.max() <= 2 and comp.min() >= 0
        assert np.array_equal(old_of_new, [0, 2, 3])
        # Survivor membership is preserved under the id shift.
        for new_id, old_id in enumerate(old_of_new):
            assert np.array_equal(comp == new_id, new == old_id)

    def test_compact_rejects_live_dead_shard(self, part4):
        with pytest.raises(ValueError):
            compact_assignment(part4, 1, num_parts=4)

    @pytest.mark.parametrize("dead", [0, 1, 3])
    def test_partial_rebuild_matches_fresh_build(self, graph, part4, dead):
        new = reassign_dead_shard(graph, part4, dead, num_parts=4)
        comp, old_of_new = compact_assignment(new, dead, num_parts=4)
        old = build_partitioned_csr(graph, part4, 4)
        got, reused = reassign_partitioned_csr(
            graph, comp, 3, old=old, old_assignment=part4,
            old_of_new=old_of_new)
        want = build_partitioned_csr(graph, comp, 3)
        for field in ("indptr", "indices", "nbr_owner", "nbr_deg",
                      "weights", "edge_cm"):
            a, b = getattr(got.slices, field), getattr(want.slices, field)
            if a is None:
                assert b is None
            else:
                assert np.array_equal(np.asarray(a), np.asarray(b)), field
        assert np.array_equal(np.asarray(got.local_of),
                              np.asarray(want.local_of))
        assert np.array_equal(got.owned, want.owned)
        assert np.array_equal(got.num_owned, want.num_owned)
        assert 0 <= reused <= 3


# ---------------------------------------------------------------------------
# Elastic shard reconfiguration: mid-run, liveness driven
# ---------------------------------------------------------------------------


class TestElasticReconfiguration:
    def test_liveness_probe_threshold(self):
        live = LivenessProbe(num_shards=4, misses_to_dead=2)
        faults = FaultInjector(down_plan={2: 0})   # down from the start
        assert live.poll(faults) == []        # first miss: below threshold
        assert live.poll(faults) == [2]       # second miss -> declared dead
        assert live.remove(2) == 2            # caller reacts + removes
        assert live.names == [0, 1, 3] and live.dead_names == [2]
        assert live.poll(faults) == []        # survivors stay live
        # Dispatch ids compact with the assignment: launch id 3 is now 2.
        live2 = LivenessProbe(num_shards=4, misses_to_dead=1)
        live2.remove(1)
        assert live2.poll(FaultInjector(down_plan={3: 0})) == [2]
        assert live2.remove(2) == 3

    def test_shard_death_mid_run_is_bit_identical(self, graph, part4,
                                                  reference4, tmp_path):
        """Kill one shard permanently mid-run: the run completes at k-1
        and — by walk-RNG shard invariance — ring and phi match the
        fault-free k=4 run bit-for-bit."""
        p = _pipeline(graph, assignment=part4, num_shards=4)
        res = p.run(ckpt_root=str(tmp_path / "elastic"),
                    ckpt_every_rounds=2,
                    faults=FaultInjector(down_plan={2: 2}),
                    liveness=LivenessProbe(num_shards=4, misses_to_dead=2))
        assert p.walk_shards == 3
        assert len(res["reconfigs"]) == 1
        rec = res["reconfigs"][0]
        assert rec["dead_shard"] == 2 and rec["walk_shards"] == 3
        assert rec["wall_s"] > 0
        assert np.array_equal(np.asarray(p.ring.walks), reference4["walks"])
        a_in, a_out = p.embeddings()
        assert np.array_equal(a_in, reference4["phi_in"])
        assert np.array_equal(a_out, reference4["phi_out"])

    def test_double_shard_death(self, graph, part4, reference4, tmp_path):
        p = _pipeline(graph, assignment=part4, num_shards=4)
        res = p.run(ckpt_root=str(tmp_path / "double"),
                    ckpt_every_rounds=2,
                    faults=FaultInjector(down_plan={1: 2, 3: 4}),
                    liveness=LivenessProbe(num_shards=4, misses_to_dead=2))
        assert p.walk_shards == 2 and len(res["reconfigs"]) == 2
        a_in, _ = p.embeddings()
        assert np.array_equal(a_in, reference4["phi_in"])

    def test_elastic_auc_parity(self, graph, part4, reference, tmp_path):
        """End-to-end quality: the degraded (k=4 -> 3) run's AUC is within
        0.02 of the unsharded fault-free run."""
        from benchmarks.common import link_prediction_auc
        p = _pipeline(graph, assignment=part4, num_shards=4)
        p.run(ckpt_root=str(tmp_path / "auc"), ckpt_every_rounds=2,
              faults=FaultInjector(down_plan={2: 2}),
              liveness=LivenessProbe(num_shards=4, misses_to_dead=2))
        phi_now, _ = p.embeddings()
        auc_ref = link_prediction_auc(graph, reference["phi_in"],
                                      np.random.default_rng(7))
        auc_now = link_prediction_auc(graph, phi_now,
                                      np.random.default_rng(7))
        assert abs(auc_now - auc_ref) <= 0.02, (auc_now, auc_ref)

    def test_resume_after_reconfig_stays_elastic(self, graph, part4,
                                                 tmp_path):
        """A post-reconfig snapshot must not resurrect the dead shard."""
        p = _pipeline(graph, assignment=part4, num_shards=4)
        root = str(tmp_path / "resume")
        p.run(ckpt_root=root, ckpt_every_rounds=1,
              faults=FaultInjector(down_plan={2: 2}),
              liveness=LivenessProbe(num_shards=4, misses_to_dead=2))
        policy, spec, _, dsgl = _plan()
        q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)
        assert q.walk_shards == 3
        a_in, _ = p.embeddings()
        b_in, _ = q.embeddings()
        assert np.array_equal(a_in, b_in)


# ---------------------------------------------------------------------------
# Elastic re-JOIN: grow k-1 -> k back when capacity returns
# ---------------------------------------------------------------------------


class TestElasticRejoin:
    def test_rejoin_shard_appends_nonempty_shard(self, graph, part4):
        """Death to k=3 then re-JOIN back to 4-way: the returned shard is
        appended (survivor placements untouched outside the donor set)."""
        asn3, _ = compact_assignment(
            reassign_dead_shard(graph, part4, 3, num_parts=4), 3,
            num_parts=4)
        asn4, moved = rejoin_shard(graph, asn3, num_parts=3)
        assert asn4.max() == 3 and (asn4 == 3).sum() > 0
        assert moved.any()
        assert np.array_equal(asn4[~moved], asn3[~moved])
        # Donated nodes all land on the returned shard.
        assert (asn4[moved] == 3).all()

    def test_rejoin_partial_rebuild_matches_fresh_build(self, graph,
                                                        part4):
        """Split-direction CSR rebuild (old_of_new carries a -1 for the
        brand-new shard) equals a from-scratch build."""
        asn3, _ = compact_assignment(
            reassign_dead_shard(graph, part4, 3, num_parts=4), 3,
            num_parts=4)
        asn4, _ = rejoin_shard(graph, asn3, num_parts=3)
        old = build_partitioned_csr(graph, asn3, 3)
        got, reused = reassign_partitioned_csr(
            graph, asn4, 4, old=old, old_assignment=asn3,
            old_of_new=np.array([0, 1, 2, -1]))
        want = build_partitioned_csr(graph, asn4, 4)
        for field in ("indptr", "indices", "nbr_owner", "nbr_deg",
                      "weights", "edge_cm"):
            a, b = getattr(got.slices, field), getattr(want.slices, field)
            if a is None:
                assert b is None
            else:
                assert np.array_equal(np.asarray(a), np.asarray(b)), field
        assert np.array_equal(np.asarray(got.local_of),
                              np.asarray(want.local_of))
        assert np.array_equal(got.owned, want.owned)
        assert 0 <= reused <= 3     # donor + new shards always rebuild

    def test_liveness_rejoin_hysteresis(self):
        """A dead name needs hits_to_live consecutive OK probes; one
        blip resets the count (a flapping machine never re-JOINs)."""
        live = LivenessProbe(num_shards=3, misses_to_dead=1,
                             hits_to_live=2)
        flap = FaultInjector(down_plan={2: (0, 1)})
        down = FaultInjector(down_plan={2: 0})
        assert live.poll(down) == [2]
        assert live.remove(2) == 2
        assert live.rejoinable() == []
        live.poll(down)                      # still down: hits reset
        assert live.rejoinable() == []
        live2 = LivenessProbe(num_shards=3, misses_to_dead=1,
                              hits_to_live=2)
        assert live2.poll(flap) == [2]       # occurrence 0: down
        live2.remove(2)
        live2.poll(flap)                     # occ 1: back -> 1 hit
        assert live2.rejoinable() == []
        live2.poll(flap)                     # occ 2: back -> 2 hits
        assert live2.rejoinable() == [2]
        assert live2.rejoin(2) == 2          # appended at the end
        assert live2.names == [0, 1, 2] and live2.dead_names == []

    def test_transient_outage_rejoin_is_bit_identical(self, graph, part4,
                                                      reference4,
                                                      tmp_path):
        """Shard 2 goes down for a probe window mid-run, comes back, and
        re-JOINs: the run ends at k=4 again and — by walk-RNG assignment
        invariance — ring and phi match the fault-free k=4 run
        bit-for-bit (re-JOIN moves NO walk data, only dispatch)."""
        p = _pipeline(graph, assignment=part4, num_shards=4)
        res = p.run(ckpt_root=str(tmp_path / "rejoin"),
                    ckpt_every_rounds=2,
                    faults=FaultInjector(down_plan={2: (1, 3)}),
                    liveness=LivenessProbe(num_shards=4, misses_to_dead=1,
                                           hits_to_live=1))
        kinds = [r.get("kind", "death") for r in res["reconfigs"]]
        assert p.walk_shards == 4
        assert kinds.count("rejoin") == 1 and len(res["reconfigs"]) == 2
        rejoin = [r for r in res["reconfigs"] if r.get("kind") == "rejoin"][0]
        assert rejoin["walk_shards"] == 4 and rejoin["moved_roots"] > 0
        assert np.array_equal(np.asarray(p.ring.walks),
                              reference4["walks"])
        a_in, a_out = p.embeddings()
        assert np.array_equal(a_in, reference4["phi_in"])
        assert np.array_equal(a_out, reference4["phi_out"])

    def test_resume_after_rejoin_stays_grown(self, graph, part4,
                                             tmp_path):
        """The post-re-JOIN snapshot restores at k=4 — a rollback can
        never shrink the dispatch space back to the outage layout."""
        p = _pipeline(graph, assignment=part4, num_shards=4)
        root = str(tmp_path / "resume_rejoin")
        p.run(ckpt_root=root, ckpt_every_rounds=1,
              faults=FaultInjector(down_plan={2: (1, 3)}),
              liveness=LivenessProbe(num_shards=4, misses_to_dead=1,
                                     hits_to_live=1))
        assert p.walk_shards == 4
        policy, spec, _, dsgl = _plan()
        q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)
        assert q.walk_shards == 4
        a_in, _ = p.embeddings()
        b_in, _ = q.embeddings()
        assert np.array_equal(a_in, b_in)

    def test_direct_rejoin_then_run(self, graph, part4, reference4):
        """Explicit reconfigure -> rejoin on a fresh pipeline, then run:
        same bits as the fault-free k=4 run."""
        p = _pipeline(graph, assignment=part4, num_shards=4)
        p.elastic_reconfigure(2)
        assert p.walk_shards == 3
        stats = p.elastic_rejoin()
        assert stats["kind"] == "rejoin" and p.walk_shards == 4
        assert stats["reused_shards"] + stats["rebuilt_shards"] == 4
        p.run()
        a_in, _ = p.embeddings()
        assert np.array_equal(a_in, reference4["phi_in"])


# ---------------------------------------------------------------------------
# Admission control: batch validation before the WAL
# ---------------------------------------------------------------------------


class TestBatchValidation:
    def test_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="outside"):
            validate_edge_batch(EdgeBatch(insert=np.array([[0, 999]])), 128)
        with pytest.raises(ValueError, match="outside"):
            validate_edge_batch(EdgeBatch(delete=np.array([[-1, 3]])), 128)

    def test_nonfinite_weights_rejected(self):
        b = EdgeBatch(insert=np.array([[1, 2]]),
                      insert_weights=np.array([np.nan], np.float32))
        with pytest.raises(ValueError, match="non-finite"):
            validate_edge_batch(b, 128)

    def test_self_loop_policies(self):
        b = EdgeBatch(insert=np.array([[1, 2], [3, 3]]))
        out = validate_edge_batch(b, 128, self_loops="drop")
        assert np.array_equal(out.insert, [[1, 2]])
        with pytest.raises(ValueError, match="self-loop"):
            validate_edge_batch(b, 128, self_loops="forbid")

    def test_duplicate_policies(self):
        b = EdgeBatch(insert=np.array([[1, 2], [2, 1], [3, 4]]))
        assert validate_edge_batch(b, 128, duplicates="allow") is b
        out = validate_edge_batch(b, 128, duplicates="drop")
        assert np.array_equal(out.insert, [[1, 2], [3, 4]])
        with pytest.raises(ValueError, match="duplicate"):
            validate_edge_batch(b, 128, duplicates="forbid")

    def test_clean_batch_passes_through(self):
        b = EdgeBatch(insert=np.array([[1, 2], [5, 9]]))
        assert validate_edge_batch(b, 128) is b

    def test_driver_rejects_before_wal(self, graph, tmp_path):
        p = _pipeline(graph)
        p.run()
        drv = IngestDriver(str(tmp_path / "ing"), p,
                           cfg=IngestConfig(apply_every=10))
        with pytest.raises(ValueError):
            drv.submit(EdgeBatch(insert=np.array([[0, 999]])))
        # The malformed batch never became durable.
        assert drv.staleness()["pending_batches"] == 0
        records, _ = drv.wal.replay()
        assert records == []


# ---------------------------------------------------------------------------
# Ingest SLO: latency accounting + degrade ladder
# ---------------------------------------------------------------------------


class TestIngestSLO:
    def _driver(self, graph, tmp_path, clock, **cfg_kw):
        p = _pipeline(graph)
        p.run()
        cfg = IngestConfig(apply_every=10, **cfg_kw)
        return IngestDriver(str(tmp_path / "slo"), p, cfg=cfg, clock=clock)

    def test_latency_percentiles(self, graph, tmp_path):
        t = [100.0]
        drv = self._driver(graph, tmp_path, lambda: t[0])
        for i, b in enumerate(_batches(3, seed=21)):
            drv.submit(b)
            t[0] += float(i + 1)
            drv.drain()
        s = drv.staleness()
        assert s["latency_p50_s"] == pytest.approx(2.0)
        assert s["latency_p99_s"] == pytest.approx(3.0, abs=0.1)
        assert s["oldest_pending_age_s"] is None

    def test_degrade_ladder_and_debt_payment(self, graph, tmp_path):
        t = [100.0]
        drv = self._driver(graph, tmp_path, lambda: t[0],
                           staleness_slo_s=5.0, slo_headroom=1.5)
        b1, b2, b3 = _batches(3, seed=22)

        drv.submit(b1); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "full" and drv.last_mode == "full"

        # Predicted cost of full/no_finetune exceeds the remaining budget:
        # the drain degrades to detect_only and records the debt.
        drv._wall_ema = {"full": 10.0, "no_finetune": 10.0}
        drv.submit(b2); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "detect_only"
        assert st.rewalk_walks == 0 and st.fine_tune_steps == 0
        assert drv._debt is not None and drv._debt.sum() > 0
        assert drv.staleness()["debt_roots"] > 0

        # Fast again: the next full drain pays the debt.
        drv._wall_ema = {}
        debt = int(drv._debt.sum())
        drv.submit(b3); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "full" and drv._debt is None
        assert st.affected >= debt          # debt OR-ed into detection
        assert drv.staleness()["debt_roots"] == 0

    def test_blown_budget_goes_detect_only(self, graph, tmp_path):
        t = [100.0]
        drv = self._driver(graph, tmp_path, lambda: t[0],
                           staleness_slo_s=2.0)
        (b,) = _batches(1, seed=23)
        drv.submit(b)
        t[0] += 10.0                         # already past the deadline
        st = drv.drain()
        assert st.mode == "detect_only"
        assert drv.staleness()["slo_violations"] == 1

    def test_middle_rung_when_it_fits(self, graph, tmp_path):
        t = [100.0]
        drv = self._driver(graph, tmp_path, lambda: t[0],
                           staleness_slo_s=5.0, slo_headroom=1.0)
        (b,) = _batches(1, seed=24)
        drv._wall_ema = {"full": 100.0, "no_finetune": 0.1}
        drv.submit(b); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "no_finetune"
        assert st.fine_tune_steps == 0 and st.extra_rounds == 0

    def test_no_slo_always_full(self, graph, tmp_path):
        drv = self._driver(graph, tmp_path, lambda: 0.0)
        drv._wall_ema = {"full": 1e9}
        (b,) = _batches(1, seed=25)
        drv.submit(b)
        st = drv.drain()
        assert st.mode == "full"
        assert drv.staleness()["staleness_slo_s"] is None

    def test_detect_only_snapshot_is_recoverable(self, graph, tmp_path):
        """detect_only adopts the new graph and snapshots: a crash right
        after must recover onto the adopted graph with the debt known."""
        t = [100.0]
        root = str(tmp_path / "slo")
        drv = self._driver(graph, tmp_path, lambda: t[0],
                           staleness_slo_s=5.0)
        (b,) = _batches(1, seed=26)
        drv._wall_ema = {"full": 10.0, "no_finetune": 10.0}
        drv.submit(b); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "detect_only"
        n_new = drv.pipeline.graph.num_edges
        rec = IngestDriver.recover(root, drv.pipeline.policy,
                                   drv.pipeline.spec, drv.pipeline.cfg)
        assert rec.pipeline.graph.num_edges == n_new
        assert rec.staleness()["pending_batches"] == 0


# ---------------------------------------------------------------------------
# Chaos sweep: all three healing loops under one randomized schedule
# ---------------------------------------------------------------------------


class TestChaosSweep:
    def test_chaos_schedule(self, graph, part4, reference4, tmp_path):
        """Randomized (seed-logged) composition: shard death x divergence
        injection, then ingest under deadline pressure. Degraded completion
        with bit-identical walks and finite phi is the pass condition."""
        seed = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
        rng = np.random.default_rng(seed)
        print(f"REPRO_CHAOS_SEED={seed}")

        dead = int(rng.integers(0, 4))
        down_at = int(rng.integers(2, 5))
        site = ["phi_nan", "lr_spike"][int(rng.integers(0, 2))]
        inject_at = int(rng.integers(3, 6))

        mon = HealthMonitor(HealthConfig(check_every=1, warmup_checks=2,
                                         update_spike_factor=50.0,
                                         lr_backoff=1.0, max_rollbacks=4))
        p = _pipeline(graph, assignment=part4, num_shards=4, health=mon)
        faults = FaultInjector(down_plan={dead: down_at},
                               inject_plan={site: [inject_at]})
        res = p.run(ckpt_root=str(tmp_path / "chaos"), ckpt_every_rounds=1,
                    faults=faults,
                    liveness=LivenessProbe(num_shards=4, misses_to_dead=2))

        assert p.walk_shards == 3 and len(res["reconfigs"]) == 1
        assert res["health"]["detections"] >= 1
        # Walk layer is deterministic under BOTH fault classes at once.
        assert np.array_equal(np.asarray(p.ring.walks), reference4["walks"])
        phi_in, _ = p.embeddings()
        # Detection fires AT the offending chunk, so the rollback discards
        # it entirely and the lr_backoff=1.0 replay heals exactly.
        assert np.array_equal(phi_in, reference4["phi_in"])

        # Ingest pressure on the degraded pipeline: force one detect_only
        # drain, then a full drain that pays the debt.
        t = [100.0]
        drv = IngestDriver(str(tmp_path / "chaos-ing"), p,
                           cfg=IngestConfig(apply_every=10,
                                            staleness_slo_s=5.0),
                           clock=lambda: t[0])
        b1, b2 = _batches(2, seed=seed + 1)
        drv._wall_ema = {"full": 10.0, "no_finetune": 10.0}
        drv.submit(b1); t[0] += 1.0
        assert drv.drain().mode == "detect_only"
        drv._wall_ema = {}
        drv.submit(b2); t[0] += 1.0
        st = drv.drain()
        assert st.mode == "full" and drv._debt is None
        phi_in, _ = drv.pipeline.embeddings()
        assert np.isfinite(phi_in).all()
