"""The trip-count-aware HLO cost model (launch.hlo_cost) — validated
against programs with analytically-known FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    m, k, n = 64, 128, 32

    def f(a, b):
        return a @ b

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    assert c.flops == pytest.approx(2 * m * k * n, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """The whole reason this model exists: XLA counts while bodies once."""
    trips, m = 12, 32

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), 0
        h, _ = jax.lax.scan(body, x, ws)
        return h

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((trips, m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    assert c.flops == pytest.approx(trips * 2 * m * m * m, rel=0.05)


def test_nested_scans_multiply():
    t_out, t_in, m = 3, 5, 16

    def f(ws, x):
        def outer(h, _):
            def inner(hh, w):
                return jnp.tanh(hh @ w), 0
            h2, _ = jax.lax.scan(inner, h, ws)
            return h2, 0
        h, _ = jax.lax.scan(outer, x, None, length=t_out)
        return h

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((t_in, m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    assert c.flops == pytest.approx(t_out * t_in * 2 * m ** 3, rel=0.05)


def test_batched_dot_counts_batch_dims():
    b, m, k, n = 4, 8, 16, 32

    def f(a, w):
        return jnp.einsum("bmk,bkn->bmn", a, w)

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    assert c.flops == pytest.approx(2 * b * m * k * n, rel=0.01)


def test_bytes_accounting_grad_step_reasonable():
    """A simple SGD step: bytes must be O(params) not O(params x iters)."""
    n = 256

    def f(w, x):
        def loss(w):
            return jnp.sum((x @ w) ** 2)
        g = jax.grad(loss)(w)
        return w - 0.1 * g

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((8, n), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    param_bytes = n * n * 4
    assert c.bytes < 40 * param_bytes   # small constant multiple
    assert c.bytes > param_bytes        # but at least one read


def test_collective_bytes_empty_on_single_device():
    def f(a):
        return a * 2
    txt = _compile_text(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    c = HloCostModel(txt).entry_cost()
    assert c.coll_bytes == 0.0


# --- region attribution (launch.profile) -----------------------------------


def test_region_map_embedding_pipeline():
    """The walk/refresh/checked-train regions exist and the precedence
    hazards are pinned: train_chunk_checked must NOT fall into dsgl_train,
    and update_norm must NOT fall into norm."""
    from repro.launch.profile import _region_of

    assert _region_of("jit(train_chunk)/chunk_scan/dot") == "dsgl_train"
    assert _region_of(
        "jit(train_chunk_checked)/reduce") == "train_checked"
    assert _region_of("train_chunk_checked/update_norm") == "train_checked"
    assert _region_of("update_norm/reduce_sum") == "train_checked"
    assert _region_of("jit(run_walk_batch)/while") == "walk_engine"
    assert _region_of("incom/exchange_step/all_to_all") == "walk_engine"
    assert _region_of("refresh/ring_replace/scatter") == "refresh"
    assert _region_of("transformer/rmsnorm/mul") == "norm"
    assert _region_of("something_unrelated") == "other"


def test_region_attribution_named_scopes():
    """End to end: named_scope op names survive into optimized HLO and
    attribute() books each scope's flops to its region."""
    from repro.launch.profile import attribute

    m = 32

    def f(a, b):
        with jax.named_scope("train_chunk"):
            x = a @ b
        with jax.named_scope("walk_transition"):
            y = x @ b
        return y

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32))
    prof = attribute(txt)
    assert prof.get("dsgl_train", {}).get("flops", 0) == pytest.approx(
        2 * m ** 3, rel=0.05)
    assert prof.get("walk_engine", {}).get("flops", 0) == pytest.approx(
        2 * m ** 3, rel=0.05)
