"""DSGL learner (paper §4): correctness of the lifetime update, hotness
sync cost claims, and end-to-end embedding quality on a tiny graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import sync as sync_mod
from repro.core.corpus import FrequencyOrder
from repro.core.dsgl import (
    DSGLConfig, init_embeddings, lifetime_step, negative_table,
    sample_negatives,
)


def test_negative_table_is_cdf(rng):
    ocn = np.array([100, 50, 20, 5, 1])
    cdf = negative_table(ocn, 0.75)
    assert np.all(np.diff(cdf) >= 0)
    assert cdf[-1] == pytest.approx(1.0)
    draws = sample_negatives(cdf, (20000,), rng)
    # unigram^0.75: rank 0 must be sampled most
    counts = np.bincount(draws, minlength=5)
    assert counts[0] > counts[-1]


@given(st.integers(1, 3), st.integers(6, 20))
@settings(max_examples=10, deadline=None)
def test_lifetime_step_moves_only_touched_rows(w_cnt, t_len):
    n, d, k_neg, g = 64, 8, 3, 2
    phi_in, phi_out = init_embeddings(n, d, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    walks = rng.integers(0, n // 2, size=(g, w_cnt, t_len)).astype(np.int32)
    negs = rng.integers(n // 2, n, size=(g, t_len, k_neg)).astype(np.int32)
    phi_in_before = np.asarray(phi_in).copy()
    pin, pout, loss = lifetime_step(
        phi_in.copy(), phi_out.copy(), jnp.asarray(walks), jnp.asarray(negs),
        jnp.float32(0.05), 2)
    touched_in = np.unique(walks)
    untouched_in = np.setdiff1d(np.arange(n), touched_in)
    np.testing.assert_array_equal(np.asarray(pin)[untouched_in],
                                  phi_in_before[untouched_in])
    assert np.isfinite(float(loss))


def test_hotness_sync_moves_fewer_bytes_than_full():
    """§4.2-III: O(ocn_max d m) vs O(|V| d m)."""
    n, d, m = 512, 16, 4
    rng = np.random.default_rng(0)
    replicas = []
    for s in range(m):
        key = jax.random.PRNGKey(s)
        replicas.append(init_embeddings(n, d, key))
    # power-law-ish occurrence counts -> hotness blocks
    ocn = np.sort(rng.zipf(2.0, n))[::-1].astype(np.int64)
    order = FrequencyOrder.from_ocn(ocn)
    starts, ends = order.hotness_blocks()
    _, hot_bytes = sync_mod.hotness_block_sync(replicas, starts, ends, rng)
    _, full_bytes = sync_mod.full_sync(replicas)
    assert hot_bytes < full_bytes
    # blocks = distinct occurrence counts << |V|
    assert len(starts) < n // 4


def test_hotness_sync_converges_replicas():
    n, d, m = 64, 8, 3
    rng = np.random.default_rng(2)
    replicas = [init_embeddings(n, d, jax.random.PRNGKey(s)) for s in range(m)]
    starts = np.arange(n)        # degenerate: every row its own block
    ends = starts + 1
    new_reps, _ = sync_mod.hotness_block_sync(replicas, starts, ends, rng)
    for r in new_reps[1:]:
        np.testing.assert_allclose(np.asarray(r[0]),
                                   np.asarray(new_reps[0][0]), atol=1e-6)


def test_training_reduces_loss(small_graph):
    from repro.core.api import EmbedConfig, sample_corpus
    from repro.core.dsgl import train_dsgl
    corpus = sample_corpus(small_graph,
                           EmbedConfig(dim=16, max_len=30, min_len=8))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    cfg = DSGLConfig(dim=16, window=4, negatives=3, epochs=2,
                     batch_groups=16)
    phi_in, phi_out, metrics = train_dsgl(corpus, order, cfg,
                                          collect_metrics=True)
    losses = metrics["loss"]
    assert len(losses) >= 2
    first = np.mean(losses[: max(len(losses) // 4, 1)])
    last = np.mean(losses[-max(len(losses) // 4, 1):])
    assert last < first
    assert not np.isnan(np.asarray(phi_in)).any()


def test_kernel_and_ref_training_paths_agree(small_graph):
    """use_kernel=True (Pallas interpret) must train identically to the ref
    path given the same inputs."""
    from repro.core.api import EmbedConfig, sample_corpus
    corpus = sample_corpus(small_graph,
                           EmbedConfig(dim=8, max_len=20, min_len=6))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    walks = order.relabel_walks(corpus.walks)[:8]
    n = len(order.to_rank)
    phi_in, phi_out = init_embeddings(n, 8, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wb = jnp.asarray(walks[:4].reshape(2, 2, -1))
    neg = jnp.asarray(rng.integers(0, n, size=(2, walks.shape[1], 3)),
                      jnp.int32)
    out_ref = lifetime_step(phi_in.copy(), phi_out.copy(), wb, neg,
                            jnp.float32(0.025), 3, False)
    out_ker = lifetime_step(phi_in.copy(), phi_out.copy(), wb, neg,
                            jnp.float32(0.025), 3, True)
    np.testing.assert_allclose(np.asarray(out_ref[0]), np.asarray(out_ker[0]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(out_ref[1]), np.asarray(out_ker[1]),
                               atol=2e-4, rtol=2e-4)
