"""Batched walk engine: termination, path validity, mode equivalence,
message accounting (paper §2.3/§3.1 claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mpgp import mpgp_partition
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, run_walk_batch, walks_to_numpy


def _run(graph, spec, n=32, seed=0, part=None):
    graph = graph.with_edge_cm()
    sources = jnp.arange(n, dtype=jnp.int32) % graph.num_nodes
    key = jax.random.PRNGKey(seed)
    policy = make_policy("huge")
    part_j = jnp.asarray(part) if part is not None else None
    return run_walk_batch(graph, sources, key, policy, spec, part_j)


def test_walks_terminate_and_paths_are_edges(small_graph):
    spec = WalkSpec(max_len=48, min_len=8, info_mode="incom", reg_start=16)
    st = _run(small_graph, spec)
    paths, lengths = walks_to_numpy(st)
    assert not bool(np.asarray(st.active).any())
    indptr = np.asarray(small_graph.indptr)
    indices = np.asarray(small_graph.indices)
    for row, ln in zip(paths, lengths):
        assert 1 <= ln <= spec.max_len
        for a, b in zip(row[: ln - 1], row[1:ln]):
            assert b in indices[indptr[a]: indptr[a + 1]], (a, b)
        assert (row[ln:] == -1).all()


def test_fixed_mode_walks_have_fixed_length(small_graph):
    spec = WalkSpec(max_len=20, info_mode="fixed", fixed_len=20)
    st = _run(small_graph, spec)
    _, lengths = walks_to_numpy(st)
    # dead-end lanes may stop early; all others must hit exactly fixed_len
    deg = np.diff(np.asarray(small_graph.indptr))
    assert (lengths == 20).mean() > 0.9


def test_incom_and_fullpath_modes_agree_on_h(small_graph):
    """The O(1) and O(L) information paths are the same mathematics: with
    identical RNG they accept identical nodes and produce identical H."""
    kw = dict(max_len=32, min_len=6, mu=0.995, reg_start=1)
    st_inc = _run(small_graph, WalkSpec(info_mode="incom", **kw), seed=3)
    st_ful = _run(small_graph, WalkSpec(info_mode="fullpath", **kw), seed=3)
    p1, l1 = walks_to_numpy(st_inc)
    p2, l2 = walks_to_numpy(st_ful)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_allclose(np.asarray(st_inc.info.H),
                               np.asarray(st_ful.info.H), atol=1e-3)


def test_message_bytes_constant_vs_linear(medium_graph):
    """Example 1: InCoM messages are constant 80 B; HuGE-D's grow as
    24 + 8L. At routine walk lengths (L -> 40..80) the full-path message is
    several x larger. (With very SHORT adaptive walks the crossover runs the
    other way — crossings at L < 7 cost < 80 B — which is why the engine
    measures both; see EXPERIMENTS.md.)"""
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    # mu = -1 disables early termination: walks run to max_len (routine).
    kw = dict(max_len=40, min_len=8, mu=-1.0, reg_start=16)
    st_inc = _run(medium_graph, WalkSpec(info_mode="incom", **kw),
                  n=64, seed=1, part=part)
    st_ful = _run(medium_graph, WalkSpec(info_mode="fullpath", **kw),
                  n=64, seed=1, part=part)
    assert int(st_inc.msg_count) > 0 and int(st_ful.msg_count) > 0
    per_inc = float(st_inc.msg_bytes) / int(st_inc.msg_count)
    per_ful = float(st_ful.msg_bytes) / int(st_ful.msg_count)
    assert per_inc == pytest.approx(80.0)
    assert per_ful > per_inc
    # at L = 80 the ratio reaches 8.3x (Example 1)
    from repro.core import incom
    assert float(incom.fullpath_msg_bytes(jnp.int32(80))) / 80.0 \
        == pytest.approx(8.3, abs=0.1)


def test_partition_locality_reduces_crossings(medium_graph):
    """MPGP vs hash partition: fewer cross-machine messages (Fig. 10c)."""
    from repro.core.mpgp import hash_partition
    spec = WalkSpec(max_len=32, min_len=8, info_mode="incom", reg_start=16)
    part_m = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    part_h = hash_partition(medium_graph, 4).assignment
    st_m = _run(medium_graph, spec, n=128, seed=5, part=part_m)
    st_h = _run(medium_graph, spec, n=128, seed=5, part=part_h)
    assert int(st_m.msg_count) < int(st_h.msg_count)
