"""MLA: the absorbed (weight-folded, MQA-over-latent) formulation must
equal the naive per-head materialization of K/V from the latent."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mla as mla_mod
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rmsnorm


def _cfg(q_lora: int = 0):
    return ModelConfig(
        name="mla-test", family="dense", num_layers=1, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
        use_mla=True, q_lora_rank=q_lora, kv_lora_rank=24, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, dtype="float32", remat="none",
        attn_impl="ref")


def _naive_mla(x, p, cfg, positions):
    """Reference: materialize per-head K/V from the latent, run standard
    multi-head attention with the shared RoPE key appended."""
    b, s, d = x.shape
    h = cfg.num_heads
    qn, qr = mla_mod._queries(x, p, cfg, positions)        # (B,H,S,*)
    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wkv_down"]),
                  p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["wk_rope"])[:, None],
        positions, cfg.rope_theta)[:, 0]                   # (B,S,rope)
    k_nope = jnp.einsum("bsr,rhk->bhsk", ckv, p["wk_up"])  # (B,H,S,nope)
    v = jnp.einsum("bsr,rhk->bhsk", ckv, p["wv_up"])       # (B,H,S,vh)
    k_rope_b = jnp.broadcast_to(krope[:, None],
                                (b, h, s, cfg.qk_rope_dim))
    q_full = jnp.concatenate([qn, qr], -1)
    k_full = jnp.concatenate([k_nope, k_rope_b], -1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = jnp.einsum("bhsk,bhtk->bhst", q_full, k_full) * scale
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhst,bhtk->bhsk", w, v)
    return jnp.einsum("bhsk,hkd->bsd", y, p["wo"])


def _check(cfg):
    key = jax.random.PRNGKey(0)
    p = mla_mod.init_mla(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, cfg.d_model))
    positions = jnp.arange(12)
    got, _ = mla_mod.mla_attention(x, p, cfg, positions)
    want = _naive_mla(x, p, cfg, positions)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_absorbed_equals_naive_no_qlora():
    _check(_cfg(q_lora=0))          # deepseek-v2-lite style


def test_absorbed_equals_naive_with_qlora():
    _check(_cfg(q_lora=32))         # minicpm3 style


def test_mla_decode_matches_prefill_tail():
    cfg = _cfg()
    key = jax.random.PRNGKey(2)
    p = mla_mod.init_mla(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 9, cfg.d_model))
    # full pass
    full, _ = mla_mod.mla_attention(x, p, cfg, jnp.arange(9))
    # prefill 8 then decode the 9th
    cache = mla_mod.init_mla_cache(cfg, 1, 16, jnp.float32)
    _, cache = mla_mod.mla_attention(x[:, :8], p, cfg, jnp.arange(8),
                                     cache=cache)
    got, _ = mla_mod.mla_attention(x[:, 8:], p, cfg, jnp.arange(8, 9),
                                   cache=cache, cache_len=jnp.int32(8))
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)
