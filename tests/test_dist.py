"""Distribution layer: spec resolution + multi-device (8 fake CPU devices,
subprocess) shard_map collectives, pipeline parallelism, sharded train step."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    BATCH_AXES, mesh_axis_size, resolve_spec, resolve_specs,
)
from repro.launch.mesh import make_host_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str, n_devices: int = 8) -> str:
    """Run a snippet under --xla_force_host_platform_device_count."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# spec resolution (single device)
# ---------------------------------------------------------------------------

def test_resolve_spec_drops_missing_axes():
    mesh = make_host_mesh(1, 1)   # has data+model but sizes 1
    s = resolve_spec(P(("pod", "data"), "model"), mesh, (4, 4))
    assert s == P("data", "model")


def test_resolve_spec_drops_nondivisible():
    mesh = make_host_mesh(1, 1)
    # trivially divisible with size-1 axes
    assert resolve_spec(P("data"), mesh, (3,)) == P("data")


def test_resolve_specs_tree():
    mesh = make_host_mesh(1, 1)
    tree = {"a": P("pod", "model"), "b": {"c": P(("pod", "data"))}}
    out = resolve_specs(tree, mesh)
    assert out["a"] == P(None, "model")
    assert out["b"]["c"] == P("data")


def test_mesh_axis_size():
    mesh = make_host_mesh(1, 1)
    assert mesh_axis_size(mesh, None) == 1
    assert mesh_axis_size(mesh, "data") == 1
    assert mesh_axis_size(mesh, ("data", "model")) == 1


# ---------------------------------------------------------------------------
# multi-device subprocess tests
# ---------------------------------------------------------------------------

def test_hotness_sync_spmd_8dev():
    out = _run_subprocess("""
        from repro.dist.collectives import hotness_sync_spmd
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        n, d = 32, 4
        pi = jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)
        po = -pi
        rows = jnp.array([0, 5, 31], jnp.int32)
        pi2, po2, nbytes = hotness_sync_spmd(pi, po, rows, mesh, "data")
        # replicated input -> pmean is identity
        assert np.allclose(np.asarray(pi2), np.asarray(pi)), "pi changed"
        print("OK", nbytes)
    """)
    assert "OK" in out


def test_pipeline_apply_matches_sequential_8dev():
    out = _run_subprocess("""
        from repro.dist.pipeline import microbatch, pipeline_apply
        S, M, mb, dim = 8, 4, 2, 16
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("pipe",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, dim, dim)) * (dim ** -0.5)
        x = jax.random.normal(jax.random.fold_in(key, 1), (M * mb, dim))

        def stage(p, h):
            return jnp.tanh(h @ p)

        # sequential reference
        ref = x
        for i in range(S):
            ref = stage(w[i], ref)

        got = pipeline_apply(stage, w, microbatch(x, M), mesh, axis="pipe")
        got = got.reshape(M * mb, dim)
        assert np.allclose(np.asarray(got), np.asarray(ref), atol=1e-5), \
            np.abs(np.asarray(got) - np.asarray(ref)).max()
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_grads_flow_8dev():
    out = _run_subprocess("""
        from repro.dist.pipeline import microbatch, pipeline_apply
        S, M, mb, dim = 4, 4, 2, 8
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("pipe",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, dim, dim)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (M * mb, dim))

        def stage(p, h):
            return jnp.tanh(h @ p)

        def loss(w):
            y = pipeline_apply(stage, w, microbatch(x, M), mesh, "pipe")
            return jnp.sum(y ** 2)

        def loss_seq(w):
            h = x
            for i in range(S):
                h = stage(w[i], h)
            return jnp.sum(h ** 2)

        g_pipe = jax.grad(loss)(w)
        g_seq = jax.grad(loss_seq)(w)
        assert np.allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           atol=1e-4), \
            np.abs(np.asarray(g_pipe) - np.asarray(g_seq)).max()
        print("PIPE_GRAD_OK")
    """)
    assert "PIPE_GRAD_OK" in out


def test_sharded_train_step_2x4_mesh():
    """A reduced arch's full train step under a (2,4) data x model mesh:
    the same code path the 512-device dry-run uses."""
    out = _run_subprocess("""
        from repro.configs import get_reduced
        from repro.launch import steps as S
        from repro.models import zoo
        from repro.dist.context import activation_sharding
        from repro.optim.optimizers import init_opt_state
        import numpy as np
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        cfg = get_reduced("yi_6b")
        fn = S.build_train_step(cfg)
        params = zoo.init_params(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params, S.default_opt(cfg))
        batch = zoo.train_batch(cfg, 4, 16, jax.random.PRNGKey(1))
        specs = {"batch": batch, "step": jnp.int32(0)}
        in_sh, out_sh, _ = S.train_shardings(cfg, mesh, specs)
        with activation_sharding(mesh):
            step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, m = step(params, opt, batch, jnp.int32(0))
        assert np.isfinite(float(m["loss"]))
        print("TRAIN_STEP_OK", float(m["loss"]))
    """)
    assert "TRAIN_STEP_OK" in out


def test_compressed_allreduce_8dev():
    out = _run_subprocess("""
        from jax.experimental.shard_map import shard_map
        from repro.dist.collectives import compressed_allreduce
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))

        def f(g, e):
            return compressed_allreduce(g[0], e[0], 0.5, "data")

        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        e = jnp.zeros((8, 64))
        synced, resid = shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P("data")), check_rep=False)(g, e)
        # error feedback: sparse + residual == original per shard
        print("COMPRESS_OK", float(jnp.abs(synced).sum()))
    """)
    assert "COMPRESS_OK" in out
