"""Partition-local walk engine (ISSUE 3): slice/halo construction
round-trips, compacted-pool walks bit-identical to the replicated
reference at every shard count, packed-exchange accounting, overflow
spill/retry paths, per-shard balance stats, windowed ΔD gate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import incom
from repro.core.mpgp import mpgp_partition
from repro.core.shard_engine import (
    make_walk_mesh, partitioned_csr_for, run_walk_sharded,
)
from repro.core.termination import WalkCountController
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, run_walk_batch
from repro.graph.csr import build_partitioned_csr

SPEC = WalkSpec(max_len=40, min_len=8, mu=0.995, info_mode="incom",
                reg_start=16)


def _local(graph, part, k, n=96, seed=11, spec=SPEC, **kw):
    graph = graph.with_edge_cm()
    sources = jnp.arange(n, dtype=jnp.int32) % graph.num_nodes
    return run_walk_sharded(graph, sources, jax.random.PRNGKey(seed),
                            make_policy("huge"), spec,
                            jnp.asarray(part, jnp.int32), k,
                            engine="local", **kw)


def _parts(graph):
    p4 = mpgp_partition(graph, 4, gamma=2.0).assignment
    n = graph.num_nodes
    return {1: np.zeros(n, np.int64), 2: p4 % 2, 4: p4,
            8: np.arange(n) % 8}


# ---------------------------------------------------------------------------
# Partition-local storage: slice construction + halo round trips
# ---------------------------------------------------------------------------


def test_partitioned_csr_slices_match_global(medium_graph):
    """Every owned node's local CSR row is bit-for-bit its global row, and
    the edge-aligned halo metadata (owner, degree, Cm) matches the global
    arrays — phase A on the slice sees exactly what it saw globally."""
    g = medium_graph.with_edge_cm()
    asn = mpgp_partition(g, 4, gamma=2.0).assignment
    pcsr = build_partitioned_csr(g, asn, 4)
    gp = g.to_numpy()
    indptr = np.asarray(gp.indptr, np.int64)
    indices = np.asarray(gp.indices, np.int64)
    cm = np.asarray(gp.edge_cm, np.int64)
    deg = np.diff(indptr)
    local_of = np.asarray(pcsr.local_of)
    for s in range(4):
        sip = np.asarray(pcsr.slices.indptr[s])
        six = np.asarray(pcsr.slices.indices[s])
        sow = np.asarray(pcsr.slices.nbr_owner[s])
        sdeg = np.asarray(pcsr.slices.nbr_deg[s])
        scm = np.asarray(pcsr.slices.edge_cm[s])
        owned = np.where(asn == s)[0]
        assert pcsr.num_owned[s] == len(owned)
        for u in owned[:64]:
            lo, hi = sip[local_of[u]], sip[local_of[u] + 1]
            np.testing.assert_array_equal(six[lo:hi],
                                          indices[indptr[u]:indptr[u + 1]])
            np.testing.assert_array_equal(scm[lo:hi],
                                          cm[indptr[u]:indptr[u + 1]])
        valid = six >= 0
        np.testing.assert_array_equal(sow[valid], asn[six[valid]])
        np.testing.assert_array_equal(sdeg[valid], deg[six[valid]])
    # per-shard CSR bytes scale ~1/k: the slice is far below the global CSR
    full = (indptr.size + indices.size + cm.size) * 4
    assert pcsr.shard_csr_nbytes().max() < 0.55 * full


@pytest.mark.parametrize("num_parts", [1, 2, 3, 5])
def test_halo_remap_round_trip_random(num_parts):
    """Property-style round trip on random graphs/assignments: local row
    of owner(v) reproduces N(v); owned/local_of invert each other."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.graph.generators import rmat_graph

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def check(seed):
        rng = np.random.default_rng(seed)
        g = rmat_graph(64, 4, seed=seed % 97)
        asn = rng.integers(0, num_parts, g.num_nodes)
        pcsr = build_partitioned_csr(g, asn, num_parts)
        local_of = np.asarray(pcsr.local_of)
        gp = g.to_numpy()
        indptr = np.asarray(gp.indptr, np.int64)
        indices = np.asarray(gp.indices, np.int64)
        for v in rng.choice(g.num_nodes, size=8, replace=False):
            s = asn[v]
            assert pcsr.owned[s, local_of[v]] == v     # inverse maps agree
            sip = np.asarray(pcsr.slices.indptr[s])
            six = np.asarray(pcsr.slices.indices[s])
            lo, hi = sip[local_of[v]], sip[local_of[v] + 1]
            np.testing.assert_array_equal(
                six[lo:hi], indices[indptr[v]:indptr[v + 1]])

    check()


# ---------------------------------------------------------------------------
# Compacted engine: bit-identity vs the replicated k=1 reference
# ---------------------------------------------------------------------------


def test_local_engine_bit_identical_across_k(medium_graph):
    """Partition-local + compacted pools: walks, lengths and every InCoM
    moment are bit-identical across k in {1, 2, 4, 8} and match the dense
    k=1 reference walk-for-walk."""
    g = medium_graph.with_edge_cm()
    sources = jnp.arange(96, dtype=jnp.int32)
    key = jax.random.PRNGKey(11)
    dense = run_walk_batch(g, sources, key, make_policy("huge"), SPEC)
    runs = {k: _local(medium_graph, part, k) for k, part
            in _parts(medium_graph).items()}
    ref = runs[1]
    for k, st in runs.items():
        np.testing.assert_array_equal(np.asarray(ref.path),
                                      np.asarray(st.path), err_msg=f"k={k}")
        for f in ("H", "L", "EH", "EL", "EHL", "EH2", "EL2"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref.info, f)),
                np.asarray(getattr(st.info, f)), err_msg=f"k={k}.{f}")
    np.testing.assert_array_equal(np.asarray(dense.path),
                                  np.asarray(ref.path))
    np.testing.assert_array_equal(np.asarray(dense.info.L),
                                  np.asarray(ref.info.L))
    assert int(dense.accepts) == int(runs[4].accepts)
    assert int(dense.rejects) == int(runs[4].rejects)
    assert int(runs[4].msg_count) > 0


def test_local_matches_replicated_engine(medium_graph):
    """Same partition, both engines: identical walks and identical
    measured hand-off counts/bytes (the exchange inventory is an engine
    invariant, not an implementation detail)."""
    part = _parts(medium_graph)[4]
    st_l = _local(medium_graph, part, 4)
    g = medium_graph.with_edge_cm()
    st_r = run_walk_sharded(g, jnp.arange(96, dtype=jnp.int32),
                            jax.random.PRNGKey(11), make_policy("huge"),
                            SPEC, jnp.asarray(part, jnp.int32), 4,
                            engine="replicated")
    np.testing.assert_array_equal(np.asarray(st_l.path), np.asarray(st_r.path))
    np.testing.assert_array_equal(np.asarray(st_l.info.L),
                                  np.asarray(st_r.info.L))
    assert int(st_l.msg_count) == int(st_r.msg_count)
    assert float(st_l.msg_bytes) == float(st_r.msg_bytes)
    assert float(st_l.msg_bytes) == float(st_l.msg_bytes_analytic)
    assert float(st_l.msg_bytes) == incom.MSG_BYTES * int(st_l.msg_count)


def test_local_transports_identical(medium_graph):
    """gather-compacted broadcast, destination-bucketed all_to_all and the
    flat pool transport deliver identical walks and identical measured
    traffic (placement is deterministic in (source, record) order)."""
    part = _parts(medium_graph)[4]
    base = _local(medium_graph, part, 4, transport="pool")
    for tr, cap in (("gather", 16), ("a2a", 8)):
        st = _local(medium_graph, part, 4, transport=tr, exchange_cap=cap)
        np.testing.assert_array_equal(np.asarray(base.path),
                                      np.asarray(st.path), err_msg=tr)
        assert int(base.msg_count) == int(st.msg_count)
        assert float(base.msg_bytes) == float(st.msg_bytes)


def test_local_fullpath_and_window_modes(medium_graph):
    """The compacted engine keeps the baseline accountings: fullpath ships
    24+8L (measured == analytic) and reg_window ships 80+8K."""
    part = _parts(medium_graph)[4]
    spec_fp = WalkSpec(max_len=32, min_len=8, mu=-1.0, info_mode="fullpath",
                       reg_start=16)
    st = _local(medium_graph, part, 4, spec=spec_fp)
    assert int(st.msg_count) > 0
    assert float(st.msg_bytes) == pytest.approx(float(st.msg_bytes_analytic))
    spec_w = WalkSpec(max_len=32, min_len=8, mu=0.995, info_mode="incom",
                      reg_window=6)
    st = _local(medium_graph, part, 4, spec=spec_w)
    assert float(st.msg_bytes) == pytest.approx(
        (incom.MSG_BYTES + 8 * 6) * int(st.msg_count))


# ---------------------------------------------------------------------------
# Overflow paths: spill rounds (tiny exchange cap) + pool growth retry
# ---------------------------------------------------------------------------


def test_spill_rounds_with_tiny_exchange_cap(medium_graph):
    """cap=1 forces many spill rounds per superstep; the walk and the
    measured traffic must not change."""
    part = _parts(medium_graph)[4]
    ref = _local(medium_graph, part, 4)
    tiny = _local(medium_graph, part, 4, transport="gather", exchange_cap=1)
    np.testing.assert_array_equal(np.asarray(ref.path), np.asarray(tiny.path))
    assert int(ref.msg_count) == int(tiny.msg_count)
    assert float(ref.msg_bytes) == float(tiny.msg_bytes)


def test_pool_overflow_grows_and_recovers(medium_graph):
    """A deliberately undersized slot pool overflows, the driver doubles
    it and re-runs; the final walk is bit-identical and the retry is
    visible in the stats."""
    part = _parts(medium_graph)[4]
    ref = _local(medium_graph, part, 4)
    small, stats = _local(medium_graph, part, 4, pool_factor=0.05,
                          with_stats=True)
    np.testing.assert_array_equal(np.asarray(ref.path), np.asarray(small.path))
    assert stats["pool_retries"] >= 1
    assert stats["pool_slots"] > 0.05 * 96 / 4


def test_returning_walker_revives_ghost_slot():
    """Walkers that ping-pong between two shards every superstep must
    REVIVE their own ghost slots (no free slot exists at pool == B when
    every lane left a ghost behind); the walk still matches the dense
    reference and the driver never trips the pool == B overflow assert."""
    from repro.graph.csr import build_csr

    # 0-1, 2-3: two disjoint edges; partition splits every pair across
    # shards, so every accepted step is a migration straight back into
    # the slot the walker ghosted the superstep before.
    g = build_csr(np.array([[0, 1], [2, 3]]), num_nodes=4)
    part = np.array([0, 1, 0, 1])
    spec = WalkSpec(max_len=12, min_len=4, mu=-1.0, info_mode="incom",
                    reg_start=16)
    sources = jnp.arange(4, dtype=jnp.int32)
    key = jax.random.PRNGKey(3)
    dense = run_walk_batch(g, sources, key, make_policy("deepwalk"), spec)
    st = run_walk_sharded(g, sources, key, make_policy("deepwalk"), spec,
                          jnp.asarray(part, jnp.int32), 2, engine="local",
                          pool_factor=10.0)       # pool == B from the start
    np.testing.assert_array_equal(np.asarray(dense.path), np.asarray(st.path))
    np.testing.assert_array_equal(np.asarray(dense.info.L),
                                  np.asarray(st.info.L))
    # every step after the first is a hand-off for every live lane
    assert int(st.msg_count) >= 4 * (spec.max_len - 2)


def test_shard_stats_surface_balance(medium_graph):
    """with_stats exposes per-shard supersteps, occupancy and CSR bytes so
    balance skew is visible to benchmarks."""
    part = _parts(medium_graph)[4]
    st, stats = _local(medium_graph, part, 4, with_stats=True)
    for key in ("supersteps", "msg_count", "peak_lane_occupancy",
                "final_lane_occupancy", "owned_nodes",
                "csr_bytes_per_shard"):
        assert len(stats[key]) == 4, key
    assert max(stats["supersteps"]) == int(st.supersteps)
    assert sum(stats["owned_nodes"]) == medium_graph.num_nodes
    assert all(v <= stats["pool_slots"]
               for v in stats["peak_lane_occupancy"])


def test_local_spmd_matches_stacked(medium_graph):
    """shard_map execution of the partition-local engine (slices placed
    per device, all_to_all exchange) is walk-identical to the stacked
    emulation (broadcast exchange)."""
    mesh = make_walk_mesh(4)
    if mesh is None:
        pytest.skip("needs >= 4 devices (e.g. "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    part = _parts(medium_graph)[4]
    g = medium_graph.with_edge_cm()
    sources = jnp.arange(64, dtype=jnp.int32)
    key = jax.random.PRNGKey(7)
    st_v = run_walk_sharded(g, sources, key, make_policy("huge"), SPEC,
                            jnp.asarray(part, jnp.int32), 4, engine="local")
    st_m = run_walk_sharded(g, sources, key, make_policy("huge"), SPEC,
                            jnp.asarray(part, jnp.int32), 4, mesh=mesh,
                            engine="local")
    np.testing.assert_array_equal(np.asarray(st_v.path), np.asarray(st_m.path))
    np.testing.assert_array_equal(np.asarray(st_v.info.L),
                                  np.asarray(st_m.info.L))
    assert int(st_v.msg_count) == int(st_m.msg_count)
    assert float(st_v.msg_bytes) == float(st_m.msg_bytes)


def test_partitioned_csr_cache_reuses(medium_graph):
    g = medium_graph.with_edge_cm()
    asn = _parts(medium_graph)[4]
    a = partitioned_csr_for(g, asn, 4)
    b = partitioned_csr_for(g, asn, 4)
    assert a is b


# ---------------------------------------------------------------------------
# ΔD controller noise floor (windowed gate)
# ---------------------------------------------------------------------------


def test_windowed_delta_gate_cuts_noise_floor():
    """A flat D series with pure sampling noise above the raw ΔD floor
    pins the paper-literal gate at max_rounds; the windowed-mean gate
    attenuates the noise ~window-fold and terminates."""
    # Alternating +-a sampling noise on a converged D: the raw delta is 2a
    # forever; the window-6 mean cancels it exactly once warm.
    series = 0.5 + 1e-3 * (-1.0) ** np.arange(64)
    raw = WalkCountController(delta=5e-4, min_rounds=2, max_rounds=40,
                              window=1)
    win = WalkCountController(delta=5e-4, min_rounds=2, max_rounds=40,
                              window=6)
    for d in series:
        if not raw.update_d(float(d)):
            break
    for d in series:
        if not win.update_d(float(d)):
            break
    assert raw.rounds == 40                  # noise keeps the raw gate open
    assert win.rounds < 15                   # smoothed delta crosses delta


def test_windowed_delta_gate_tracks_trend(small_graph):
    """On the seed graph at a tight delta the windowed gate must not stop
    EARLIER than the trend warrants: it ignores single-round noise
    downcrossings (the raw gate's false stops) yet still terminates
    before max_rounds."""
    from repro.core.corpus import generate_corpus

    kw = dict(policy="deepwalk",
              spec=WalkSpec(max_len=16, min_len=6, reg_start=16),
              delta=1e-4, min_rounds=2, max_rounds=30, seed=4)
    raw = generate_corpus(small_graph, window=1, **kw)
    win = generate_corpus(small_graph, window=3, **kw)
    assert win.rounds < 30                   # terminates despite noise
    assert win.rounds >= raw.rounds          # no noise-induced false stop