"""Fault-tolerance tests for the walk→train lifecycle.

The recovery contract under test: every host-boundary crash — mid-round,
mid-superstep, mid-tail, mid-checkpoint, mid-refresh-splice, mid-WAL-append
— is survivable from durable state alone, and the recovered run's final
embeddings are BIT-IDENTICAL to an uninterrupted run (vertex-keyed walk
RNG + step-keyed train RNG + persisted cursors make replay deterministic,
not merely statistically equivalent).
"""

import dataclasses
import logging

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import EmbedConfig, make_walk_plan
from repro.core.dsgl import DSGLConfig
from repro.core.incremental import IncrementalRefresh
from repro.core.termination import WalkCountController
from repro.graph.delta import EdgeBatch
from repro.graph.generators import churn_batch, rmat_graph
from repro.runtime.faults import (FaultInjector, NullInjector,
                                  SimulatedFailure, run_with_restarts)
from repro.runtime.ingest import IngestConfig, IngestDriver, WriteAheadLog
from repro.runtime.trainer import StreamingEmbedPipeline


def _plan(seed=3, dim=16):
    cfg = dataclasses.replace(EmbedConfig(dim=dim, seed=seed),
                              rng_mode="vertex")
    policy, spec, rounds = make_walk_plan(cfg)
    return policy, spec, rounds, DSGLConfig(dim=dim, seed=seed)


def _pipeline(graph, **kw):
    policy, spec, rounds, dsgl = _plan()
    return StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl, **kw)


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(128, 7, seed=7)


@pytest.fixture(scope="module")
def reference(graph):
    """Uninterrupted run: the bit-identity target for every crash test."""
    p = _pipeline(graph)
    res = p.run()
    phi_in, phi_out = p.embeddings()
    return {"pipe": p, "res": res, "phi_in": phi_in, "phi_out": phi_out}


# ---------------------------------------------------------------------------
# Snapshot round-trip
# ---------------------------------------------------------------------------


class TestSnapshotRoundTrip:
    def test_save_resume_bit_identical(self, graph, reference, tmp_path):
        p = reference["pipe"]
        root = str(tmp_path / "ckpt")
        p.save(root)
        policy, spec, _, dsgl = _plan()
        q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)

        a_in, a_out = p.embeddings()
        b_in, b_out = q.embeddings()
        np.testing.assert_array_equal(a_in, b_in)
        np.testing.assert_array_equal(a_out, b_out)
        assert jnp.array_equal(p.ring.walks, q.ring.walks)
        assert jnp.array_equal(p.ring.ocn, q.ring.ocn)
        assert int(p.ring.cursor) == int(q.ring.cursor)
        assert int(p.ring.total) == int(q.ring.total)
        np.testing.assert_array_equal(p._slot_root, q._slot_root)
        np.testing.assert_array_equal(p._slot_round, q._slot_round)
        np.testing.assert_array_equal(np.asarray(p.key_walk),
                                      np.asarray(q.key_walk))
        np.testing.assert_array_equal(np.asarray(p.key_train),
                                      np.asarray(q.key_train))
        assert p.controller.history == q.controller.history
        assert (p._phase, p._trained_rounds, p._rounds_walked,
                p.global_step) == (q._phase, q._trained_rounds,
                                   q._rounds_walked, q.global_step)

    def test_resume_empty_root_raises(self, graph, tmp_path):
        policy, spec, _, dsgl = _plan()
        with pytest.raises(FileNotFoundError):
            StreamingEmbedPipeline.resume(str(tmp_path / "nothing"),
                                          policy, spec, dsgl)

    def test_controller_state_round_trip(self):
        c = WalkCountController(delta=1e-3, min_rounds=2, max_rounds=20,
                                window=3)
        rng = np.random.default_rng(0)
        d = 1.0
        for _ in range(6):
            d *= 0.7 + 0.02 * rng.standard_normal()
            c.update_d(d)
        c2 = WalkCountController.from_state(c.to_state())
        assert c2.history == c.history
        assert c2._smooth == c._smooth
        # Identical future decisions from the restored gate.
        for nxt in (d * 0.9, d * 0.9001, d * 0.89999):
            ca = WalkCountController.from_state(c.to_state())
            cb = WalkCountController.from_state(c.to_state())
            assert ca.update_d(nxt) == cb.update_d(nxt)


# ---------------------------------------------------------------------------
# Crash-at-every-injection-point sweep
# ---------------------------------------------------------------------------


def _run_with_crashes(graph, root, plan, torn_plan=None, max_restarts=8):
    """Supervise a pipeline run under an injection plan: crash → resume
    from the newest durable snapshot → continue. Returns (pipe, injector,
    restarts)."""
    policy, spec, rounds, dsgl = _plan()
    faults = FaultInjector(plan, torn_plan or {})
    state = {"p": StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl)}

    def attempt(i):
        return state["p"].run(ckpt_root=root, ckpt_every_rounds=1,
                              faults=faults)

    def recover(i):
        try:
            state["p"] = StreamingEmbedPipeline.resume(root, policy, spec,
                                                       dsgl)
        except FileNotFoundError:
            # Crashed before the first snapshot: start over from zero.
            state["p"] = StreamingEmbedPipeline(graph, policy, spec, rounds,
                                                dsgl)

    _, restarts = run_with_restarts(attempt, recover=recover,
                                    max_restarts=max_restarts)
    return state["p"], faults, restarts


class TestCrashReplay:
    @pytest.mark.parametrize("point,occurrence", [
        ("round", 2),        # crash at a round boundary
        ("superstep", 5),    # crash mid-round, some chunks dispatched
        ("tail", 1),         # crash between schedule-tail iterations
        ("ckpt_write", 3),   # crash before a snapshot commits
    ])
    def test_crash_point_bit_identical(self, graph, reference, tmp_path,
                                       point, occurrence):
        p, faults, restarts = _run_with_crashes(
            graph, str(tmp_path / "ckpt"), {point: [occurrence]})
        assert restarts == 1 and faults.fired == [(point, occurrence)]
        phi_in, phi_out = p.embeddings()
        np.testing.assert_array_equal(reference["phi_in"], phi_in)
        np.testing.assert_array_equal(reference["phi_out"], phi_out)
        assert jnp.array_equal(reference["pipe"].ring.walks, p.ring.walks)

    def test_multi_crash_run(self, graph, reference, tmp_path):
        p, faults, restarts = _run_with_crashes(
            graph, str(tmp_path / "ckpt"),
            {"round": [3], "superstep": [9], "tail": [2]})
        assert restarts == 3 and faults.pending == 0
        phi_in, _ = p.embeddings()
        np.testing.assert_array_equal(reference["phi_in"], phi_in)

    def test_torn_checkpoint_falls_back(self, graph, reference, tmp_path):
        # The 3rd snapshot write crashes mid-commit, leaving a torn
        # (corrupt-manifest) step directory behind; the validating loader
        # must treat it as invisible, fall back one snapshot, and the run
        # must still converge bit-identically.
        root = str(tmp_path / "ckpt")
        p, faults, restarts = _run_with_crashes(
            graph, root, {}, torn_plan={"ckpt": [2]})
        assert restarts == 1
        phi_in, _ = p.embeddings()
        np.testing.assert_array_equal(reference["phi_in"], phi_in)

    def test_crash_without_snapshot_exhausts_supervisor(self, graph,
                                                        tmp_path):
        # A deterministic crash with no progress possible must surface,
        # not loop forever: plan more failures than max_restarts.
        with pytest.raises(SimulatedFailure):
            _run_with_crashes(graph, str(tmp_path / "ckpt"),
                              {"round": list(range(20))}, max_restarts=3)

    def test_injector_fires_once_and_counts(self):
        f = FaultInjector({"round": [1]})
        f.fire("round")                       # occurrence 0: no fire
        with pytest.raises(SimulatedFailure):
            f.fire("round")                   # occurrence 1: fires
        f.fire("round")                       # occurrence 1 consumed
        assert f.counts["round"] == 3
        assert f.fired == [("round", 1)]
        assert f.pending == 0
        null = NullInjector()
        null.fire("round"); null.fire("round")
        assert not null.torn("ckpt")


# ---------------------------------------------------------------------------
# Shard-loss degraded recovery
# ---------------------------------------------------------------------------


class TestShardLoss:
    def test_lost_shard_rewalk_restores_ring(self, graph):
        from repro.core.corpus import ring_replace
        from repro.core.mpgp import mpgp_partition

        part = mpgp_partition(graph, 2).assignment
        p = _pipeline(graph, assignment=part, num_shards=2)
        p.run()
        walks_ref = np.asarray(p.ring.walks).copy()
        ocn_ref = np.asarray(p.ring.ocn).copy()
        phi_ref, _ = p.embeddings()

        # Simulate losing shard 1: zap every resident slot rooted in it
        # (ring_replace keeps ocn consistent with the corrupted corpus, the
        # state a surviving host actually observes after a peer dies).
        lost = np.asarray(part) == 1
        bad_slots = np.nonzero(
            (p._slot_root >= 0) & lost[np.maximum(p._slot_root, 0)])[0]
        assert len(bad_slots) > 0
        garbage = jnp.zeros((len(bad_slots), p.ring.walks.shape[1]),
                            jnp.int32)
        p.ring = ring_replace(p.ring, jnp.asarray(bad_slots, jnp.int32),
                              garbage, jnp.ones(len(bad_slots), jnp.int32))
        assert not np.array_equal(np.asarray(p.ring.walks), walks_ref)

        info = p.recover_shard_loss(1)
        assert info["lost_roots"] == int(lost.sum())
        assert info["rewalk_walks"] >= len(bad_slots)
        # Vertex-keyed replay under original round keys: EXACT restoration.
        np.testing.assert_array_equal(np.asarray(p.ring.walks), walks_ref)
        np.testing.assert_array_equal(np.asarray(p.ring.ocn), ocn_ref)

        # Degraded-mode quality: embeddings trained from the recovered
        # corpus score like the undamaged run (bit-equal here, but the
        # AUC comparison is the contract a lossy recovery would have to
        # meet too).
        from benchmarks.common import link_prediction_auc
        phi_now, _ = p.embeddings()
        auc_ref = link_prediction_auc(graph, phi_ref,
                                      np.random.default_rng(7))
        auc_now = link_prediction_auc(graph, phi_now,
                                      np.random.default_rng(7))
        assert abs(auc_now - auc_ref) <= 0.02, (auc_now, auc_ref)

    def test_shard_loss_needs_vertex_rng(self, graph):
        cfg = EmbedConfig(dim=16, seed=3)      # default lane-keyed RNG
        policy, spec, rounds = make_walk_plan(cfg)
        p = StreamingEmbedPipeline(graph, policy, spec, rounds,
                                   DSGLConfig(dim=16, seed=3))
        with pytest.raises(ValueError, match="vertex"):
            p.recover_shard_loss(0)

    def test_unknown_shard_rejected(self, graph, reference):
        with pytest.raises(ValueError, match="shard"):
            reference["pipe"].recover_shard_loss(3)


# ---------------------------------------------------------------------------
# Refresh interrupted mid-splice (the half-updated-ring hazard)
# ---------------------------------------------------------------------------


class TestRefreshCrash:
    def test_splice_crash_recovery_bit_identical(self, graph, tmp_path):
        policy, spec, _, dsgl = _plan()
        p = _pipeline(graph)
        p.run()
        root = str(tmp_path / "pre_refresh")
        p.save(root)
        batch = churn_batch(graph, 0.05, seed=11)

        # Reference: the same snapshot refreshed without interruption.
        q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)
        IncrementalRefresh(q).apply_updates(batch).refresh()
        phi_ref, _ = q.embeddings()

        # Crash after the first resident round's splices landed: the ring
        # is now half old, half new — the state that must never survive.
        faults = FaultInjector({"refresh_splice": [1]})
        with pytest.raises(SimulatedFailure):
            IncrementalRefresh(p).apply_updates(batch).refresh(faults=faults)
        # Recovery protocol: restore the pre-refresh snapshot, re-apply
        # the churn, redo the refresh. Bit-identical to the uninterrupted
        # refresh — the torn intermediate state is unobservable.
        p2 = StreamingEmbedPipeline.resume(root, policy, spec, dsgl)
        IncrementalRefresh(p2).apply_updates(batch).refresh()
        phi_in, _ = p2.embeddings()
        np.testing.assert_array_equal(phi_ref, phi_in)
        assert jnp.array_equal(q.ring.walks, p2.ring.walks)
        assert jnp.array_equal(q.ring.ocn, p2.ring.ocn)


# ---------------------------------------------------------------------------
# WAL + ingest driver
# ---------------------------------------------------------------------------


def _batches(n, seed=5, num_nodes=128, k=6):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        ins = rng.integers(0, num_nodes, (k, 2))
        out.append(EdgeBatch(insert=ins[ins[:, 0] != ins[:, 1]]))
    return out


class TestWriteAheadLog:
    def test_append_replay_truncate(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.log"))
        batches = _batches(3)
        for i, b in enumerate(batches, start=1):
            wal.append(i, b)
        recs, _ = wal.replay()
        assert [s for s, _ in recs] == [1, 2, 3]
        for (_, got), want in zip(recs, batches):
            np.testing.assert_array_equal(got.insert, want.insert)
            np.testing.assert_array_equal(got.delete, want.delete)
        recs, _ = wal.replay(after_seq=2)
        assert [s for s, _ in recs] == [3]
        wal.truncate_upto(2)
        recs, _ = wal.replay()
        assert [s for s, _ in recs] == [3]
        wal.truncate_upto(3)
        assert wal.replay() == ([], 0)

    def test_torn_tail_detected(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.log"))
        b1, b2 = _batches(2)
        wal.append(1, b1)
        # Crash mid-append: half of record 2 reaches disk.
        faults = FaultInjector(torn_plan={"wal": [0]})
        with pytest.raises(SimulatedFailure):
            wal.append(2, b2, faults=faults)
        recs, _ = wal.replay()
        assert [s for s, _ in recs] == [1]      # torn record 2 discarded
        # Truncation rewrites only the valid prefix; the tail is gone.
        wal.truncate_upto(0)
        recs, _ = wal.replay()
        assert [s for s, _ in recs] == [1]

    def test_garbage_file_is_all_torn(self, tmp_path):
        path = str(tmp_path / "wal.log")
        with open(path, "wb") as f:
            f.write(b"not a wal record at all")
        assert WriteAheadLog(path).replay() == ([], 0)


class TestIngestDriver:
    @pytest.fixture(scope="class")
    def trained(self, graph):
        p = _pipeline(graph)
        p.run()
        root_store = {}
        return p, root_store

    def _driver(self, graph, tmp_path, name, **cfg_kw):
        p = _pipeline(graph)
        p.run()
        cfg = IngestConfig(**cfg_kw)
        return IngestDriver(str(tmp_path / name), p, cfg=cfg)

    def test_submit_drain_staleness(self, graph, tmp_path):
        drv = self._driver(graph, tmp_path, "a", apply_every=2)
        b1, b2, b3 = _batches(3, seed=5)
        drv.submit(b1)
        st = drv.staleness()
        assert st["pending_batches"] == 1 and st["applied_seq"] == 0
        drv.submit(b2)                       # cadence reached → drain
        st = drv.staleness()
        assert st["pending_batches"] == 0
        assert st["applied_seq"] == st["appended_seq"] == 2
        assert st["drains"] == 1
        # WAL truncated back to empty after the drain.
        assert drv.wal.replay() == ([], 0)
        drv.submit(b3)
        assert drv.staleness()["pending_batches"] == 1

    def test_staleness_backpressure(self, graph, tmp_path):
        drv = self._driver(graph, tmp_path, "b", apply_every=100,
                           max_pending_edges=4)
        (b,) = _batches(1, seed=6, k=8)
        drv.submit(b)                        # > 4 pending edges → forced
        assert drv.staleness()["pending_batches"] == 0

    def test_crash_recovery_equals_uninterrupted(self, graph, tmp_path):
        root = str(tmp_path / "c")
        drv = self._driver(graph, tmp_path, "c", apply_every=10)
        b1, b2 = _batches(2, seed=7)
        drv.submit(b1)
        drv.submit(b2)                       # durable in WAL, not applied
        assert drv.staleness()["pending_batches"] == 2

        # Process dies here. Recover purely from disk: snapshot + WAL tail.
        rec = IngestDriver.recover(root, drv.pipeline.policy,
                                   drv.pipeline.spec, drv.pipeline.cfg)
        assert rec.staleness()["applied_seq"] == 2
        assert rec.staleness()["pending_batches"] == 0
        # ... and matches the never-crashed driver draining the same WAL.
        drv.drain()
        a_in, _ = drv.embeddings()
        b_in, _ = rec.embeddings()
        np.testing.assert_array_equal(a_in, b_in)

    def test_torn_wal_append_not_acknowledged(self, graph, tmp_path):
        root = str(tmp_path / "d")
        faults = FaultInjector(torn_plan={"wal": [0]})
        p = _pipeline(graph)
        p.run()
        drv = IngestDriver(root, p, cfg=IngestConfig(apply_every=10),
                           faults=faults)
        (b,) = _batches(1, seed=8)
        with pytest.raises(SimulatedFailure):
            drv.submit(b)                    # crash mid-append
        # Recovery sees no acknowledged batch: the torn record is dropped.
        rec = IngestDriver.recover(root, p.policy, p.spec, p.cfg)
        st = rec.staleness()
        assert st["appended_seq"] == st["applied_seq"] == 0
        assert rec.wal.replay() == ([], 0)

    def test_refresh_failure_restores_then_retries(self, graph, tmp_path):
        root = str(tmp_path / "e")
        p = _pipeline(graph)
        p.run()
        delays = []
        # First refresh attempt dies at entry (churn staged, nothing
        # spliced); the driver must restore the snapshot and retry.
        faults = FaultInjector({"refresh": [0]})
        drv = IngestDriver(root, p, cfg=IngestConfig(
            apply_every=1, max_retries=2, backoff_s=0.01),
            faults=faults, sleep=delays.append)
        (b,) = _batches(1, seed=9)
        drv.submit(b)
        st = drv.staleness()
        assert st["applied_seq"] == 1 and st["retries"] == 1
        assert delays == [0.01]              # exponential backoff engaged

        # Same churn, no faults: the retried result is bit-identical.
        q = _pipeline(graph)
        q.run()
        ref = IngestDriver(str(tmp_path / "e_ref"), q,
                           cfg=IngestConfig(apply_every=1))
        ref.submit(b)
        a_in, _ = drv.embeddings()
        b_in, _ = ref.embeddings()
        np.testing.assert_array_equal(a_in, b_in)

    def test_refresh_failure_exhausts_retries(self, graph, tmp_path):
        p = _pipeline(graph)
        p.run()
        faults = FaultInjector({"refresh": [0, 1]})
        drv = IngestDriver(str(tmp_path / "f"), p, cfg=IngestConfig(
            apply_every=1, max_retries=1, backoff_s=0.0),
            faults=faults, sleep=lambda s: None)
        (b,) = _batches(1, seed=10)
        with pytest.raises(SimulatedFailure):
            drv.submit(b)
        # The batch stays durable in the WAL: recovery can still absorb it
        # once the fault condition clears.
        rec = IngestDriver.recover(str(tmp_path / "f"), p.policy, p.spec,
                                   p.cfg)
        assert rec.staleness()["applied_seq"] == 1


# ---------------------------------------------------------------------------
# Structured logging
# ---------------------------------------------------------------------------


class TestLogging:
    def test_env_level_parsing(self, monkeypatch):
        from repro.common import logging as rlog
        monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
        assert rlog._env_level() == logging.DEBUG
        monkeypatch.setenv("REPRO_LOG_LEVEL", "41")
        assert rlog._env_level() == 41
        monkeypatch.setenv("REPRO_LOG_LEVEL", "bogus")
        assert rlog._env_level() == logging.INFO
        monkeypatch.delenv("REPRO_LOG_LEVEL")
        assert rlog._env_level() == logging.INFO

    def test_env_level_applied_at_configure(self, monkeypatch):
        from repro.common import logging as rlog
        monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
        root = logging.getLogger("repro")
        saved = (root.handlers[:], root.level)
        root.handlers = []
        try:
            rlog.get_logger("repro.test.envlvl")
            assert root.level == logging.WARNING
            # Handler install is idempotent by tag, not by module flag.
            n = len(root.handlers)
            rlog.get_logger("repro.test.envlvl2")
            assert len(root.handlers) == n
        finally:
            root.handlers = saved[0]
            root.setLevel(saved[1])
        monkeypatch.delenv("REPRO_LOG_LEVEL")
        rlog.refresh_log_level()

    def _captured(self):
        """(handler, buffer, old_stream) of the configured repro handler."""
        import io
        from repro.common.logging import get_logger
        get_logger()
        h = logging.getLogger("repro").handlers[0]
        buf = io.StringIO()
        return h, buf, h.setStream(buf)

    def test_log_context_fields(self):
        from repro.common.logging import get_logger, log_context
        lg = get_logger("repro.test.ctx")
        h, buf, old = self._captured()
        try:
            with log_context(round=4, shard=1):
                lg.info("inside")
            lg.info("outside")
        finally:
            h.setStream(old)
        lines = buf.getvalue().splitlines()
        inside = [ln for ln in lines if "inside" in ln]
        outside = [ln for ln in lines if "outside" in ln]
        assert inside and "round=4" in inside[0] and "shard=1" in inside[0]
        assert outside and "round=" not in outside[0]

    def test_log_context_nests_and_restores(self):
        from repro.common.logging import get_logger, log_context
        lg = get_logger("repro.test.ctx2")
        h, buf, old = self._captured()
        try:
            with log_context(a=1):
                with log_context(b=2):
                    lg.info("deep")
                lg.info("shallow")
        finally:
            h.setStream(old)
        lines = buf.getvalue().splitlines()
        deep = [ln for ln in lines if "deep" in ln][0]
        shallow = [ln for ln in lines if "shallow" in ln][0]
        assert "a=1" in deep and "b=2" in deep
        assert "a=1" in shallow and "b=2" not in shallow
