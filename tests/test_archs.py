"""Deliverable (f): every assigned architecture instantiates at REDUCED
size and runs one forward/train step on CPU — shapes asserted, no NaNs.
Decode path is exercised for every decoder-bearing arch; state-based archs
additionally check prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import zoo


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(key, cfg)
    batch = zoo.train_batch(cfg, 2, 16, jax.random.fold_in(key, 1))
    loss, grads = jax.value_and_grad(zoo.loss_fn(cfg))(params, batch)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    # one SGD step changes the loss
    params2 = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - 0.1 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    loss2 = zoo.loss_fn(cfg)(params2, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) != pytest.approx(float(loss), abs=1e-7)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = zoo.init_params(key, cfg)
    b, s, max_len = 2, 8, 24
    batch = zoo.train_batch(cfg, b, s, jax.random.fold_in(key, 1))
    batch.pop("labels")
    logits, caches = zoo.prefill_fn(cfg, max_len)(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    prompt_len = batch["tokens"].shape[1]
    lg2, caches = zoo.decode_fn(cfg)(params, caches, tok,
                                     jnp.int32(prompt_len))
    assert lg2.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["zamba2_7b", "xlstm_350m", "yi_6b",
                                  "minicpm3_4b"])
def test_decode_matches_full_forward(arch):
    """Prefill(t0..tn) then decode(tn+1) must equal the full forward pass's
    next-token logits — the KV/state cache correctness property."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = zoo.init_params(key, cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.fold_in(key, 3), (b, s), 0,
                              cfg.vocab_size, jnp.int32)
    # full forward logits at the last position == prefill's last logits
    logits_pre, caches = zoo.prefill_fn(cfg, s + 4)(
        params, {"tokens": toks})
    from repro.models import transformer as T
    # recompute via prefill of the same tokens with one extra step
    lg_a, caches_a = zoo.prefill_fn(cfg, s + 4)(params,
                                                {"tokens": toks[:, :-1]})
    lg_b, _ = zoo.decode_fn(cfg)(params, caches_a, toks[:, -1:],
                                 jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(lg_b, np.float32),
                               np.asarray(logits_pre, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_moe_param_counts_match_grid():
    """A2.7B really activates ~2.7B; deepseek-lite ~16B total."""
    q = get_config("qwen2_moe_a2_7b")
    assert q.active_param_count() / 1e9 == pytest.approx(2.7, abs=0.3)
    d = get_config("deepseek_v2_lite_16b")
    assert d.param_count() / 1e9 == pytest.approx(16, abs=1.5)
    l = get_config("llama3_405b")
    assert l.param_count() / 1e9 == pytest.approx(405, abs=8)


def test_grid_cells_and_skips():
    from repro.configs import grid_cells
    cells = grid_cells()
    assert len(cells) == 40
    runnable = [(a, s) for a, s, ok, _ in cells if ok]
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert len(runnable) == 32
    assert all(s == "long_500k" for _, s in skipped)
    assert ("zamba2_7b", "long_500k") in runnable
    assert ("xlstm_350m", "long_500k") in runnable
