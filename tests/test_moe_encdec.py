"""MoE dispatch invariants + encoder-decoder cache consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe as moe_mod
from repro.models import zoo
from repro.models.config import ModelConfig


def _moe_cfg(**kw):
    base = dict(name="m", family="moe", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                moe=True, n_routed_experts=6, n_shared_experts=0, top_k=2,
                moe_d_ff=16, capacity_factor=8.0, dtype="float32",
                remat="none")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_expert_padding():
    cfg = _moe_cfg()
    assert moe_mod.padded_experts(cfg) == 16       # 6 -> 16 for TP16
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert p["gate"].shape[0] == 16
    assert p["router"].shape == (32, 6)            # router sees REAL experts


def test_moe_identity_when_experts_equal():
    """With all experts holding IDENTICAL weights and ample capacity, the
    MoE output must equal a single dense MLP (gates sum to 1)."""
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(1)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    for nm in ("gate", "up", "down"):
        p[nm] = jnp.broadcast_to(p[nm][:1], p[nm].shape)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 8, 32))
    y, aux = moe_mod.moe_ffn(x, p, cfg)
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["gate"][0]))
    u = jnp.einsum("bsd,df->bsf", x, p["up"][0])
    dense = jnp.einsum("bsf,fd->bsd", g * u, p["down"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """capacity_factor ~ 0 forces drops: output collapses toward zero (plus
    shared expert if any) rather than erroring."""
    cfg = _moe_cfg(capacity_factor=1e-6)
    p = moe_mod.init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 32))
    y, _ = moe_mod.moe_ffn(x, p, cfg)
    y_full, _ = moe_mod.moe_ffn(
        x, p, dataclasses.replace(cfg, capacity_factor=8.0))
    assert float(jnp.abs(y).mean()) < float(jnp.abs(y_full).mean())


def test_moe_aux_loss_balanced_vs_skewed():
    """Uniform routing gives aux ~ 1; a skewed router scores higher."""
    cfg = _moe_cfg()
    p = moe_mod.init_moe(jax.random.PRNGKey(5), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 32))
    p_skew = dict(p)
    p_skew["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux_rand = moe_mod.moe_ffn(x, p, cfg)
    _, aux_skew = moe_mod.moe_ffn(x, p_skew, cfg)
    assert float(aux_skew) > float(aux_rand)


def test_encdec_decode_matches_two_phase_prefill():
    """prefill(t0..tn-1) + decode(tn) == prefill(t0..tn) last logits."""
    cfg = get_reduced("seamless_m4t_large_v2")
    key = jax.random.PRNGKey(7)
    params = zoo.init_params(key, cfg)
    b, s_src, s_tgt = 2, 6, 10
    frames = jax.random.normal(jax.random.fold_in(key, 1),
                               (b, s_src, cfg.d_model), jnp.float32)
    toks = jax.random.randint(jax.random.fold_in(key, 2), (b, s_tgt), 0,
                              cfg.vocab_size, jnp.int32)
    full_logits, _ = zoo.prefill_fn(cfg, s_tgt + 4)(
        params, {"frames": frames, "tokens": toks})
    part_logits, caches = zoo.prefill_fn(cfg, s_tgt + 4)(
        params, {"frames": frames, "tokens": toks[:, :-1]})
    step_logits, _ = zoo.decode_fn(cfg)(params, caches, toks[:, -1:],
                                        jnp.int32(s_tgt - 1))
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_encdec_encoder_bidirectional():
    """Flipping a LATE source frame must change EARLY encoder outputs
    (bidirectional attention), unlike a causal decoder."""
    from repro.models import encdec as E
    cfg = get_reduced("seamless_m4t_large_v2")
    params = zoo.init_params(jax.random.PRNGKey(8), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(9), (1, 8, cfg.d_model))
    out1 = E.encode(params, cfg, frames)
    frames2 = frames.at[0, -1].set(-frames[0, -1])
    out2 = E.encode(params, cfg, frames2)
    early_delta = float(jnp.abs(out1[0, 0] - out2[0, 0]).max())
    assert early_delta > 1e-6
