import os
import sys

# Tests see the default single CPU device (the 512-device flag belongs ONLY
# to the dry-run); keep JAX quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph.generators import rmat_graph
    return rmat_graph(256, 8, seed=7)


@pytest.fixture(scope="session")
def medium_graph():
    from repro.graph.generators import rmat_graph
    return rmat_graph(1024, 10, seed=3)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
