"""Per-kernel shape/dtype sweeps: Pallas kernels (interpret=True on CPU)
vs their pure-jnp ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.sgns import ops as sg_ops, ref as sg_ref
from repro.kernels.ssm_scan import ops as ssm_ops, ref as ssm_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 2, 256, 32),
                                     (1, 4, 512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(b, h, s, d, causal):
    key = jax.random.PRNGKey(b * 100 + h * 10 + s)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, h, s, d),
                                 jnp.float32) for i in range(3))
    got = fa_ops.flash_attention_pallas(q, k, v, causal=causal,
                                        interpret=True)
    want = fa_ref.mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_attention_wrapper_pads_ragged_seq():
    """The public ops wrapper pads non-tile-multiple lengths (causal)."""
    key = jax.random.PRNGKey(77)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (2, 1, 384, 128), jnp.float32)
               for i in range(3))
    got = fa_ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = fa_ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (1, 2, 256, 64), dtype) for i in range(3))
    got = fa_ops.flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = fa_ref.mha_reference(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    """q_offset (decode with cache) must equal masked reference."""
    key = jax.random.PRNGKey(1)
    kv_len, q_len = 256, 128
    q = jax.random.normal(key, (1, 2, q_len, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, kv_len, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, kv_len, 64))
    got = fa_ops.flash_attention_pallas(q, k, v, causal=True,
                                        q_offset=kv_len - q_len,
                                        interpret=True)
    want = fa_ref.mha_reference(q, k, v, causal=True,
                                q_offset=kv_len - q_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_chunked_equals_reference_long():
    key = jax.random.PRNGKey(2)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (1, 2, 640, 32), jnp.float32)
               for i in range(3))
    got = fa_ref.mha_chunked(q, k, v, causal=True)
    want = fa_ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


# ---------------------------------------------------------------------------
# SSD scan (Mamba2 / mLSTM)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,p,n", [(2, 64, 16, 8), (4, 128, 32, 16),
                                      (1, 200, 64, 32), (3, 96, 8, 64)])
def test_ssd_chunked_matches_sequential(bh, s, p, n):
    key = jax.random.PRNGKey(bh + s)
    xdt = jax.random.normal(key, (bh, s, p), jnp.float32)
    loga = -jax.random.uniform(jax.random.fold_in(key, 1), (bh, s)) * 0.2
    b = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, n))
    c = jax.random.normal(jax.random.fold_in(key, 3), (bh, s, n))
    y_ref, s_ref = ssm_ref.ssd_scan_reference(xdt, loga, b, c)
    y_chk, s_chk = ssm_ref.ssd_chunked_ref(xdt, loga, b, c, chunk=32)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("chunk", [16, 64, 128])
def test_ssd_pallas_kernel_sweep(chunk):
    bh, s, p, n = 2, 128, 16, 8
    key = jax.random.PRNGKey(chunk)
    xdt = jax.random.normal(key, (bh, s, p), jnp.float32)
    loga = -jax.random.uniform(jax.random.fold_in(key, 1), (bh, s)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, n))
    c = jax.random.normal(jax.random.fold_in(key, 3), (bh, s, n))
    y_ref, s_ref = ssm_ref.ssd_scan_reference(xdt, loga, b, c)
    y_k, s_k = ssm_ops.ssd_chunked_pallas(xdt, loga, b, c, chunk=chunk,
                                          interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=3e-3, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               atol=3e-3, rtol=3e-3)


def test_ssd_decode_matches_scan_tail():
    """Stepping the recurrence one token must continue the scan exactly."""
    bh, s, p, n = 2, 33, 8, 4
    key = jax.random.PRNGKey(5)
    xdt = jax.random.normal(key, (bh, s, p), jnp.float32)
    loga = -jax.random.uniform(jax.random.fold_in(key, 1), (bh, s)) * 0.3
    b = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, n))
    c = jax.random.normal(jax.random.fold_in(key, 3), (bh, s, n))
    y_all, _ = ssm_ref.ssd_scan_reference(xdt, loga, b, c)
    _, s_prefix = ssm_ref.ssd_scan_reference(
        xdt[:, :-1], loga[:, :-1], b[:, :-1], c[:, :-1])
    y_last, _ = ssm_ref.ssd_decode_step(
        s_prefix, xdt[:, -1], loga[:, -1], b[:, -1], c[:, -1])
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(y_all[:, -1]),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# SGNS lifetime kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w,t,d,k,window", [(2, 16, 32, 5, 3),
                                            (1, 24, 16, 4, 5),
                                            (3, 12, 64, 2, 2)])
def test_sgns_pallas_matches_ref(w, t, d, k, window):
    key = jax.random.PRNGKey(w * t)
    ctx = jax.random.normal(key, (w, t, d), jnp.float32) * 0.1
    out = jax.random.normal(jax.random.fold_in(key, 1), (w, t, d)) * 0.1
    neg = jax.random.normal(jax.random.fold_in(key, 2), (t, k, d)) * 0.1
    valid = jax.random.uniform(jax.random.fold_in(key, 3), (w, t)) > 0.2
    lr = jnp.float32(0.01)
    ref_out = sg_ref.sgns_lifetime_ref(ctx, out, neg, valid, lr, window)
    ker_out = sg_ops.sgns_lifetime_batch(
        ctx[None], out[None], neg[None], valid[None], lr, window)
    for a, b, name in zip(ker_out, ref_out, ("ctx", "out", "neg", "loss")):
        np.testing.assert_allclose(np.asarray(a)[0], np.asarray(b),
                                   atol=5e-4, rtol=5e-4, err_msg=name)


def test_sgns_batch_wrapper_matches_ref():
    g, w, t, d, k = 2, 2, 12, 16, 3
    key = jax.random.PRNGKey(9)
    ctx = jax.random.normal(key, (g, w, t, d), jnp.float32) * 0.1
    out = jax.random.normal(jax.random.fold_in(key, 1), (g, w, t, d)) * 0.1
    neg = jax.random.normal(jax.random.fold_in(key, 2), (g, t, k, d)) * 0.1
    valid = jnp.ones((g, w, t), bool)
    lr = jnp.float32(0.025)
    got = sg_ops.sgns_lifetime_batch(ctx, out, neg, valid, lr, 4)
    want = sg_ref.sgns_lifetime_batch_ref(ctx, out, neg, valid, lr, 4)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)
