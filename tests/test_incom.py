"""InCoM (paper §3.1): the O(1) incremental updates must EXACTLY match the
full-path recomputation — Theorem 1 and Eq. 12/13 are algebraic identities,
so these are equality properties, not approximations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import incom, info


def _entropy_ref(path):
    """H(W) per Eq. 4 (log2), recomputed from scratch."""
    vals, counts = np.unique(path, return_counts=True)
    p = counts / len(path)
    return float(-(p * np.log2(p)).sum())


@st.composite
def walks(draw):
    n_nodes = draw(st.integers(2, 12))
    length = draw(st.integers(2, 60))
    return draw(st.lists(st.integers(0, n_nodes - 1),
                         min_size=length, max_size=length))


@given(walks())
@settings(max_examples=60, deadline=None)
def test_incremental_entropy_matches_fullpath(walk):
    """Theorem 1: running H after appending each node == batch recompute."""
    max_len = len(walk) + 1
    path = jnp.full((1, max_len), -1, jnp.int32)
    path = path.at[0, 0].set(walk[0])
    s = incom.InfoState.init(1)
    for v in walk[1:]:
        s, path = incom.accept_update(s, path, jnp.array([v], jnp.int32))
    got = float(s.H[0])
    want = _entropy_ref(walk)
    assert got == pytest.approx(want, abs=1e-3)


@given(walks())
@settings(max_examples=40, deadline=None)
def test_incremental_r2_matches_series_pearson(walk):
    """Eq. 12/13: running R^2 == Pearson^2 over the full (L, H-prefix) series."""
    max_len = len(walk) + 1
    path = jnp.full((1, max_len), -1, jnp.int32)
    path = path.at[0, 0].set(walk[0])
    s = incom.InfoState.init(1)
    h_series = [0.0]
    for v in walk[1:]:
        s, path = incom.accept_update(s, path, jnp.array([v], jnp.int32))
        h_series.append(float(s.H[0]))
    got = float(incom.r_squared(s)[0])
    l_series = np.arange(1, len(h_series) + 1, dtype=np.float64)
    r = info.pearson_r(np.array(h_series), l_series)
    assert got == pytest.approx(r * r, abs=2e-3)


def test_count_in_path_masked():
    path = jnp.array([[3, 1, 3, 7, -1, -1]], jnp.int32)
    length = jnp.array([4.0])
    assert int(incom.count_in_path(path, length.astype(jnp.int32),
                                   jnp.array([3]))[0]) == 2
    # beyond-length entries never count
    assert int(incom.count_in_path(path, jnp.array([2]),
                                   jnp.array([3]))[0]) == 1


def test_message_is_constant_size_80_bytes():
    """Example 1: the InCoM message is 80 B regardless of walk length; the
    HuGE-D full-path message grows as 24 + 8L."""
    assert incom.MSG_BYTES == 80
    assert int(incom.fullpath_msg_bytes(jnp.int32(80))) == 24 + 8 * 80
    # 8.3x claim at L = 80
    assert float(incom.fullpath_msg_bytes(jnp.int32(80))) / incom.MSG_BYTES \
        == pytest.approx(8.3, abs=0.1)


def test_message_pack_unpack_roundtrip():
    s = incom.InfoState.init(4)
    s = incom.stats_step(s, jnp.ones(4) * 0.5, jnp.ones(4) * 2.0)
    msg = incom.pack_message(jnp.arange(4), jnp.arange(4) * 10, s)
    assert msg.shape == (4, incom.MSG_WIDTH)
    wid, nid, s2 = incom.unpack_message(msg)
    np.testing.assert_array_equal(np.asarray(wid), np.arange(4))
    np.testing.assert_array_equal(np.asarray(nid), np.arange(4) * 10)
    for f in ("H", "L", "EH", "EL", "EHL", "EH2", "EL2"):
        np.testing.assert_allclose(
            np.asarray(getattr(s2, f)), np.asarray(getattr(s, f)), rtol=1e-6)


@given(st.lists(st.floats(0.0, 8.0), min_size=3, max_size=40))
@settings(max_examples=40, deadline=None)
def test_running_stats_match_batch_means(hs):
    """Eq. 13 incremental means == numpy batch means over the same series."""
    s = incom.InfoState.init(1)
    ls = []
    for i, h in enumerate(hs):
        l_new = float(s.L[0]) + 1.0
        s = incom.stats_step(s, jnp.array([h], jnp.float32),
                             jnp.array([l_new], jnp.float32))
        ls.append(l_new)
    series_h = np.array([0.0] + list(hs))
    series_l = np.array([1.0] + ls)
    np.testing.assert_allclose(float(s.EH[0]), series_h.mean(), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(float(s.EL[0]), series_l.mean(), rtol=2e-4)
    np.testing.assert_allclose(float(s.EHL[0]), (series_h * series_l).mean(),
                               rtol=2e-3, atol=1e-4)
