"""graph/io.py coverage: weighted edge lists, comments/blank lines, npz,
and save -> load -> save round-trips on a delta-compacted graph."""

import numpy as np
import pytest

from repro.graph.csr import build_csr
from repro.graph.delta import DeltaCSR, EdgeBatch
from repro.graph.io import load_edge_list, save_edge_list


def _arrays(g):
    gn = g.to_numpy()
    return (np.asarray(gn.indptr), np.asarray(gn.indices),
            None if gn.weights is None else np.asarray(gn.weights))


def test_text_comments_and_blank_lines(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text(
        "# a comment line\n"
        "\n"
        "0 1\n"
        "   \n"
        "1 2\n"
        "# trailing comment\n"
        "2 3\n")
    g = load_edge_list(str(p))
    assert g.num_nodes == 4
    assert g.num_edges == 6            # 3 undirected edges, both arcs
    np.testing.assert_array_equal(g.neighbors(1), [0, 2])


def test_weighted_text_round_trip(tmp_path):
    edges = np.array([[0, 1], [1, 2], [0, 3], [2, 3]])
    w = np.array([0.5, 2.0, 1.25, 4.0], np.float32)
    g = build_csr(edges, 4, weights=w)
    p = tmp_path / "w.txt"
    save_edge_list(g, str(p))
    g2 = load_edge_list(str(p))
    i1, x1, w1 = _arrays(g)
    i2, x2, w2 = _arrays(g2)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(x1, x2)
    assert w1 is not None and w2 is not None
    np.testing.assert_allclose(w1, w2)


def test_weighted_text_parse(tmp_path):
    p = tmp_path / "w.txt"
    p.write_text("0 1 2.5\n1 2 0.75\n")
    g = load_edge_list(str(p))
    assert g.weights is not None
    lo = int(np.asarray(g.indptr)[0])
    assert float(np.asarray(g.weights)[lo]) == 2.5


def test_npz_round_trip(tmp_path):
    edges = np.array([[0, 1], [1, 2], [3, 0]])
    g = build_csr(edges, 5)                      # isolated node 4
    p = tmp_path / "g.npz"
    save_edge_list(g, str(p))
    g2 = load_edge_list(str(p))
    assert g2.num_nodes == 5
    i1, x1, _ = _arrays(g)
    i2, x2, _ = _arrays(g2)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(x1, x2)


@pytest.mark.parametrize("fmt", ["txt", "npz"])
def test_delta_compacted_save_load_save_round_trip(tmp_path, fmt):
    """A graph mutated through the delta overlay and compacted back into
    CSR must survive save -> load -> save with identical bytes."""
    rng = np.random.default_rng(3)
    edges = rng.integers(0, 40, (120, 2))
    g = build_csr(edges, 40)
    d = DeltaCSR(g, compact_threshold=0)
    und_src = np.repeat(np.arange(40), np.diff(np.asarray(
        g.to_numpy().indptr)))
    arcs = np.stack([und_src, np.asarray(g.to_numpy().indices)], 1)
    und = arcs[arcs[:, 0] < arcs[:, 1]]
    d.apply_batch(EdgeBatch(
        insert=np.array([[0, 39], [5, 31], [7, 11]]),
        delete=und[:4]))
    compacted = d.compact()

    p1 = tmp_path / f"a.{fmt}"
    p2 = tmp_path / f"b.{fmt}"
    save_edge_list(compacted, str(p1))
    loaded = load_edge_list(str(p1), num_nodes=compacted.num_nodes)
    i1, x1, _ = _arrays(compacted)
    i2, x2, _ = _arrays(loaded)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(x1, x2)
    save_edge_list(loaded, str(p2))
    if fmt == "txt":
        assert p1.read_text() == p2.read_text()
    else:
        a, b = np.load(str(p1)), np.load(str(p2))
        np.testing.assert_array_equal(a["edges"], b["edges"])
        assert int(a["num_nodes"]) == int(b["num_nodes"])
