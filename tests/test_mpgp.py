"""MPGP streaming partitioner (paper §3.2): invariants + quality claims."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.mpgp import (
    balanced_only_partition, hash_partition, mpgp_partition,
    mpgp_partition_parallel, stream_order,
)


def _locality(graph, assignment):
    """Fraction of edges whose endpoints share a partition."""
    src = np.repeat(np.arange(graph.num_nodes),
                    np.diff(np.asarray(graph.indptr)))
    dst = np.asarray(graph.indices)
    return float(np.mean(assignment[src] == assignment[dst]))


@pytest.mark.parametrize("m", [2, 4])
def test_all_nodes_assigned_and_balanced(small_graph, m):
    res = mpgp_partition(small_graph, m, gamma=2.0)
    a = res.assignment
    assert a.shape == (small_graph.num_nodes,)
    assert a.min() >= 0 and a.max() < m
    counts = res.counts()
    # dynamic balance term keeps partitions within the gamma slack
    assert counts.max() <= 2.0 * small_graph.num_nodes / m + 1


def test_mpgp_beats_balanced_only_on_locality(medium_graph):
    """The paper's central partitioning claim (Fig. 10c): proximity-aware
    placement keeps more random-walk transitions local than load-balancing
    alone."""
    m = 4
    mp = mpgp_partition(medium_graph, m, gamma=2.0)
    bal = balanced_only_partition(medium_graph, m)
    hsh = hash_partition(medium_graph, m)
    loc_mpgp = _locality(medium_graph, mp.assignment)
    loc_bal = _locality(medium_graph, bal.assignment)
    loc_hash = _locality(medium_graph, hsh.assignment)
    assert loc_mpgp > loc_bal
    assert loc_mpgp > loc_hash


@pytest.mark.parametrize("order", ["random", "natural", "bfs", "dfs",
                                   "bfs+degree", "dfs+degree"])
def test_stream_orders_cover_all_nodes(small_graph, order):
    o = stream_order(small_graph, order, seed=0)
    assert sorted(o.tolist()) == list(range(small_graph.num_nodes))


def test_parallel_mpgp_consistent(medium_graph):
    res = mpgp_partition_parallel(medium_graph, 4, num_segments=4, gamma=2.0)
    a = res.assignment
    assert a.shape == (medium_graph.num_nodes,)
    assert set(np.unique(a)) <= set(range(4))
    # parallel variant must stay within a reasonable locality band of seq
    seq = mpgp_partition(medium_graph, 4, gamma=2.0)
    assert _locality(medium_graph, a) > 0.5 * _locality(
        medium_graph, seq.assignment)


@given(st.integers(2, 6))
@settings(max_examples=6, deadline=None)
def test_partition_counts_sum_to_nodes(m):
    from repro.graph.generators import rmat_graph
    g = rmat_graph(128, 6, seed=m)
    res = mpgp_partition(g, m, gamma=2.0)
    assert int(res.counts().sum()) == g.num_nodes


def test_degree_tau_balances_degree_mass(medium_graph):
    """Eq. 15 with tau_weight='degree' and a tight gamma must spread the
    DEGREE mass (the quantity walker occupancy follows) across all
    shards, where the node-count tau lets a couple of shards absorb the
    whole rich club (the BENCH_walk 384/512 walker pile-up)."""
    import numpy as np
    from repro.core.mpgp import mpgp_partition

    deg = np.asarray(medium_graph.degrees(), dtype=np.int64)
    nodes = mpgp_partition(medium_graph, 4, gamma=2.0)
    degree = mpgp_partition(medium_graph, 4, gamma=1.15,
                            tau_weight="degree")
    dm_nodes = np.bincount(nodes.assignment, weights=deg, minlength=4)
    dm_degree = np.bincount(degree.assignment, weights=deg, minlength=4)
    # skew = max shard degree mass / mean
    skew_nodes = dm_nodes.max() / max(dm_nodes.mean(), 1)
    skew_degree = dm_degree.max() / max(dm_degree.mean(), 1)
    assert skew_degree < skew_nodes
    assert skew_degree < 1.3              # the gamma*B/k bound can bind
    # still a full valid partition
    assert (degree.assignment >= 0).all()
    assert degree.counts().sum() == medium_graph.num_nodes


def test_degree_tau_parallel_variant(small_graph):
    from repro.core.mpgp import mpgp_partition_parallel

    res = mpgp_partition_parallel(small_graph, 3, gamma=1.2,
                                  tau_weight="degree")
    assert (res.assignment >= 0).all()
    assert res.counts().sum() == small_graph.num_nodes


def test_unknown_tau_weight_rejected(small_graph):
    import pytest
    from repro.core.mpgp import mpgp_partition

    with pytest.raises(ValueError):
        mpgp_partition(small_graph, 2, tau_weight="edges")
