"""Optimizers, schedules, compression policies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.optim.compression import HotnessSync, TopKErrorFeedback
from repro.optim.optimizers import (
    AdamWConfig, SGDConfig, clip_by_global_norm, global_norm,
    init_opt_state, opt_update,
)
from repro.optim.schedules import (
    constant, cosine_warmup, linear_warmup, word2vec_linear,
)


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |step 1| == lr per coordinate (up to eps)."""
    params = {"w": jnp.ones((4,))}
    cfg = AdamWConfig(weight_decay=0.0, grad_clip=0.0)
    state = init_opt_state(params, cfg)
    grads = {"w": jnp.full((4,), 0.5)}
    new_p, state, gn = opt_update(grads, state, params, cfg,
                                  jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(params["w"] - new_p["w"]),
                               0.1 * np.ones(4), rtol=1e-4)
    assert int(state["count"]) == 1


def test_adamw_bf16_moments_roundtrip():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    cfg = AdamWConfig(moment_dtype="bfloat16")
    state = init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full((8,), 0.1, jnp.bfloat16)}
    new_p, state, _ = opt_update(grads, state, params, cfg, jnp.float32(0.01))
    assert new_p["w"].dtype == jnp.bfloat16
    assert state["v"]["w"].dtype == jnp.bfloat16


def test_sgd_momentum_accumulates():
    params = {"w": jnp.zeros((2,))}
    cfg = SGDConfig(momentum=0.9, grad_clip=0.0)
    state = init_opt_state(params, cfg)
    g = {"w": jnp.ones((2,))}
    p1, state, _ = opt_update(g, state, params, cfg, jnp.float32(1.0))
    p2, state, _ = opt_update(g, state, p1, cfg, jnp.float32(1.0))
    # second step = 1 + 0.9 -> total 2.9
    np.testing.assert_allclose(np.asarray(-p2["w"]), [2.9, 2.9], rtol=1e-5)


def test_global_norm_clip():
    grads = {"a": jnp.full((3,), 4.0), "b": jnp.zeros((2,))}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(48.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@given(st.integers(0, 5000))
@settings(max_examples=30, deadline=None)
def test_schedules_bounded(step):
    for sched in (constant(0.1), linear_warmup(0.1, 100, 5000),
                  cosine_warmup(0.1, 100, 5000),
                  word2vec_linear(0.025, 1e-4, 5000)):
        v = float(sched(jnp.int32(step)))
        assert 0.0 <= v <= 0.1 + 1e-6


def test_hotness_sync_blocks_from_counts():
    counts = np.array([9, 9, 5, 5, 5, 2, 1, 1, 1, 1])
    hs = HotnessSync.from_counts(counts, period=2)
    assert len(hs.block_starts) == 4          # distinct counts: 9,5,2,1
    rows = hs.sample_rows(np.random.default_rng(0))
    assert len(rows) == 4
    for r, (s, e) in zip(rows, zip(hs.block_starts, hs.block_ends)):
        assert s <= r < e
    assert hs.bytes_per_period(16, 4) < hs.full_bytes(10, 16, 4)
    assert not hs.due() and hs.due()           # period = 2


def test_topk_error_feedback_preserves_mass():
    """Sparsified + residual == original (error feedback loses nothing)."""
    t = TopKErrorFeedback(k_frac=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16,))
                          .astype(np.float32))}
    sparse, resid = t.compress(g)
    np.testing.assert_allclose(
        np.asarray(sparse["w"], np.float32) + np.asarray(resid["w"]),
        np.asarray(g["w"]), rtol=1e-6)
    nz = int((np.asarray(sparse["w"]) != 0).sum())
    assert nz == 4
