"""Partition-sharded BSP walk engine + streaming corpus ring (ISSUE 2):
shard-count invariance, measured hand-off traffic, ring/stream pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import incom
from repro.core.corpus import CorpusRing, count_occurrences, ring_append, ring_to_numpy
from repro.core.mpgp import mpgp_partition
from repro.core.shard_engine import make_walk_mesh, run_walk_sharded
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, run_walk_batch, walks_to_numpy


def _sharded(graph, spec, part, k, n=96, seed=11, policy="huge"):
    graph = graph.with_edge_cm()
    sources = jnp.arange(n, dtype=jnp.int32) % graph.num_nodes
    return run_walk_sharded(graph, sources, jax.random.PRNGKey(seed),
                            make_policy(policy), spec,
                            jnp.asarray(part, jnp.int32), k)


def test_shard_count_invariance_bit_identical(medium_graph):
    """Same seed => bit-identical walks (paths, lengths, every InCoM
    moment) at 1 vs 2 vs 4 shards — the walk is a property of the graph
    and the RNG, never of the layout."""
    spec = WalkSpec(max_len=40, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    part4 = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    st1 = _sharded(medium_graph, spec, np.zeros(medium_graph.num_nodes), 1)
    st2 = _sharded(medium_graph, spec, part4 % 2, 2)
    st4 = _sharded(medium_graph, spec, part4, 4)
    for other in (st2, st4):
        np.testing.assert_array_equal(np.asarray(st1.path),
                                      np.asarray(other.path))
        for f in ("H", "L", "EH", "EL", "EHL", "EH2", "EL2"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st1.info, f)),
                np.asarray(getattr(other.info, f)), err_msg=f)
    assert int(st1.msg_count) == 0
    assert int(st4.msg_count) > 0


def test_dense_engine_matches_sharded(medium_graph):
    """run_walk_batch without a partition (dense single-shard program)
    walks the identical chain as the k-shard BSP engine."""
    spec = WalkSpec(max_len=32, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    g = medium_graph.with_edge_cm()
    sources = jnp.arange(96, dtype=jnp.int32)
    key = jax.random.PRNGKey(3)
    st_dense = run_walk_batch(g, sources, key, make_policy("huge"), spec)
    st_shard = run_walk_batch(g, sources, key, make_policy("huge"), spec,
                              jnp.asarray(part))
    p1, l1 = walks_to_numpy(st_dense)
    p2, l2 = walks_to_numpy(st_shard)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(p1, p2)
    assert int(st_dense.accepts) == int(st_shard.accepts)
    assert int(st_dense.rejects) == int(st_shard.rejects)


def test_measured_handoff_bytes_incom(medium_graph):
    """Every measured InCoM hand-off is exactly the Example-1 80-byte
    message, and the measured total equals the analytic closed form."""
    spec = WalkSpec(max_len=40, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    st = _sharded(medium_graph, spec, part, 4, n=128)
    count = int(st.msg_count)
    assert count > 0
    assert float(st.msg_bytes) == pytest.approx(incom.MSG_BYTES * count)
    assert float(st.msg_bytes) == pytest.approx(float(st.msg_bytes_analytic))


def test_measured_handoff_bytes_fullpath(medium_graph):
    """Full-path hand-offs measure 24 + 8L from the routed path payload
    and match the analytic per-crossing sum exactly."""
    spec = WalkSpec(max_len=32, min_len=8, mu=-1.0, info_mode="fullpath",
                    reg_start=16)
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    st = _sharded(medium_graph, spec, part, 4, n=96)
    count = int(st.msg_count)
    assert count > 0
    meas, analytic = float(st.msg_bytes), float(st.msg_bytes_analytic)
    assert meas == pytest.approx(analytic)
    per = meas / count
    # every message is 24 + 8L for some 2 <= L <= max_len
    assert 24 + 8 * 2 <= per <= 24 + 8 * spec.max_len
    assert (meas - 24.0 * count) % 8.0 == pytest.approx(0.0)


def test_windowed_message_carries_ring(medium_graph):
    """reg_window mode ships the K-entry H ring: 80 + 8K bytes/message."""
    k_win = 6
    spec = WalkSpec(max_len=32, min_len=8, mu=0.995, info_mode="incom",
                    reg_window=k_win)
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    st = _sharded(medium_graph, spec, part, 4, n=96)
    count = int(st.msg_count)
    assert count > 0
    assert float(st.msg_bytes) == pytest.approx(
        (incom.MSG_BYTES + 8 * k_win) * count)


def test_spmd_shard_map_matches_stacked(medium_graph):
    """The shard_map execution (real per-device collectives) is
    bit-identical to the stacked vmap emulation."""
    mesh = make_walk_mesh(4)
    if mesh is None:
        pytest.skip("needs >= 4 devices (e.g. "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    spec = WalkSpec(max_len=32, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    part = mpgp_partition(medium_graph, 4, gamma=2.0).assignment
    g = medium_graph.with_edge_cm()
    sources = jnp.arange(64, dtype=jnp.int32)
    key = jax.random.PRNGKey(7)
    st_v = run_walk_sharded(g, sources, key, make_policy("huge"), spec,
                            jnp.asarray(part, jnp.int32), 4)
    st_m = run_walk_sharded(g, sources, key, make_policy("huge"), spec,
                            jnp.asarray(part, jnp.int32), 4, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(st_v.path), np.asarray(st_m.path))
    np.testing.assert_array_equal(np.asarray(st_v.info.L),
                                  np.asarray(st_m.info.L))
    assert int(st_v.msg_count) == int(st_m.msg_count)
    assert float(st_v.msg_bytes) == float(st_m.msg_bytes)


def test_corpus_ring_append_and_ocn(small_graph):
    """Ring slots, lengths and the fused ocn scatter-add match the host
    reference; wrap-around retires the oldest slots."""
    n = small_graph.num_nodes
    ring = CorpusRing.create(capacity=8, max_len=5, num_nodes=n)
    paths1 = jnp.asarray([[1, 2, 1, -1, -1], [3, 4, -1, -1, -1]], jnp.int32)
    lens1 = jnp.asarray([3, 2], jnp.int32)
    ring = ring_append(ring, paths1, lens1)
    walks, lengths = ring_to_numpy(ring)
    np.testing.assert_array_equal(walks, np.asarray(paths1))
    np.testing.assert_array_equal(lengths, [3, 2])
    ref = count_occurrences(np.asarray(paths1), np.asarray(lens1, np.int64), n)
    np.testing.assert_array_equal(np.asarray(ring.ocn), ref)
    # wrap: append 8 more rows into capacity-8 ring => first batch retired
    big = jnp.tile(jnp.asarray([[5, 6, -1, -1, -1]], jnp.int32), (8, 1))
    ring = ring_append(ring, big, jnp.full((8,), 2, jnp.int32))
    walks, lengths = ring_to_numpy(ring)
    assert walks.shape[0] == 8
    assert (walks[:, 0] == 5).all()
    assert int(ring.total) == 10


def test_generate_corpus_shim_matches_ring_and_controller(small_graph):
    """The compatibility shim still honors the Eq. 7 controller and its
    occurrence counts equal a host recount of the returned walks."""
    from repro.core.corpus import generate_corpus
    corpus = generate_corpus(
        small_graph, policy="deepwalk",
        spec=WalkSpec(max_len=16, min_len=6, reg_start=16),
        delta=1e-2, min_rounds=2, max_rounds=5, seed=4)
    assert 2 <= corpus.rounds <= 5
    assert len(corpus.stats["d_history"]) == corpus.rounds
    assert corpus.num_walks == corpus.rounds * small_graph.num_nodes
    ref = count_occurrences(corpus.walks, corpus.lengths,
                            small_graph.num_nodes)
    np.testing.assert_array_equal(corpus.ocn, ref)


def test_generate_corpus_host_spill_matches_ring(small_graph):
    """When full retention would overflow the device ring budget, the shim
    spills rounds to host and produces the identical corpus."""
    from repro.core.corpus import generate_corpus
    kw = dict(policy="deepwalk",
              spec=WalkSpec(max_len=16, min_len=6, reg_start=16),
              delta=1e-2, min_rounds=2, seed=4)
    dev = generate_corpus(small_graph, max_rounds=5, **kw)
    # max_rounds large enough that capacity * max_len >= 2**31 forces the
    # host path; the controller still stops at the same Delta-D round.
    host = generate_corpus(small_graph, max_rounds=2_000_000, **kw)
    assert host.rounds == dev.rounds
    np.testing.assert_array_equal(host.walks, dev.walks)
    np.testing.assert_array_equal(host.ocn, dev.ocn)


def test_streaming_pipeline_walks_are_edges_and_phi_finite(small_graph):
    """End-to-end streamed walk→train: ring walks are real graph walks and
    the node-space embeddings come back finite."""
    from repro.core.api import EmbedConfig, make_walk_plan
    from repro.core.dsgl import DSGLConfig
    from repro.runtime.trainer import StreamingEmbedPipeline

    cfg = EmbedConfig(dim=8, epochs=1, max_len=16, min_len=6)
    policy, spec, rounds = make_walk_plan(cfg)
    rounds["max_rounds"] = 3
    # round-robin partition: MPGP on this graph reaches locality 1.0
    # (zero crossings), which would make the hand-off assertion vacuous
    part = np.arange(small_graph.num_nodes, dtype=np.int32) % 2
    pipe = StreamingEmbedPipeline(
        small_graph, policy, spec, rounds,
        DSGLConfig(dim=8, window=4, negatives=3, seed=0),
        num_shards=2, assignment=part)
    out = pipe.run()
    phi = np.asarray(out["phi_in"])
    assert phi.shape == (small_graph.num_nodes, 8)
    assert np.isfinite(phi).all()
    assert out["steps"] == pipe.total_steps          # schedule completed
    assert out["stats"]["msg_count"] > 0             # real hand-offs happened

    corpus = pipe.corpus()
    indptr = np.asarray(small_graph.indptr)
    indices = np.asarray(small_graph.indices)
    for row, ln in zip(corpus.walks[:64], corpus.lengths[:64]):
        for a, b in zip(row[: ln - 1], row[1:ln]):
            assert b in indices[indptr[a]: indptr[a + 1]], (a, b)


def test_ring_chunk_indices_cover_pool():
    from repro.data.pipeline import ring_chunk_indices
    idx = ring_chunk_indices(jax.random.PRNGKey(0), base=10, pool=64,
                             count=2, shards=2, groups=4, windows=2)
    assert idx.shape == (2, 2, 4, 2)
    flat = np.asarray(idx).reshape(-1)
    assert flat.min() >= 10 and flat.max() < 74
    assert len(np.unique(flat)) == flat.size        # without replacement
    # tiny pool: tiling keeps shapes legal
    idx2 = ring_chunk_indices(jax.random.PRNGKey(1), base=0, pool=4,
                              count=2, shards=1, groups=4, windows=2)
    assert idx2.shape == (2, 1, 4, 2)
    assert np.asarray(idx2).max() < 4
