"""Device-resident DSGL hot path (no hypothesis dependency — this file
covers the training pipeline even where dev deps are absent):

* Pallas kernel vs ref.py parity across (window, W, K, T) shapes,
* alias-table sampler vs CDF-searchsorted distribution equivalence
  (chi-square tolerance),
* allocation-free write-back vs the dense scatter-mean oracle on
  duplicate-heavy batches,
* train_chunk (fused scan + stacked replicas + in-jit negatives + fused
  hotness sync) vs the per-step single-replica path,
* the end-to-end trainer still learns.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sync as sync_mod
from repro.core.corpus import FrequencyOrder
from repro.core.dsgl import (
    DSGLConfig, build_alias_table, init_embeddings, lifetime_step,
    negative_table, sample_alias, sample_negatives, train_chunk, train_dsgl,
)
from repro.kernels.sgns import ops as sg_ops, ref as sg_ref


# ---------------------------------------------------------------------------
# Pallas kernel vs pure-jnp oracle across shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,w_cnt,k_neg,t_len", [
    (1, 1, 1, 6),
    (2, 2, 3, 9),
    (3, 2, 5, 17),
    (4, 4, 2, 12),
    (5, 3, 4, 21),
])
def test_sgns_kernel_matches_ref_shapes(window, w_cnt, k_neg, t_len):
    dim, g_cnt = 16, 2
    key = jax.random.PRNGKey(window * 100 + t_len)
    ks = jax.random.split(key, 4)
    ctx = jax.random.normal(ks[0], (g_cnt, w_cnt, t_len, dim)) * 0.1
    out = jax.random.normal(ks[1], (g_cnt, w_cnt, t_len, dim)) * 0.1
    neg = jax.random.normal(ks[2], (g_cnt, t_len, k_neg, dim)) * 0.1
    # ragged validity: walk w of group g ends at a different position
    lens = jax.random.randint(ks[3], (g_cnt, w_cnt), t_len // 2, t_len + 1)
    valid = jnp.arange(t_len)[None, None, :] < lens[:, :, None]
    lr = jnp.float32(0.04)
    want = sg_ref.sgns_lifetime_batch_ref(ctx, out, neg, valid, lr, window)
    got = sg_ops.sgns_lifetime_batch(ctx, out, neg, valid, lr, window)
    for w, g in zip(want[:3], got[:3]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# Alias table vs CDF searchsorted
# ---------------------------------------------------------------------------

def test_alias_table_matches_cdf_distribution():
    """Chi-square: on-device Vose draws and host searchsorted draws must
    come from the same unigram^0.75 distribution."""
    rng = np.random.default_rng(0)
    ocn = np.sort(rng.zipf(1.8, 64))[::-1].astype(np.int64)
    cdf = negative_table(ocn, 0.75)
    table = build_alias_table(ocn, 0.75)
    n, draws = len(ocn), 200_000

    got = np.asarray(sample_alias(table, jax.random.PRNGKey(1), (draws,)))
    assert got.dtype == np.int32 and got.min() >= 0 and got.max() < n

    w = ocn.astype(np.float64) ** 0.75
    p = w / w.sum()
    counts = np.bincount(got, minlength=n)
    expected = p * draws
    chi2 = float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
    # dof = n - 1 = 63; mean 63, std ~11 — 63 + 5*sigma is a generous but
    # real bound (a wrong table overshoots by orders of magnitude).
    assert chi2 < 63 + 5 * np.sqrt(2 * 63), chi2

    # and the host CDF draws pass the same test against the same expectation
    host = sample_negatives(cdf, (draws,), np.random.default_rng(2))
    hc = np.bincount(host, minlength=n)
    chi2_host = float(np.sum((hc - expected) ** 2 / np.maximum(expected, 1e-9)))
    assert chi2_host < 63 + 5 * np.sqrt(2 * 63), chi2_host


def test_alias_table_probability_mass_exact():
    """The alias table must encode the distribution EXACTLY: summing slot
    masses recovers unigram^power up to float tolerance."""
    ocn = np.array([1000, 400, 50, 50, 3, 1], np.int64)
    t = build_alias_table(ocn, 0.75)
    prob = np.asarray(t.prob, np.float64)
    alias = np.asarray(t.alias)
    n = len(ocn)
    mass = prob / n
    for i in range(n):
        mass[alias[i]] += (1.0 - prob[i]) / n
    w = ocn.astype(np.float64) ** 0.75
    np.testing.assert_allclose(mass, w / w.sum(), atol=1e-6)


# ---------------------------------------------------------------------------
# Write-back: allocation-free scatter-average vs dense scatter-mean oracle
# ---------------------------------------------------------------------------

def _dense_scatter_mean(base, ids, deltas, mask):
    """The seed implementation: two dense (N, d) temporaries per call."""
    n_rows = base.shape[0]
    ones = jnp.where(mask, 1.0, 0.0)
    cnt = jnp.zeros((n_rows,), jnp.float32).at[ids].add(ones)
    summed = jnp.zeros_like(base).at[ids].add(
        jnp.where(mask[:, None], deltas, 0.0))
    return base + summed / jnp.maximum(cnt, 1.0)[:, None]


def test_writeback_matches_dense_scatter_mean_on_duplicates():
    from repro.core.dsgl import _scatter_average
    rng = np.random.default_rng(3)
    n, d, rows = 32, 8, 4096
    base = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    # duplicate-heavy: power-law ids so hub rows appear hundreds of times
    ids = jnp.asarray(np.minimum(rng.zipf(1.5, rows) - 1, n - 1), jnp.int32)
    deltas = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    mask = jnp.asarray(rng.random(rows) < 0.9)

    got = _scatter_average(base, ids, deltas, mask)
    want = _dense_scatter_mean(base, ids, deltas, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # untouched rows must be BITWISE untouched (no dense add over N)
    touched = np.unique(np.asarray(ids)[np.asarray(mask)])
    untouched = np.setdiff1d(np.arange(n), touched)
    np.testing.assert_array_equal(np.asarray(got)[untouched],
                                  np.asarray(base)[untouched])


def test_lifetime_step_moves_only_touched_rows():
    n, d, k_neg, g, w_cnt, t_len = 64, 8, 3, 2, 2, 12
    phi_in, phi_out = init_embeddings(n, d, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    walks = rng.integers(0, n // 2, size=(g, w_cnt, t_len)).astype(np.int32)
    negs = rng.integers(n // 2, n, size=(g, t_len, k_neg)).astype(np.int32)
    before = np.asarray(phi_in).copy()
    pin, pout, loss = lifetime_step(
        phi_in.copy(), phi_out.copy(), jnp.asarray(walks), jnp.asarray(negs),
        jnp.float32(0.05), 2)
    untouched = np.setdiff1d(np.arange(n), np.unique(walks))
    np.testing.assert_array_equal(np.asarray(pin)[untouched],
                                  before[untouched])
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# Fused chunk vs per-step path; stacked replicas; fused hotness sync
# ---------------------------------------------------------------------------

def test_train_chunk_matches_per_step_path():
    """One scan chunk with in-jit negatives must reproduce the per-step
    lifetime_step sequence bit-for-bit given the same negative draws."""
    n, d, g, w_cnt, t_len, k_neg, window = 48, 8, 3, 2, 10, 3, 2
    rng = np.random.default_rng(0)
    walks = rng.integers(0, n, size=(4, 1, g, w_cnt, t_len)).astype(np.int32)
    walks[0, 0, 0, 0, -3:] = -1                    # ragged padding
    table = build_alias_table(np.arange(n, 0, -1), 0.75)
    lrs = jnp.linspace(0.05, 0.01, 4, dtype=jnp.float32)
    key = jax.random.PRNGKey(7)
    phi_in, phi_out = init_embeddings(n, d, jax.random.PRNGKey(1))

    got_in, got_out, losses = train_chunk(
        phi_in[None].copy(), phi_out[None].copy(), jnp.asarray(walks),
        table, jnp.zeros(0, jnp.int32), key, lrs, window, k_neg)
    assert losses.shape == (4, 1)

    # replay: identical key schedule -> identical negatives -> same result
    pi, po = phi_in.copy(), phi_out.copy()
    k = key
    for c in range(4):
        k, sub = jax.random.split(k)
        negs = sample_alias(table, sub, (1, g, t_len, k_neg))[0]
        pi, po, _ = lifetime_step(pi, po, jnp.asarray(walks[c, 0]), negs,
                                  lrs[c], window)
    np.testing.assert_allclose(np.asarray(got_in[0]), np.asarray(pi),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_out[0]), np.asarray(po),
                               atol=1e-6, rtol=1e-6)


def test_train_chunk_stacked_replicas_match_independent_runs():
    """S replicas trained in one stacked chunk == each trained alone (until
    a sync mixes them)."""
    n, d, g, w_cnt, t_len, k_neg, window, s_cnt = 40, 4, 2, 2, 8, 2, 2, 3
    rng = np.random.default_rng(5)
    walks = rng.integers(0, n, size=(3, s_cnt, g, w_cnt, t_len)).astype(np.int32)
    table = build_alias_table(np.arange(n, 0, -1) ** 2, 0.75)
    lrs = jnp.full((3,), 0.03, jnp.float32)
    key = jax.random.PRNGKey(11)
    stacks = [init_embeddings(n, d, jax.random.PRNGKey(s + 20))
              for s in range(s_cnt)]
    phi_in = jnp.stack([s[0] for s in stacks])
    phi_out = jnp.stack([s[1] for s in stacks])

    got_in, got_out, _ = train_chunk(
        phi_in.copy(), phi_out.copy(), jnp.asarray(walks), table,
        jnp.zeros(0, jnp.int32), key, lrs, window, k_neg)

    for s in range(s_cnt):
        pi, po = stacks[s][0].copy(), stacks[s][1].copy()
        k = key
        for c in range(3):
            k, sub = jax.random.split(k)
            negs = sample_alias(table, sub, (s_cnt, g, t_len, k_neg))[s]
            pi, po, _ = lifetime_step(pi, po, jnp.asarray(walks[c, s]), negs,
                                      lrs[c], window)
        np.testing.assert_allclose(np.asarray(got_in[s]), np.asarray(pi),
                                   atol=1e-5, rtol=1e-5)


def test_train_chunk_sync_averages_rows_across_replicas():
    n, d, s_cnt = 16, 4, 3
    rng = np.random.default_rng(2)
    phi_in = jnp.asarray(rng.normal(size=(s_cnt, n, d)), jnp.float32)
    phi_out = jnp.asarray(rng.normal(size=(s_cnt, n, d)), jnp.float32)
    rows = jnp.asarray([0, 3, 9], jnp.int32)
    pi, po = sync_mod.hotness_sync_stacked(phi_in, phi_out, rows)
    want = np.mean(np.asarray(phi_in)[:, [0, 3, 9]], axis=0)
    for s in range(s_cnt):
        np.testing.assert_allclose(np.asarray(pi)[s, [0, 3, 9]], want,
                                   atol=1e-6)
    # non-sampled rows untouched
    np.testing.assert_array_equal(np.asarray(pi)[:, 1], np.asarray(phi_in)[:, 1])
    np.testing.assert_array_equal(np.asarray(po)[:, 1], np.asarray(phi_out)[:, 1])


# ---------------------------------------------------------------------------
# End-to-end: the reworked trainer still learns, sharded regime converges
# ---------------------------------------------------------------------------

def test_training_reduces_loss_device_resident(small_graph):
    from repro.core.api import EmbedConfig, sample_corpus
    corpus = sample_corpus(small_graph,
                           EmbedConfig(dim=16, max_len=30, min_len=8))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    cfg = DSGLConfig(dim=16, window=4, negatives=3, epochs=2,
                     batch_groups=16)
    phi_in, phi_out, metrics = train_dsgl(corpus, order, cfg,
                                          collect_metrics=True)
    losses = metrics["loss"]
    assert len(losses) >= 2
    first = np.mean(losses[: max(len(losses) // 4, 1)])
    last = np.mean(losses[-max(len(losses) // 4, 1):])
    assert last < first
    assert not np.isnan(np.asarray(phi_in)).any()


def test_dsgl_trainer_runtime(small_graph):
    """The prefetched runtime driver: chunks stream through train_chunk,
    embeddings come out replica-averaged and finite, throughput is
    reported."""
    from repro.core.api import EmbedConfig, sample_corpus
    from repro.runtime.trainer import DSGLTrainer
    corpus = sample_corpus(small_graph,
                           EmbedConfig(dim=8, max_len=20, min_len=6))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    walks_rank = order.relabel_walks(corpus.walks)
    cfg = DSGLConfig(dim=8, window=3, negatives=2, epochs=1,
                     batch_groups=8, sync_period=3)
    trainer = DSGLTrainer(walks_rank, order, cfg, num_shards=2)
    out = trainer.run()
    assert out["steps"] >= trainer.steps_per_epoch()
    assert out["steps_per_s"] > 0
    assert out["sync_bytes"] > 0
    phi_in, phi_out = trainer.embeddings()
    assert phi_in.shape == (len(order.to_rank), 8)
    assert np.isfinite(np.asarray(phi_in)).all()
    assert np.isfinite(np.asarray(out["loss"])).all()


def test_sharded_training_runs_and_syncs(small_graph):
    from repro.core.api import EmbedConfig, sample_corpus
    corpus = sample_corpus(small_graph,
                           EmbedConfig(dim=8, max_len=20, min_len=6))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    cfg = DSGLConfig(dim=8, window=3, negatives=2, epochs=1,
                     batch_groups=8, sync_period=2)
    phi_in, phi_out, metrics = train_dsgl(
        corpus, order, cfg, num_shards=2, collect_metrics=True)
    assert phi_in.shape == (len(order.to_rank), 8)
    assert metrics["sync_bytes"] > 0
    assert not np.isnan(np.asarray(phi_in)).any()
