"""Dynamic-graph subsystem tests: delta-CSR overlay, affected-vertex
detection from the corpus, vertex-keyed subset re-walks, cache
invalidation on mutation, and the end-to-end incremental refresh
acceptance criteria (<=30% re-walk, AUC within 0.02 of scratch,
bit-identical unaffected walks)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import EmbedConfig, embed_graph, refresh_embedding
from repro.core.incremental import affected_roots, changed_arc_codes
from repro.core.termination import WalkCountController
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, run_walk_batch
from repro.graph.csr import build_csr, edge_common_neighbors_fast
from repro.graph.delta import DeltaCSR, EdgeBatch, bump_graph_version, \
    graph_version
from repro.graph.generators import churn_batch, rmat_graph, undirected_edges


def _und(graph):
    return undirected_edges(graph)


# ---------------------------------------------------------------------------
# Delta overlay
# ---------------------------------------------------------------------------


class TestDeltaOverlay:
    def _base(self, n=48, m=160, seed=0):
        rng = np.random.default_rng(seed)
        edges = rng.integers(0, n, (m, 2))
        return build_csr(edges, n)

    def test_merge_equals_rebuild(self):
        g = self._base()
        und = _und(g)
        rng = np.random.default_rng(1)
        dele = und[rng.choice(len(und), 8, replace=False)]
        ins = np.stack([rng.integers(0, 48, 12), rng.integers(0, 48, 12)], 1)
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=ins, delete=dele))
        merged = d.graph().to_numpy()

        codes = und[:, 0] * 48 + und[:, 1]
        keep = ~np.isin(codes, dele[:, 0] * 48 + dele[:, 1])
        ins_f = ins[ins[:, 0] != ins[:, 1]]
        ref = build_csr(np.concatenate([und[keep], np.sort(ins_f, 1)]),
                        48).to_numpy()
        np.testing.assert_array_equal(np.asarray(merged.indptr),
                                      np.asarray(ref.indptr))
        np.testing.assert_array_equal(np.asarray(merged.indices),
                                      np.asarray(ref.indices))

    def test_rows_stay_sorted(self):
        g = self._base()
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=np.array([[0, 47], [0, 1], [3, 40]])))
        m = d.graph().to_numpy()
        indptr = np.asarray(m.indptr)
        indices = np.asarray(m.indices)
        for u in range(len(indptr) - 1):
            row = indices[indptr[u]:indptr[u + 1]]
            assert (np.diff(row) > 0).all(), f"row {u} not sorted/unique"

    def test_duplicate_insert_ignored(self):
        g = self._base()
        und = _und(g)
        before = g.num_edges
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=und[:3]))       # already present
        assert d.graph().num_edges == before

    def test_delete_then_insert_resurrects(self):
        g = self._base()
        e = _und(g)[:1]
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(delete=e))
        d.apply_batch(EdgeBatch(insert=e))
        np.testing.assert_array_equal(
            np.asarray(d.graph().to_numpy().indices),
            np.asarray(g.to_numpy().indices))

    def test_insert_grows_vertex_set(self):
        g = self._base(n=10, m=30)
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=np.array([[2, 14]])))
        m = d.graph()
        assert m.num_nodes == 15
        assert 14 in m.neighbors(2)

    def test_incremental_edge_cm_matches_full(self):
        g = self._base().with_edge_cm()
        und = _und(g)
        rng = np.random.default_rng(2)
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(
            insert=np.stack([rng.integers(0, 48, 6),
                             rng.integers(0, 48, 6)], 1),
            delete=und[rng.choice(len(und), 5, replace=False)]))
        merged = d.graph()
        np.testing.assert_array_equal(
            np.asarray(merged.to_numpy().edge_cm),
            edge_common_neighbors_fast(merged))

    def test_auto_compaction_threshold(self):
        g = self._base()
        d = DeltaCSR(g, compact_threshold=0.01)
        und = _und(g)
        d.apply_batch(EdgeBatch(delete=und[:10]))      # > 1% of arcs
        assert d.compactions == 1
        assert d.pending_arcs == 0

    def test_weighted_overlay(self):
        edges = np.array([[0, 1], [1, 2], [2, 3]])
        g = build_csr(edges, 4, weights=np.array([1.0, 2.0, 3.0],
                                                 np.float32))
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=np.array([[0, 3]]),
                                insert_weights=np.array([5.0])))
        m = d.graph().to_numpy()
        indptr = np.asarray(m.indptr)
        row0 = np.asarray(m.indices)[indptr[0]:indptr[1]]
        w0 = np.asarray(m.weights)[indptr[0]:indptr[1]]
        assert row0.tolist() == [1, 3]
        assert w0.tolist() == [1.0, 5.0]

    def test_out_of_range_delete_ignored_no_code_alias(self):
        """delete=[[0, n+k]] must be a no-op: 0*n + (n+k) aliases the
        arc code of a REAL edge, so unguarded encoding would tombstone
        an unrelated arc (one direction only)."""
        g = build_csr(np.array([[2, 3], [1, 4], [0, 2]]), 10)
        before = np.asarray(g.to_numpy().indices).copy()
        d = DeltaCSR(g, compact_threshold=0)
        # 0*10 + 23 == 23 == code of arc (2, 3)
        d.apply_batch(EdgeBatch(delete=np.array([[0, 23]])))
        m = d.graph().to_numpy()
        np.testing.assert_array_equal(np.asarray(m.indices), before)
        assert d.pending_arcs == 0

    def test_resurrected_edge_takes_new_weight(self):
        edges = np.array([[0, 1], [1, 2]])
        g = build_csr(edges, 3, weights=np.array([2.0, 3.0], np.float32))
        base_w = np.asarray(g.to_numpy().weights).copy()
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(delete=np.array([[0, 1]])))
        d.apply_batch(EdgeBatch(insert=np.array([[0, 1]]),
                                insert_weights=np.array([7.5])))
        m = d.graph().to_numpy()
        indptr = np.asarray(m.indptr)
        w01 = float(np.asarray(m.weights)[indptr[0]])
        assert w01 == 7.5                       # re-priced, not stale 2.0
        # and the caller's base graph was never mutated in place
        np.testing.assert_array_equal(np.asarray(g.to_numpy().weights),
                                      base_w)

    def test_version_bumps_on_mutation(self):
        g = self._base()
        d = DeltaCSR(g, compact_threshold=0)
        v1 = d.graph()
        assert graph_version(v1) == 0
        d.apply_batch(EdgeBatch(insert=np.array([[1, 2]])))
        # Retired view's version is bumped so (id, version) cache keys
        # can never serve its pre-mutation derivatives to a new view.
        assert graph_version(v1) > 0
        v2 = d.graph()
        assert v2 is not v1


# ---------------------------------------------------------------------------
# Vertex-keyed RNG: subset re-walks are bit-identical
# ---------------------------------------------------------------------------


class TestVertexKeyedRng:
    def _setup(self, small_graph):
        g = small_graph.with_edge_cm()
        spec = WalkSpec(max_len=24, min_len=6, mu=0.995, info_mode="incom",
                        reg_start=16, rng_mode="vertex")
        return g, make_policy("huge"), spec, jax.random.PRNGKey(11)

    def test_subset_matches_full_batch_dense(self, small_graph):
        g, policy, spec, key = self._setup(small_graph)
        full = run_walk_batch(g, jnp.arange(g.num_nodes, dtype=jnp.int32),
                              key, policy, spec)
        sub_ids = np.array([1, 7, 60, 130, 255], np.int32)
        sub = run_walk_batch(g, jnp.asarray(sub_ids), key, policy, spec)
        np.testing.assert_array_equal(np.asarray(full.path)[sub_ids],
                                      np.asarray(sub.path))
        np.testing.assert_array_equal(np.asarray(full.info.L)[sub_ids],
                                      np.asarray(sub.info.L))

    def test_subset_matches_full_batch_sharded(self, small_graph):
        g, policy, spec, key = self._setup(small_graph)
        part = jnp.asarray(np.arange(g.num_nodes) % 3, jnp.int32)
        full = run_walk_batch(g, jnp.arange(g.num_nodes, dtype=jnp.int32),
                              key, policy, spec, part, num_shards=3)
        sub_ids = np.array([0, 5, 77, 200], np.int32)
        sub = run_walk_batch(g, jnp.asarray(sub_ids), key, policy, spec,
                             part, num_shards=3)
        np.testing.assert_array_equal(np.asarray(full.path)[sub_ids],
                                      np.asarray(sub.path))

    def test_chunking_invariance(self, small_graph):
        """Splitting one source set into chunks under a shared key gives
        the same walks — the property the streaming pipeline relies on to
        re-walk arbitrary subsets without knowing chunk boundaries."""
        g, policy, spec, key = self._setup(small_graph)
        ids = np.arange(100, dtype=np.int32)
        whole = run_walk_batch(g, jnp.asarray(ids), key, policy, spec)
        parts = [run_walk_batch(g, jnp.asarray(ids[i:i + 32]), key, policy,
                                spec) for i in range(0, 100, 32)]
        stitched = np.concatenate([np.asarray(p.path) for p in parts])
        np.testing.assert_array_equal(np.asarray(whole.path), stitched)

    def test_lane_vs_vertex_keying_semantics(self, small_graph):
        """Duplicate sources separate the two modes: lane keying draws per
        BATCH POSITION (duplicate roots diverge), vertex keying draws per
        SOURCE VERTEX (duplicate roots walk identically)."""
        g = small_graph.with_edge_cm()
        hub = int(np.argmax(np.asarray(g.degrees())))
        ids = jnp.full((8,), hub, jnp.int32)
        key = jax.random.PRNGKey(11)
        policy = make_policy("huge")
        base = dict(max_len=24, min_len=6, mu=0.995, info_mode="incom",
                    reg_start=16)
        lane = run_walk_batch(g, ids, key, policy, WalkSpec(**base))
        vert = run_walk_batch(g, ids, key, policy,
                              WalkSpec(**base, rng_mode="vertex"))
        lane_paths = np.asarray(lane.path)
        vert_paths = np.asarray(vert.path)
        assert (vert_paths == vert_paths[0]).all(), \
            "vertex keying must give duplicate roots identical walks"
        assert (lane_paths != lane_paths[0]).any(), \
            "lane keying draws per position; duplicates should diverge"


# ---------------------------------------------------------------------------
# Affected-vertex detection (recovered from the corpus)
# ---------------------------------------------------------------------------


class TestAffectedDetection:
    def test_path_line_graph(self):
        # 0-1-2-3-4 path; walks recorded manually.
        g = build_csr(np.array([[0, 1], [1, 2], [2, 3], [3, 4]]), 5)
        walks = np.array([
            [0, 1, 2, -1],        # traverses (1,2)
            [2, 3, 4, -1],        # traverses (2,3), (3,4)
            [4, 3, -1, -1],       # traverses (3,4)
        ], np.int32)
        roots = np.array([0, 2, 4])
        changed = np.array([[1, 2]])
        aff = affected_roots(walks, roots, changed, np.array([1, 2]), 5)
        # endpoints 1,2 + root 0 (its walk traverses 1-2); root 2's walk
        # does NOT traverse 1-2 (it goes 2-3-4)
        assert aff.tolist() == [True, True, True, False, False]

    def test_reverse_direction_detected(self):
        g = build_csr(np.array([[0, 1], [1, 2]]), 3)
        walks = np.array([[2, 1, 0, -1]], np.int32)      # traverses 1-0
        aff = affected_roots(walks, np.array([2]), np.array([[0, 1]]),
                             np.array([0, 1]), 3)
        assert aff[2]

    def test_empty_churn(self):
        walks = np.array([[0, 1, -1]], np.int32)
        aff = affected_roots(walks, np.array([0]),
                             np.zeros((0, 2), np.int64),
                             np.zeros(0, np.int64), 3)
        assert not aff.any()

    def test_paranoid_superset_and_exactness(self, small_graph):
        """Paranoid mode must (a) contain the traversal set and (b) flag
        every walk whose from-scratch re-run on the mutated graph differs
        — the provable kept-walk invariance guarantee."""
        g = small_graph.with_edge_cm()
        n = g.num_nodes
        spec = WalkSpec(max_len=20, min_len=6, mu=0.995, info_mode="incom",
                        reg_start=16, rng_mode="vertex")
        policy = make_policy("huge")
        key = jax.random.PRNGKey(3)
        old = run_walk_batch(g, jnp.arange(n, dtype=jnp.int32), key,
                             policy, spec)
        walks_old = np.asarray(old.path)

        und = _und(g)
        rng = np.random.default_rng(5)
        dele = und[rng.choice(len(und), 4, replace=False)]
        ins = np.stack([rng.integers(0, n, 5), rng.integers(0, n, 5)], 1)
        d = DeltaCSR(g, compact_threshold=0)
        d.apply_batch(EdgeBatch(insert=ins, delete=dele))
        g2 = d.compact()
        changed = np.concatenate([ins, dele])
        touched = np.unique(changed)

        roots = np.arange(n)
        trav = affected_roots(walks_old, roots, changed, touched, n)
        par = affected_roots(walks_old, roots, changed, touched, n,
                             mode="paranoid", old_graph=g, new_graph=g2)
        assert (trav <= par).all()

        new = run_walk_batch(g2, jnp.arange(n, dtype=jnp.int32), key,
                             policy, spec)
        same = (walks_old == np.asarray(new.path)).all(axis=1)
        assert not (~same & ~par).any(), \
            "paranoid detector missed a diverging walk"

    def test_changed_arc_codes_sorted_both_dirs(self):
        codes = changed_arc_codes(np.array([[3, 1], [0, 2]]), 10)
        assert codes.tolist() == sorted(codes.tolist())
        assert set(codes.tolist()) == {31, 13, 2, 20}


# ---------------------------------------------------------------------------
# Cache invalidation on mutation (pcsr + slot pool)
# ---------------------------------------------------------------------------


class TestCacheInvalidation:
    def test_pcsr_never_stale_across_mutation(self, small_graph):
        from repro.core.shard_engine import partitioned_csr_for

        g = small_graph.with_edge_cm()
        n = g.num_nodes
        asn = np.arange(n) % 2
        d = DeltaCSR(g, compact_threshold=0)
        v1 = d.graph()
        p1 = partitioned_csr_for(v1, asn, 2)
        assert partitioned_csr_for(v1, asn, 2) is p1       # cache hit
        d.apply_batch(EdgeBatch(insert=np.array([[0, n - 1]])))
        v2 = d.graph()
        p2 = partitioned_csr_for(v2, asn, 2)
        assert p2 is not p1
        # the new pcsr must contain the inserted arc
        shard_of_0 = asn[0]
        row = np.asarray(p2.slices.indices[shard_of_0])
        indptr = np.asarray(p2.slices.indptr[shard_of_0])
        local0 = int(np.asarray(p2.local_of)[0])
        assert (n - 1) in row[indptr[local0]:indptr[local0 + 1]]

    def test_version_guard_defeats_id_aliasing(self, small_graph):
        """Even if a mutated graph were passed under the SAME object (the
        in-place overlay hazard the PR-3 cache could not see), the bumped
        version must miss the cache."""
        from repro.core.shard_engine import partitioned_csr_for

        g = small_graph.with_edge_cm()
        asn = np.arange(g.num_nodes) % 2
        p1 = partitioned_csr_for(g, asn, 2)
        bump_graph_version(g)          # simulate in-place mutation
        p2 = partitioned_csr_for(g, asn, 2)
        assert p2 is not p1

    def test_walks_see_mutation(self, small_graph):
        """run_walk_sharded on the post-mutation view must walk the NEW
        graph (no stale pcsr serving)."""
        from repro.core.shard_engine import run_walk_sharded

        g = small_graph.with_edge_cm()
        n = g.num_nodes
        spec = WalkSpec(max_len=16, min_len=4, mu=0.995, info_mode="incom",
                        reg_start=16, rng_mode="vertex")
        policy = make_policy("huge")
        part = jnp.asarray(np.arange(n) % 2, jnp.int32)
        key = jax.random.PRNGKey(0)
        src = jnp.arange(n, dtype=jnp.int32)

        d = DeltaCSR(g, compact_threshold=0)
        st1 = run_walk_sharded(d.graph(), src, key, policy, spec, part, 2,
                               engine="local")
        # delete EVERY edge of the highest-degree node; its walks must
        # become length-1 dead ends on the mutated graph
        hub = int(np.argmax(np.asarray(g.degrees())))
        nbrs = g.neighbors(hub)
        d.apply_batch(EdgeBatch(
            delete=np.stack([np.full(len(nbrs), hub), nbrs], 1)))
        st2 = run_walk_sharded(d.graph(), src, key, policy, spec, part, 2,
                               engine="local")
        assert float(np.asarray(st1.info.L)[hub]) > 1.0
        assert float(np.asarray(st2.info.L)[hub]) == 1.0


# ---------------------------------------------------------------------------
# Ring replacement + seeded gate
# ---------------------------------------------------------------------------


class TestRingReplace:
    def test_ocn_exact_after_replace(self):
        from repro.core.corpus import CorpusRing, ring_append, ring_replace

        ring = CorpusRing.create(8, 5, 10)
        w0 = jnp.asarray(np.array([[0, 1, 2, -1, -1],
                                   [3, 4, -1, -1, -1]], np.int32))
        ring = ring_append(ring, w0, jnp.asarray([3, 2], jnp.int32))
        w1 = jnp.asarray(np.array([[5, 6, 7, 8, -1]], np.int32))
        ring = ring_replace(ring, jnp.asarray([0], jnp.int32), w1,
                            jnp.asarray([4], jnp.int32))
        ocn = np.asarray(ring.ocn)
        expect = np.bincount([5, 6, 7, 8, 3, 4], minlength=10)
        np.testing.assert_array_equal(ocn, expect)
        assert int(ring.cursor) == 2                  # replace ≠ append
        assert int(ring.total) == 2

    def test_untouched_slots_bitwise_stable(self):
        from repro.core.corpus import CorpusRing, ring_append, ring_replace

        ring = CorpusRing.create(4, 3, 6)
        w = jnp.asarray(np.array([[0, 1, -1], [2, 3, -1], [4, 5, -1]],
                                 np.int32))
        ring = ring_append(ring, w, jnp.asarray([2, 2, 2], jnp.int32))
        before = np.asarray(ring.walks).copy()
        ring2 = ring_replace(ring, jnp.asarray([1], jnp.int32),
                             jnp.asarray([[5, 0, 1]], jnp.int32),
                             jnp.asarray([3], jnp.int32))
        after = np.asarray(ring2.walks)
        np.testing.assert_array_equal(before[[0, 2, 3]], after[[0, 2, 3]])


class TestSeededGate:
    def test_converged_history_no_extra_rounds(self):
        hist = [0.5, 0.41, 0.4, 0.4]
        gate = WalkCountController(delta=1e-2, min_rounds=1,
                                   max_rounds=len(hist) + 3,
                                   seed_history=hist)
        # refreshed D lands where the prior run converged -> stop at once
        assert gate.update_d(0.4005) is False

    def test_shifted_d_walks_more(self):
        hist = [0.5, 0.41, 0.4, 0.4]
        gate = WalkCountController(delta=1e-2, min_rounds=1,
                                   max_rounds=len(hist) + 3,
                                   seed_history=hist)
        assert gate.update_d(0.46) is True            # churn moved D
        assert gate.update_d(0.461) is False          # re-converged

    def test_seed_replays_windowed_smoothing(self):
        hist = [0.5, 0.4]
        gate = WalkCountController(delta=1e-3, window=2, seed_history=hist)
        ref = WalkCountController(delta=1e-3, window=2)
        ref.update_d(0.5)
        ref.update_d(0.4)
        assert gate._smooth == ref._smooth

    def test_no_min_rounds_burn_in(self):
        """Seeded gates judge the first post-churn D immediately (the
        cold-start path would force min_rounds extra walks)."""
        hist = [0.3] * 5
        gate = WalkCountController(delta=1e-2, min_rounds=1,
                                   max_rounds=10, seed_history=hist)
        assert gate.update_d(0.3001) is False


# ---------------------------------------------------------------------------
# churn generator
# ---------------------------------------------------------------------------


class TestChurnBatch:
    def test_shape_and_freshness(self, medium_graph):
        und = _und(medium_graph)
        batch = churn_batch(medium_graph, 0.05, seed=2)
        assert batch.num_changes >= int(0.04 * len(und))
        existing = set(map(tuple, np.sort(und, 1).tolist()))
        for e in np.sort(batch.insert, 1).tolist():
            assert tuple(e) not in existing
        for e in np.sort(batch.delete, 1).tolist():
            assert tuple(e) in existing

    def test_deterministic(self, medium_graph):
        a = churn_batch(medium_graph, 0.05, seed=2)
        b = churn_batch(medium_graph, 0.05, seed=2)
        np.testing.assert_array_equal(a.insert, b.insert)
        np.testing.assert_array_equal(a.delete, b.delete)


def test_refresh_extra_rounds_never_wrap_a_full_ring(small_graph):
    """When the corpus ring is exactly full, the ΔD top-up must stop
    instead of wrapping — a wrap would overwrite retained walks of
    UNAFFECTED roots and permanently over-count ocn."""
    from repro.core.api import make_walk_plan
    from repro.core.dsgl import DSGLConfig
    from repro.core.incremental import IncrementalRefresh
    from repro.runtime.trainer import StreamingEmbedPipeline

    cfg = EmbedConfig(dim=8, epochs=1, max_len=16, min_len=4, window=3,
                      negatives=2, rng_mode="vertex")
    policy, spec, _ = make_walk_plan(cfg)
    # Fixed 2-round run fills a 2-round ring to exactly its capacity.
    rounds = dict(delta=-1.0, min_rounds=2, max_rounds=2)
    dcfg = DSGLConfig(dim=8, window=3, negatives=2, seed=0)
    pipe = StreamingEmbedPipeline(small_graph.with_edge_cm(), policy, spec,
                                  rounds, dcfg)
    pipe.run()
    assert int(pipe.ring.total) == pipe.ring.capacity     # full

    walks_before = np.asarray(pipe.ring.walks).copy()
    roots_before = pipe._slot_root.copy()
    refresher = IncrementalRefresh(pipe)
    batch = churn_batch(small_graph, 0.05, seed=4)
    refresher.apply_updates(batch)
    stats = refresher.refresh(max_extra_rounds=4)
    assert stats.extra_rounds == 0                        # capacity guard
    # every slot rooted at an unaffected vertex is still bit-identical
    changed_edges = np.concatenate([batch.insert, batch.delete])
    aff = affected_roots(walks_before, roots_before, changed_edges,
                         np.unique(changed_edges),
                         small_graph.num_nodes)
    walks_after = np.asarray(pipe.ring.walks)
    kept = ~aff[np.maximum(roots_before, 0)] & (roots_before >= 0)
    np.testing.assert_array_equal(walks_before[kept], walks_after[kept])
    # and ocn stayed exact (recount over all slots)
    w = walks_after[roots_before >= 0]
    cnt = np.bincount(w[w >= 0], minlength=small_graph.num_nodes)
    np.testing.assert_array_equal(cnt, np.asarray(pipe.ring.ocn))


def test_refresh_detect_override_is_per_call(small_graph):
    """detect= in refresh_embedding applies to that call only; the
    refresher's configured mode is restored afterwards."""
    cfg = EmbedConfig(dim=8, epochs=1, max_len=16, min_len=4, window=3,
                      negatives=2, delta=1e-2)
    _, _, state = embed_graph(small_graph, cfg, num_shards=1,
                              return_state=True)
    assert state.refresher.detect == "traversal"
    batch = churn_batch(small_graph, 0.02, seed=5)
    refresh_embedding(state, batch, detect="paranoid",
                      fine_tune_steps=1, max_extra_rounds=0)
    assert state.refresher.detect == "traversal"


def test_refresh_rejects_vertex_growth_before_draining(small_graph):
    """Churn that grows |V| must be rejected BEFORE the churn log drains
    or the overlay compacts — a failed refresh leaves the refresher
    consistent instead of permanently corrupted."""
    cfg = EmbedConfig(dim=8, epochs=1, max_len=16, min_len=4, window=3,
                      negatives=2, delta=1e-2)
    _, _, state = embed_graph(small_graph, cfg, num_shards=1,
                              return_state=True)
    n = small_graph.num_nodes
    grow = EdgeBatch(insert=np.array([[0, n + 3]]))
    with pytest.raises(ValueError, match="vertex set"):
        refresh_embedding(state, grow)
    # the staged churn is still in the log (nothing was drained)
    ins, _ = state.refresher.delta.pending_changes()
    assert len(ins) == 1


# ---------------------------------------------------------------------------
# End-to-end acceptance (ISSUE 4)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_refresh_acceptance_e2e():
    """Mutate 5% of edges; the refresh must (a) re-walk <= 30% of
    vertices, (b) keep every walk rooted at an unaffected vertex
    bit-identical to its pre-update counterpart, and (c) land within
    0.02 AUC of a from-scratch recompute on the mutated graph."""
    from benchmarks.common import link_prediction_auc

    g = rmat_graph(2048, 10, seed=3)
    cfg = EmbedConfig(dim=32, epochs=1, lr=0.05, delta=1e-3, max_len=40,
                      min_len=10, window=6, negatives=4)
    phi0, _, state = embed_graph(g, cfg, num_shards=2, return_state=True)
    pipe = state.refresher.pipeline

    walks_before = np.asarray(pipe.ring.walks).copy()
    roots_before = pipe._slot_root.copy()
    batch = churn_batch(g, 0.05, seed=1)
    und = _und(g)
    assert batch.num_changes >= int(0.045 * len(und))   # really ~5% churn

    phi1, _, stats = refresh_embedding(state, batch)

    # (a) affected fraction
    assert stats.affected_frac <= 0.30, stats.affected_frac

    # (b) unaffected slots bit-identical: every slot whose pre-update
    # root is NOT affected must hold exactly its pre-update walk.
    walks_after = np.asarray(pipe.ring.walks)
    changed_slot = (walks_before != walks_after).any(axis=1)
    prev_written = roots_before >= 0
    # In-place changes split into REPLACED slots (must be affected-rooted)
    # and fresh APPENDS from extra rounds (previously unwritten slots).
    replaced_roots = roots_before[changed_slot & prev_written]
    assert len(set(replaced_roots.tolist())) <= stats.affected
    # every slot whose pre-update root was NOT replaced is bit-identical
    kept = ~changed_slot & prev_written
    assert kept.sum() > 0
    np.testing.assert_array_equal(walks_before[kept], walks_after[kept])
    # and specifically: recompute the affected set independently from the
    # pre-update corpus; no slot rooted OUTSIDE it may have changed.
    changed_edges = np.concatenate([batch.insert, batch.delete])
    aff_mask = affected_roots(
        walks_before[prev_written], roots_before[prev_written],
        changed_edges, np.unique(changed_edges), g.num_nodes)
    assert int(aff_mask.sum()) == stats.affected
    assert set(replaced_roots.tolist()) <= set(np.nonzero(aff_mask)[0]
                                               .tolist())
    unaffected_slot = prev_written & ~aff_mask[np.maximum(roots_before, 0)]
    np.testing.assert_array_equal(walks_before[unaffected_slot],
                                  walks_after[unaffected_slot])

    # (c) AUC parity with scratch recompute on the mutated graph
    g2 = state.graph
    cfg_s = dataclasses.replace(cfg, rng_mode="vertex")
    phi_scratch, _ = embed_graph(g2, cfg_s, num_shards=2)
    auc_refresh = link_prediction_auc(g2, phi1, np.random.default_rng(7))
    auc_scratch = link_prediction_auc(g2, phi_scratch,
                                      np.random.default_rng(7))
    assert abs(auc_refresh - auc_scratch) <= 0.02, \
        (auc_refresh, auc_scratch)
    # absolute sanity: the refreshed embedding still separates edges
    assert auc_refresh > 0.8, auc_refresh
