"""Fault-tolerant embedding serving tests (DESIGN.md §14).

Four contracts under test:

* **bit-identity** — device scores (pair and top-K) match the NumPy
  oracle bit-for-bit for every dim / candidate width / batch shape the
  wave scheduler can produce (the FMA-contraction regression guard);
* **swap atomicity** — under concurrent submit/tick/swap, every
  response's scores match exactly ONE version's oracle (the version it
  is stamped with) — a half-swapped read is unrepresentable;
* **degraded reads** — torn / unhealthy candidates leave the active
  version serving (stamped stale), the ladder returns to fresh on the
  next good swap, and terminal states (nothing servable at all) dump a
  flight record and raise;
* **admission control** — deadline sheds use the wave-wall EMA
  predictor, overflow (real or drilled) sheds at the door, and a wave
  fault re-queues: an admitted query is never dropped.
"""

import threading

import numpy as np
import pytest

from repro.ckpt.checkpoint import save_checkpoint
from repro.runtime.faults import FaultInjector, SimulatedFailure
from repro.runtime.health import SnapshotGate, SnapshotGateConfig
from repro.runtime.serve import (EmbedServer, ServeConfig, ServeError,
                                 oracle_scores, oracle_topk, wave_batches)

jnp = pytest.importorskip("jax.numpy")


def _phi(n=64, d=16, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)) \
        .astype(np.float32)


def _ckpt(root, step, phi, **meta):
    meta.setdefault("graph_version", 0)
    meta.setdefault("global_step", step)
    return save_checkpoint(str(root), step, {"phi_in": phi}, meta=meta)


def _server(**kw):
    kw.setdefault("cfg", ServeConfig(batch_slots=8))
    return EmbedServer(**kw)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Oracle bit-identity
# ---------------------------------------------------------------------------


class TestBitIdentity:
    @pytest.mark.parametrize("d", [8, 16, 17, 33, 64])
    def test_pair_scores_match_oracle_exactly(self, tmp_path, d):
        phi = _phi(d=d, seed=d)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        assert srv.offer_snapshot(str(tmp_path))
        rng = np.random.default_rng(d)
        for width in (1, 2, 5, 8, 16):
            cand = rng.integers(0, 64, size=width)
            qid = srv.submit(int(rng.integers(0, 64)), candidates=cand)
            srv.drain()
            r = srv.responses[qid]
            want = oracle_scores(phi, r.u, cand)
            assert np.array_equal(r.scores, want), (d, width)
            assert np.array_equal(r.ids, cand)

    @pytest.mark.parametrize("k", [1, 5, 16])
    def test_topk_matches_oracle_exactly(self, tmp_path, k):
        phi = _phi(seed=k)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        qids = [srv.submit(u, k=k) for u in (0, 7, 63)]
        srv.drain()
        for qid, u in zip(qids, (0, 7, 63)):
            r = srv.responses[qid]
            vals, ids = oracle_topk(phi, u, k)
            assert np.array_equal(r.scores, vals)
            assert np.array_equal(r.ids, ids)
            assert u not in r.ids          # self excluded

    def test_mixed_wave_groups_do_not_leak_padding(self, tmp_path):
        """One wave mixing top-K and several candidate widths: each
        response is trimmed to its own query's shape and exact."""
        phi = _phi(seed=42)
        _ckpt(tmp_path, 0, phi)
        srv = _server(cfg=ServeConfig(batch_slots=32))
        assert srv.offer_snapshot(str(tmp_path))
        specs = [{"u": 1, "candidates": [2, 3, 4]},
                 {"u": 5, "k": 4},
                 {"u": 9, "candidates": [10]},
                 {"u": 11, "candidates": list(range(20))},
                 {"u": 13, "k": 4}]
        out = srv.serve(specs)
        assert all(r is not None for r in out)
        for spec, r in zip(specs, out):
            if "candidates" in spec:
                assert len(r.scores) == len(spec["candidates"])
                assert np.array_equal(
                    r.scores, oracle_scores(phi, spec["u"],
                                            spec["candidates"]))
            else:
                vals, ids = oracle_topk(phi, spec["u"], spec["k"])
                assert np.array_equal(r.scores, vals)
                assert np.array_equal(r.ids, ids)

    def test_wave_batches_shapes(self):
        assert [len(w) for w in wave_batches(list(range(10)), 4)] \
            == [4, 4, 2]
        assert list(wave_batches([], 4)) == []


# ---------------------------------------------------------------------------
# Versioned snapshot swap
# ---------------------------------------------------------------------------


class TestSnapshotSwap:
    def test_swap_is_monotone_and_stamped(self, tmp_path):
        a, b = _phi(seed=1), _phi(seed=2)
        _ckpt(tmp_path, 0, a)
        srv = _server()
        assert srv.offer_snapshot(str(tmp_path))
        q0 = srv.submit(3, candidates=[1, 2])
        srv.drain()
        _ckpt(tmp_path, 1, b)
        assert srv.offer_snapshot(str(tmp_path))
        q1 = srv.submit(3, candidates=[1, 2])
        srv.drain()
        assert srv.responses[q0].served_version == 0
        assert srv.responses[q1].served_version == 1
        assert np.array_equal(srv.responses[q0].scores,
                              oracle_scores(a, 3, [1, 2]))
        assert np.array_equal(srv.responses[q1].scores,
                              oracle_scores(b, 3, [1, 2]))
        assert srv.swaps == 2

    def test_reoffer_of_active_version_is_noop(self, tmp_path):
        _ckpt(tmp_path, 0, _phi())
        srv = _server()
        assert srv.offer_snapshot(str(tmp_path))
        assert not srv.offer_snapshot(str(tmp_path))
        assert srv.swaps == 1
        assert srv.stats()["freshness"] == "fresh"

    def test_torn_candidate_falls_back_and_keeps_serving(self, tmp_path):
        """A torn (manifest-less) newer step is invisible: the loader
        falls back to the active version, which keeps serving fresh."""
        phi = _phi(seed=3)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        torn = tmp_path / "step_00000001"
        torn.mkdir()
        (torn / "phi_in.npy").write_bytes(b"\x93NUMPY garbage")
        assert not srv.offer_snapshot(str(tmp_path))
        assert srv.active_version() == 0
        r = srv.serve([{"u": 2, "candidates": [4, 5]}])[0]
        assert np.array_equal(r.scores, oracle_scores(phi, 2, [4, 5]))
        assert srv.stats()["availability"] == 1.0

    def test_no_snapshot_at_all_is_terminal(self, tmp_path):
        srv = _server()
        with pytest.raises(ServeError):
            srv.offer_snapshot(str(tmp_path / "empty"))

    def test_swap_window_fault_leaves_old_version_serving(self, tmp_path):
        """Drill point "swap" fires inside the swap window, before the
        commit: the offer dies but the previous version keeps serving."""
        a, b = _phi(seed=4), _phi(seed=5)
        _ckpt(tmp_path, 0, a)
        faults = FaultInjector(plan={"swap": (1,)})
        srv = _server(faults=faults)
        assert srv.offer_snapshot(str(tmp_path))          # occurrence 0
        _ckpt(tmp_path, 1, b)
        with pytest.raises(SimulatedFailure):
            srv.offer_snapshot(str(tmp_path))             # occurrence 1
        assert srv.active_version() == 0
        r = srv.serve([{"u": 6, "candidates": [7]}])[0]
        assert np.array_equal(r.scores, oracle_scores(a, 6, [7]))
        assert r.served_version == 0
        # Retry after the (transient) fault: the swap completes.
        assert srv.offer_snapshot(str(tmp_path))
        assert srv.active_version() == 1

    def test_concurrent_swap_atomicity(self, tmp_path):
        """Queries racing ~30 swaps: every response's scores must match
        the oracle of EXACTLY the version it is stamped with — the
        captured-snapshot invariant at the bit level."""
        phis = {v: _phi(seed=100 + v) for v in range(30)}
        _ckpt(tmp_path, 0, phis[0])
        srv = _server(cfg=ServeConfig(batch_slots=4))
        srv.offer_snapshot(str(tmp_path))
        stop = threading.Event()
        errors: list = []

        def swapper():
            try:
                for v in range(1, 30):
                    _ckpt(tmp_path, v, phis[v])
                    assert srv.offer_snapshot(str(tmp_path))
            except Exception as e:               # pragma: no cover
                errors.append(e)
            finally:
                stop.set()

        t = threading.Thread(target=swapper)
        t.start()
        cand = np.array([1, 2, 3, 4, 5])
        qids = []
        while not stop.is_set() or srv.stats()["queue_depth"]:
            qid = srv.submit(9, candidates=cand)
            if qid is not None:
                qids.append(qid)
            srv.tick()
        t.join()
        srv.drain()
        assert not errors
        assert srv.swaps == 30 and len(qids) > 0
        for qid in qids:
            r = srv.responses[qid]
            want = oracle_scores(phis[r.served_version], 9, cand)
            assert np.array_equal(r.scores, want), qid


# ---------------------------------------------------------------------------
# Health-gated swap
# ---------------------------------------------------------------------------


class TestHealthGate:
    def test_nonfinite_candidate_rejected_serves_stale(self, tmp_path):
        phi = _phi(seed=6)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        bad = phi.copy()
        bad[5, 0] = np.nan
        _ckpt(tmp_path, 1, bad)
        assert not srv.offer_snapshot(str(tmp_path))
        assert srv.rejected_candidates == 1
        assert srv.active_version() == 0
        r = srv.serve([{"u": 1, "candidates": [2]}])[0]
        assert r.freshness == "stale"       # a newer version exists but
        assert r.served_version == 0        # is unhealthy
        assert np.array_equal(r.scores, oracle_scores(phi, 1, [2]))

    def test_good_swap_clears_stale_flag(self, tmp_path):
        phi = _phi(seed=7)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        bad = np.full_like(phi, np.inf)
        _ckpt(tmp_path, 1, bad)
        assert not srv.offer_snapshot(str(tmp_path))
        assert srv.stats()["freshness"] == "stale"
        _ckpt(tmp_path, 2, _phi(seed=8))
        assert srv.offer_snapshot(str(tmp_path))
        assert srv.stats()["freshness"] == "fresh"

    def test_version_regression_rejected_by_gate(self):
        gate = SnapshotGate(SnapshotGateConfig())
        phi = _phi()
        ok, _ = gate.admit(phi, version=5)
        assert ok
        ok, reason = gate.admit(phi, version=5)
        assert not ok and reason == "version_regression"
        ok, reason = gate.admit(phi, version=6, graph_version=-1)
        assert not ok and reason == "graph_version_regression"

    def test_norm_spike_rejected_after_warmup(self):
        gate = SnapshotGate(SnapshotGateConfig(spike_factor=4.0,
                                               warmup_admits=1))
        phi = _phi(seed=9)
        assert gate.admit(phi, version=0)[0]
        ok, reason = gate.admit(phi * 100.0, version=1)
        assert not ok and reason == "norm_spike"
        assert gate.admit(phi * 1.01, version=2)[0]

    def test_rejected_first_candidate_is_terminal(self, tmp_path):
        bad = np.full((8, 4), np.nan, np.float32)
        _ckpt(tmp_path, 0, bad)
        srv = _server()
        with pytest.raises(ServeError, match="rejected"):
            srv.offer_snapshot(str(tmp_path))


# ---------------------------------------------------------------------------
# Degrade ladder + admission control
# ---------------------------------------------------------------------------


class TestDegradeLadderAndAdmission:
    def test_refresh_state_moves_the_ladder(self, tmp_path):
        phi = _phi(seed=10)
        _ckpt(tmp_path, 0, phi)
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        srv.note_refresh("degraded")
        r = srv.serve([{"u": 1, "candidates": [2]}])[0]
        assert r.freshness == "stale"
        srv.note_refresh("ok")
        r = srv.serve([{"u": 1, "candidates": [2]}])[0]
        assert r.freshness == "fresh"
        with pytest.raises(AssertionError):
            srv.note_refresh("on_fire")

    def test_no_version_sheds_at_admission(self):
        srv = _server()
        assert srv.submit(1, candidates=[2]) is None
        assert srv.shed == {"no_version": 1}

    def test_queue_overflow_sheds(self, tmp_path):
        _ckpt(tmp_path, 0, _phi())
        srv = _server(cfg=ServeConfig(batch_slots=4, max_queue=3))
        srv.offer_snapshot(str(tmp_path))
        qids = [srv.submit(1, candidates=[2]) for _ in range(5)]
        assert sum(q is not None for q in qids) == 3
        assert srv.shed["overflow"] == 2
        srv.drain()
        assert srv.stats()["availability"] == 1.0   # of admitted

    def test_queue_overflow_drill(self, tmp_path):
        _ckpt(tmp_path, 0, _phi())
        faults = FaultInjector(inject_plan={"queue_overflow": (1,)})
        srv = _server(faults=faults)
        srv.offer_snapshot(str(tmp_path))
        assert srv.submit(1, candidates=[2]) is not None
        assert srv.submit(1, candidates=[2]) is None   # drilled occurrence
        assert srv.submit(1, candidates=[2]) is not None
        assert srv.shed["overflow"] == 1

    def test_deadline_shed_uses_wave_ema_prediction(self, tmp_path):
        """After a slow wave (fake clock), a tight deadline is shed at
        admission while a loose one is admitted."""
        clock = FakeClock()
        _ckpt(tmp_path, 0, _phi(seed=11))
        srv = _server(cfg=ServeConfig(batch_slots=4, headroom=1.0),
                      clock=clock)
        srv.offer_snapshot(str(tmp_path))
        # First wave is never shed (no EMA yet); the fake clock charges
        # it 1s of wall, seeding the predictor.
        assert srv.submit(1, candidates=[2],
                          deadline_s=0.1) is not None
        inner = srv._score_wave

        def slow(wave, snap):
            clock.advance(1.0)
            return inner(wave, snap)

        srv._score_wave = slow
        srv.drain()
        assert srv._wave_ema == pytest.approx(1.0)
        # predicted = 1 wave * 1s EMA * 1.0 headroom = 1s.
        assert srv.submit(2, candidates=[3], deadline_s=0.1) is None
        assert srv.shed["deadline"] == 1
        assert srv.submit(2, candidates=[3], deadline_s=10.0) is not None
        srv.drain()
        assert srv.stats()["availability"] == 1.0

    def test_wave_fault_requeues_admitted_queries(self, tmp_path):
        """The "serve_wave" drill kills a wave mid-flight: the wave goes
        back to the queue front and the retry answers every query."""
        phi = _phi(seed=12)
        _ckpt(tmp_path, 0, phi)
        faults = FaultInjector(plan={"serve_wave": (0,)})
        srv = _server(faults=faults)
        srv.offer_snapshot(str(tmp_path))
        qids = [srv.submit(u, candidates=[1, 2]) for u in (3, 4, 5)]
        with pytest.raises(SimulatedFailure):
            srv.tick()
        assert srv.wave_faults == 1
        assert srv.stats()["queue_depth"] == 3       # nothing dropped
        srv.drain()
        for qid, u in zip(qids, (3, 4, 5)):
            assert np.array_equal(srv.responses[qid].scores,
                                  oracle_scores(phi, u, [1, 2]))
        assert srv.stats()["availability"] == 1.0

    def test_stats_shape(self, tmp_path):
        _ckpt(tmp_path, 0, _phi())
        srv = _server()
        srv.offer_snapshot(str(tmp_path))
        srv.serve([{"u": 1, "candidates": [2]}, {"u": 3, "k": 2}])
        s = srv.stats()
        assert s["served"] == 2 and s["availability"] == 1.0
        assert s["served_by_version"] == {0: 2}
        assert s["served_by_freshness"]["fresh"] == 2
        assert s["latency_p50_s"] >= 0.0
        assert s["offered_total"] == s["admitted"] + s["shed_total"]
