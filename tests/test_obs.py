"""Unified runtime telemetry (DESIGN.md §13).

Four contracts under test:

* the metrics/tracer/recorder substrate itself — bounded reservoirs,
  span nesting, contextvar isolation across the prefetch thread, the
  log_context integration, and the logging-config satellite fixes;
* flight-recorder postmortems — a chaos-injected crash (``wal_append``,
  ``refresh_splice``) must dump a record whose faulting span carries its
  round/shard/graph_version fields;
* RUN_TELEMETRY.json — schema round-trip and validation;
* the non-negotiable invariant: telemetry fully on vs fully off is
  BIT-IDENTICAL in phi and the corpus ring — for a plain run, across a
  divergence heal (lr_backoff=1.0), and across a crash-resume.
"""

import dataclasses
import json
import logging
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.common.logging import get_logger, log_context, refresh_log_level
from repro.core.api import EmbedConfig, make_walk_plan
from repro.core.dsgl import DSGLConfig
from repro.graph.delta import EdgeBatch
from repro.graph.generators import rmat_graph
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.runtime.faults import (FaultInjector, SimulatedFailure,
                                  run_with_restarts)
from repro.runtime.health import HealthConfig, HealthMonitor
from repro.runtime.ingest import IngestConfig, IngestDriver
from repro.runtime.trainer import StreamingEmbedPipeline


def _plan(seed=3, dim=16):
    cfg = dataclasses.replace(EmbedConfig(dim=dim, seed=seed),
                              rng_mode="vertex")
    policy, spec, rounds = make_walk_plan(cfg)
    return policy, spec, rounds, DSGLConfig(dim=dim, seed=seed)


def _pipeline(graph, **kw):
    policy, spec, rounds, dsgl = _plan()
    return StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl, **kw)


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(128, 7, seed=7)


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.reset()
    obs.configure(enabled=True, clear_sinks=True)
    yield
    obs.reset()
    obs.configure(enabled=True, clear_sinks=True)


# --- metrics registry -------------------------------------------------------


class TestMetrics:
    def test_counter_gauge(self):
        obs.inc("x.count")
        obs.inc("x.count", 2.5)
        obs.set_gauge("x.g", 7)
        snap = obs.REGISTRY.snapshot()
        assert snap["counters"]["x.count"] == 3.5
        assert snap["gauges"]["x.g"] == 7.0

    def test_histogram_window_is_bounded(self):
        h = obs.REGISTRY.histogram("x.h", window=8)
        for v in range(100):
            h.observe(v)
        assert len(h.values()) == 8
        assert h.count == 100                      # lifetime count survives
        assert h.min == 0 and h.max == 99
        # Window percentiles are np.percentile over the LAST 8 values.
        assert h.percentile(50) == pytest.approx(
            np.percentile(np.arange(92, 100), 50))

    def test_empty_histogram(self):
        h = obs_metrics.Histogram("empty")
        assert h.percentile(50) is None
        assert h.summary() == {"count": 0}

    def test_disabled_is_noop(self):
        with obs.override(enabled=False):
            obs.inc("gone")
            obs.set_gauge("gone.g", 1)
            obs.observe("gone.h", 1.0)
        snap = obs.REGISTRY.snapshot()
        assert "gone" not in snap["counters"]
        assert "gone.g" not in snap["gauges"]
        assert "gone.h" not in snap["histograms"]

    def test_prometheus_snapshot(self):
        obs.inc("walk.supersteps", 41)
        obs.set_gauge("walk.pool_slots", 256)
        obs.observe("span.walk.round.s", 0.25)
        text = obs.prometheus_snapshot()
        assert "# TYPE repro_walk_supersteps counter" in text
        assert "repro_walk_supersteps 41" in text
        assert "repro_walk_pool_slots 256" in text
        assert 'repro_span_walk_round_s{quantile="0.50"} 0.25' in text

    def test_attach_shares_driver_owned_histogram(self):
        h = obs_metrics.Histogram(window=4)
        obs.REGISTRY.attach("ingest.latency_s", h)
        h.observe(1.0)
        snap = obs.REGISTRY.snapshot()
        assert snap["histograms"]["ingest.latency_s"]["count"] == 1


# --- span tracer ------------------------------------------------------------


class TestTracer:
    def test_nesting_and_recorder_order(self):
        with obs.trace_span("outer", round=1) as f_out:
            with obs.trace_span("inner", shard=2) as f_in:
                assert f_in["parent"] == "outer"
                assert f_in["depth"] == 1
                assert obs.ambient_fields() == {"round": 1, "shard": 2}
            assert obs.current_span() is f_out
        assert obs.current_span() is None
        names = [r["name"] for r in obs.recent()]
        assert names == ["inner", "outer"]         # closed inner-first
        snap = obs.REGISTRY.snapshot()
        assert snap["histograms"]["span.outer.s"]["count"] == 1
        assert snap["histograms"]["span.inner.s"]["count"] == 1

    def test_span_error_marked_and_propagated(self):
        with pytest.raises(ValueError):
            with obs.trace_span("boom"):
                raise ValueError("x")
        rec = obs.recent()[-1]
        assert rec["ok"] is False and rec["error"] == "ValueError"

    def test_span_event_inherits_ambient_fields(self):
        with log_context(shard=3):
            with obs.trace_span("walk.round", round=7):
                obs.span_event("fault.fire", point="superstep")
        ev = [r for r in obs.recent() if r["kind"] == "event"][0]
        assert ev["fields"]["round"] == 7
        assert ev["fields"]["shard"] == 3          # from bare log_context
        assert ev["fields"]["point"] == "superstep"
        assert ev["span"] == "walk.round"

    def test_disabled_span_is_passthrough(self):
        with obs.override(enabled=False):
            with obs.trace_span("off", round=1) as f:
                assert f is None
                assert obs.current_span() is None
        assert obs.recent() == []

    def test_prefetch_thread_contextvar_isolation(self):
        """A span opened on the driver thread must be invisible to the
        prefetch thread (and vice versa) — the Prefetcher pattern in
        runtime.trainer runs fetches on a daemon thread."""
        from repro.data.pipeline import Prefetcher

        seen = []
        started = threading.Event()

        def fetch(step):
            with obs.trace_span("thread.fetch", step=step):
                seen.append(tuple(f["name"] for f in obs.span_stack()))
            started.set()
            return step

        with obs.trace_span("driver.loop", round=0):
            pf = Prefetcher(fetch, depth=1)
            try:
                pf.next()
                started.wait(5.0)
            finally:
                pf.close()
            # Driver-side stack untouched by the thread's spans.
            assert [f["name"] for f in obs.span_stack()] == ["driver.loop"]
        assert seen and all(names == ("thread.fetch",) for names in seen)

    def test_span_jsonl_stream(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        with obs.override(jsonl_path=path):
            with obs.trace_span("walk.round", round=4):
                obs.span_event("tick")
        lines = [json.loads(s) for s in open(path).read().splitlines()]
        assert [r["kind"] for r in lines] == ["event", "span"]
        assert lines[1]["name"] == "walk.round"
        assert lines[1]["fields"]["round"] == 4


# --- logging satellite ------------------------------------------------------


class TestLoggingConfig:
    def test_handler_install_is_idempotent(self):
        root = logging.getLogger("repro")
        get_logger()
        n = len(root.handlers)
        for _ in range(5):
            get_logger("repro.sub")
        assert len(root.handlers) == n

    def test_level_reread_from_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
        assert refresh_log_level() == logging.DEBUG
        assert logging.getLogger("repro").level == logging.DEBUG
        monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
        get_logger()                  # get_logger also re-reads the env
        assert logging.getLogger("repro").level == logging.WARNING
        monkeypatch.delenv("REPRO_LOG_LEVEL")
        refresh_log_level()

    def test_span_close_logs_through_shared_formatter(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
        refresh_log_level()
        records = []

        class Capture(logging.Handler):
            def emit(self, record):
                records.append(record)

        h = Capture(level=logging.DEBUG)
        root = logging.getLogger("repro")
        root.addHandler(h)
        try:
            with obs.trace_span("walk.round", round=9):
                pass
        finally:
            root.removeHandler(h)
            monkeypatch.delenv("REPRO_LOG_LEVEL")
            refresh_log_level()
        close = [r for r in records if "span walk.round" in r.getMessage()]
        assert close, "span close line missing"


# --- flight recorder --------------------------------------------------------


class TestFlightRecorder:
    def test_ring_is_bounded(self):
        obs_recorder.resize(16)
        try:
            for i in range(100):
                obs.span_event("e", i=i)
            recs = obs.recent()
            assert len(recs) == 16
            assert recs[-1]["fields"]["i"] == 99
        finally:
            obs_recorder.resize(obs_recorder.DEFAULT_RING)

    def test_no_dump_without_flight_dir(self):
        assert obs.dump_flight_record("nope") is None

    def test_dump_on_wal_append_fault(self, graph, tmp_path):
        """Chaos-injected WAL crash → on-disk postmortem whose context
        carries the injection point and WAL seq of the dying submit."""
        flight = tmp_path / "flight"
        policy, spec, rounds, dsgl = _plan()
        p = StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl)
        p.run()
        faults = FaultInjector(plan={"wal_append": [0]})
        driver = IngestDriver(str(tmp_path / "ing"), p,
                              cfg=IngestConfig(apply_every=100),
                              faults=faults)
        batch = EdgeBatch(insert=np.array([[1, 2], [3, 4]]))
        with obs.override(flight_dir=str(flight)):
            with pytest.raises(SimulatedFailure):
                driver.submit(batch)
        dumps = sorted(flight.glob("flight_fault_wal_append_*.json"))
        assert len(dumps) == 1
        doc = obs.load_flight_record(str(dumps[0]))
        assert doc["schema"] == "repro.flight_record.v1"
        assert doc["context"]["point"] == "wal_append"
        assert doc["context"]["seq"] == 1           # ingest.submit span field
        assert any(s["name"] == "ingest.submit" for s in doc["open_spans"])
        # The ring holds the durable append that preceded the crash.
        assert any(r["name"] == "ingest.wal_append" for r in doc["ring"])

    def test_dump_on_refresh_splice_fault(self, graph, tmp_path):
        """The acceptance scenario: a refresh_splice crash dumps a record
        whose faulting span carries round + graph_version (+ shard from
        the ambient log_context)."""
        flight = tmp_path / "flight"
        p = _pipeline(graph)
        p.run()
        faults = FaultInjector(plan={"refresh_splice": [0]})
        with obs.override(flight_dir=str(flight)):
            with pytest.raises(SimulatedFailure):
                p.recover_shard_loss(0, faults=faults)
        dumps = sorted(flight.glob("flight_fault_refresh_splice_*.json"))
        assert len(dumps) == 1
        doc = obs.load_flight_record(str(dumps[0]))
        ctx = doc["context"]
        assert ctx["point"] == "refresh_splice"
        assert "round" in ctx and "graph_version" in ctx and "shard" in ctx
        assert ctx["shard"] == 0
        spans = {s["name"]: s for s in doc["open_spans"]}
        assert "refresh.splice" in spans
        assert set(spans["refresh.splice"]["fields"]) >= {
            "round", "graph_version"}
        assert doc["metrics"]["counters"].get("faults.fired.refresh_splice"
                                              ) == 1

    def test_supervisor_restart_events(self):
        calls = []

        def attempt(i):
            calls.append(i)
            if i < 2:
                raise SimulatedFailure("boom")
            return "ok"

        out, restarts = run_with_restarts(attempt)
        assert out == "ok" and restarts == 2
        snap = obs.REGISTRY.snapshot()
        assert snap["counters"]["supervisor.restarts"] == 2
        events = [r for r in obs.recent()
                  if r["name"] == "supervisor.restart"]
        assert len(events) == 2


# --- RUN_TELEMETRY.json -----------------------------------------------------


class TestRunTelemetry:
    def test_round_trip(self, tmp_path):
        obs.inc("walk.supersteps", 17)
        obs.set_gauge("walk.pool_slots", 64)
        obs.observe("span.walk.round.s", 0.5)
        path = str(tmp_path / "RUN_TELEMETRY.json")
        doc = obs.write_run_telemetry(path, run={"bench": "unit",
                                                 "nodes": 128})
        loaded = obs.load_run_telemetry(path)
        assert loaded == json.loads(json.dumps(doc))
        assert loaded["schema"] == "repro.run_telemetry.v1"
        assert loaded["run"]["nodes"] == 128
        assert loaded["counters"]["walk.supersteps"] == 17
        assert loaded["histograms"]["span.walk.round.s"]["count"] == 1

    def test_schema_validation(self, tmp_path):
        path = str(tmp_path / "bad.json")
        with open(path, "w") as f:
            json.dump({"schema": "repro.run_telemetry.v1"}, f)
        with pytest.raises(ValueError, match="missing keys"):
            obs.load_run_telemetry(path)
        with open(path, "w") as f:
            json.dump({"schema": "nope", "run": {}, "counters": {},
                       "gauges": {}, "histograms": {}}, f)
        with pytest.raises(ValueError, match="unknown RUN_TELEMETRY"):
            obs.load_run_telemetry(path)


# --- ingest staleness on the shared reservoir -------------------------------


class TestIngestStaleness:
    def test_latency_histogram_exported(self, graph, tmp_path):
        p = _pipeline(graph)
        p.run()
        driver = IngestDriver(str(tmp_path / "ing"), p,
                              cfg=IngestConfig(apply_every=1))
        driver.submit(EdgeBatch(insert=np.array([[1, 2], [5, 9]])))
        s = driver.staleness()
        assert s["latency_p50_s"] is not None
        # Same reservoir feeds the registry export.
        snap = obs.REGISTRY.snapshot()
        assert snap["histograms"]["ingest.latency_s"]["count"] == 1
        assert snap["histograms"]["ingest.latency_s"]["p50"] == \
            pytest.approx(s["latency_p50_s"])
        assert snap["counters"]["ingest.drains"] >= 1

    def test_staleness_works_with_telemetry_off(self, graph, tmp_path):
        p = _pipeline(graph)
        p.run()
        with obs.override(enabled=False):
            driver = IngestDriver(str(tmp_path / "ing"), p,
                                  cfg=IngestConfig(apply_every=1))
            driver.submit(EdgeBatch(insert=np.array([[1, 2]])))
            s = driver.staleness()
        assert s["latency_p50_s"] is not None      # driver-owned, not gated


# --- the non-negotiable invariant: zero numerical footprint -----------------


def _run_plain(graph, enabled):
    with obs.override(enabled=enabled):
        p = _pipeline(graph)
        p.run()
        phi_in, phi_out = p.embeddings()
        return phi_in, phi_out, np.asarray(p.ring.walks).copy()


def _run_heal(graph, tmp_path, enabled, tag):
    """Divergence → rollback → replay with lr_backoff=1.0 (bit-neutral)."""
    with obs.override(enabled=enabled):
        faults = FaultInjector(inject_plan={"phi_nan": [3]})
        p = _pipeline(graph, health=HealthMonitor(
            HealthConfig(check_every=1, lr_backoff=1.0)))
        p.run(ckpt_root=str(tmp_path / f"heal_{tag}"),
              ckpt_every_rounds=1, faults=faults)
        assert p.health.rollbacks >= 1
        phi_in, phi_out = p.embeddings()
        return phi_in, phi_out, np.asarray(p.ring.walks).copy()


class TestBitIdentityOnVsOff:
    def test_plain_run(self, graph):
        on = _run_plain(graph, True)
        off = _run_plain(graph, False)
        for a, b in zip(on, off):
            np.testing.assert_array_equal(a, b)

    def test_across_heal(self, graph, tmp_path):
        on = _run_heal(graph, tmp_path, True, "on")
        off = _run_heal(graph, tmp_path, False, "off")
        for a, b in zip(on, off):
            np.testing.assert_array_equal(a, b)

    def test_across_resume(self, graph, tmp_path):
        """Telemetry ON for the interrupted+resumed run, OFF for the
        uninterrupted reference — the strongest cross-mode form."""
        policy, spec, rounds, dsgl = _plan()
        off_in, off_out, off_walks = _run_plain(graph, False)
        with obs.override(enabled=True):
            p = StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl)
            root = str(tmp_path / "resume_ckpt")
            p.run(ckpt_root=root, ckpt_every_rounds=1)
            steps = sorted(int(d.split("_")[-1]) for d in os.listdir(root)
                           if d.startswith("step_")
                           and not d.endswith(".tmp"))
            q = StreamingEmbedPipeline.resume(root, policy, spec, dsgl,
                                              step=steps[0])
            q.run()
            phi_in, phi_out = q.embeddings()
            walks = np.asarray(q.ring.walks).copy()
        np.testing.assert_array_equal(phi_in, off_in)
        np.testing.assert_array_equal(phi_out, off_out)
        np.testing.assert_array_equal(walks, off_walks)
