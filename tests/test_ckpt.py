"""Checkpointing: atomicity, bit-exact restore, restart-resume, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (
    latest_step, load_checkpoint, read_meta, restore_into, save_checkpoint,
)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "opt": {"m": jax.random.normal(k2, (8, 4)),
                "count": jnp.int32(7)},
    }


def test_save_restore_bit_exact(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, tree, meta={"data_step": 3})
    step, arrays, meta = load_checkpoint(str(tmp_path))
    assert step == 3 and meta["data_step"] == 3
    restored = restore_into(jax.tree_util.tree_map(jnp.zeros_like, tree),
                            arrays)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_tmp_visible(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    entries = os.listdir(tmp_path)
    assert not any(e.endswith(".tmp") for e in entries)
    assert latest_step(str(tmp_path)) == 2


def test_restore_rejects_shape_mismatch(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    save_checkpoint(str(tmp_path), 1, tree)
    _, arrays, _ = load_checkpoint(str(tmp_path))
    bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
           "opt": {"m": jnp.zeros((8, 4)), "count": jnp.int32(0)}}
    with pytest.raises(ValueError):
        restore_into(bad, arrays)


def test_trainer_restart_is_bit_exact(tmp_path):
    """Crash at step k, restart from checkpoint -> final params identical to
    an uninterrupted run (pure-function data pipeline + saved RNG/cursor)."""
    from repro.configs import get_reduced
    from repro.runtime.trainer import (FailureInjector, Trainer,
                                       TrainerConfig)
    cfg = get_reduced("qwen3_1_7b")
    base = dict(steps=6, ckpt_every=2, batch=2, seq_len=12)

    t1 = Trainer(cfg, TrainerConfig(ckpt_dir=str(tmp_path / "a"), **base))
    out1 = t1.run()

    t2 = Trainer(cfg, TrainerConfig(ckpt_dir=str(tmp_path / "b"), **base),
                 injector=FailureInjector(fail_at_steps=(3,)))
    out2 = t2.run_with_restarts()
    assert out2["restarts"] == 1

    for a, b in zip(jax.tree_util.tree_leaves(out1["state"]["params"]),
                    jax.tree_util.tree_leaves(out2["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stale_tmp_swept_on_next_save(tmp_path):
    """A crashed save's ``step_*.tmp`` debris is removed by the next save
    and never shadows a committed step."""
    tree = _tree(jax.random.PRNGKey(3))
    stale = tmp_path / "step_00000009.tmp"
    stale.mkdir()
    (stale / "leaf_00000.npy").write_bytes(b"partial garbage")
    save_checkpoint(str(tmp_path), 1, tree)
    entries = os.listdir(tmp_path)
    assert not any(e.endswith(".tmp") for e in entries)
    assert latest_step(str(tmp_path)) == 1


def test_torn_manifest_falls_back_to_valid_step(tmp_path):
    """A newest step with a corrupt manifest is invisible: ``latest_step``
    and ``load_checkpoint`` fall back to the newest VALID one."""
    tree = _tree(jax.random.PRNGKey(4))
    save_checkpoint(str(tmp_path), 1, tree, meta={"mark": "good"})
    save_checkpoint(str(tmp_path), 2, tree, meta={"mark": "torn"})
    with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
        f.write('{"step": ')                    # torn mid-write
    assert latest_step(str(tmp_path)) == 1
    step, _, meta = load_checkpoint(str(tmp_path))
    assert step == 1 and meta["mark"] == "good"
    # An EXPLICITLY requested torn step still raises — silently
    # substituting other state would be worse than failing.
    with pytest.raises((OSError, ValueError)):
        load_checkpoint(str(tmp_path), step=2)


def test_missing_leaf_invalidates_step(tmp_path):
    tree = _tree(jax.random.PRNGKey(5))
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    os.remove(tmp_path / "step_00000002" / "leaf_00000.npy")
    assert latest_step(str(tmp_path)) == 1
    step, _, _ = load_checkpoint(str(tmp_path))
    assert step == 1


def test_all_steps_torn_raises(tmp_path):
    tree = _tree(jax.random.PRNGKey(6))
    save_checkpoint(str(tmp_path), 1, tree)
    os.remove(tmp_path / "step_00000001" / "manifest.json")
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path))


def test_read_meta_without_arrays(tmp_path):
    tree = _tree(jax.random.PRNGKey(7))
    save_checkpoint(str(tmp_path), 4, tree, meta={"applied_seq": 17})
    step, meta = read_meta(str(tmp_path))
    assert step == 4 and meta["applied_seq"] == 17
    with pytest.raises(FileNotFoundError):
        read_meta(str(tmp_path / "void"))


def test_elastic_reshard_roundtrip(tmp_path):
    """A checkpoint saved from one topology restores onto another mesh
    (1 device here; shardings resolve to what the mesh supports)."""
    from jax.sharding import PartitionSpec as P
    from repro.ckpt.checkpoint import reshard_to_mesh
    from repro.launch.mesh import make_host_mesh
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    specs = {"w": P("data", "model")}
    save_checkpoint(str(tmp_path), 1, tree)
    _, arrays, _ = load_checkpoint(str(tmp_path))
    restored = restore_into(jax.tree_util.tree_map(jnp.zeros_like, tree),
                            arrays)
    mesh = make_host_mesh(1, 1)
    placed = reshard_to_mesh(restored, mesh, specs)
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.asarray(tree["w"]))
