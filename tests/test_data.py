"""Data pipeline: determinism, prefetch, straggler mitigation."""

import time

import numpy as np

from repro.data.pipeline import (
    BackupShardFetcher, Prefetcher, TokenStream, WalkCorpusStream,
)


def test_token_stream_deterministic():
    s1 = TokenStream(vocab_size=100, batch_per_shard=2, seq_len=8, seed=1)
    s2 = TokenStream(vocab_size=100, batch_per_shard=2, seq_len=8, seed=1)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(s1.batch_at(step)["tokens"],
                                      s2.batch_at(step)["tokens"])
    # different shards -> different data
    s3 = TokenStream(vocab_size=100, batch_per_shard=2, seq_len=8, seed=1,
                     shard_id=1, num_shards=2)
    assert not np.array_equal(s1.batch_at(0)["tokens"],
                              s3.batch_at(0)["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenStream(vocab_size=50, batch_per_shard=1, seq_len=6, seed=0)
    b = s.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (1, 6)


def test_prefetcher_orders_batches():
    s = TokenStream(vocab_size=100, batch_per_shard=1, seq_len=4, seed=0)
    pf = Prefetcher(s.batch_at, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]
    finally:
        pf.close()


def test_backup_fetcher_uses_backup_on_slow_primary():
    s = TokenStream(vocab_size=100, batch_per_shard=1, seq_len=4, seed=0)
    f = BackupShardFetcher(
        primary=s.batch_at, backup=s.batch_at, deadline_s=0.05,
        delay_injector=lambda step: 0.5 if step == 2 else 0.0)
    outs = [f.fetch(i) for i in range(4)]
    assert f.stats["backup"] >= 1
    assert f.stats["primary"] >= 2
    # speculation returns identical data (pure-function batches)
    np.testing.assert_array_equal(outs[2]["tokens"], s.batch_at(2)["tokens"])


def test_walk_corpus_stream_shapes_and_determinism():
    walks = np.arange(200).reshape(20, 10).astype(np.int32)
    st = WalkCorpusStream(walks=walks, group_size=3, multi_windows=2, seed=5)
    b1 = st.batch_at(0, 1)
    b2 = st.batch_at(0, 1)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (3, 2, 10)
    assert st.steps_per_epoch() >= 1
