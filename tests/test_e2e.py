"""End-to-end system tests: embedding quality (paper Table 4 sanity),
generality API (§6.6), corpus invariants."""

import numpy as np
import pytest

from repro.core.api import EmbedConfig, embed_graph
from repro.core.corpus import FrequencyOrder


def _link_prediction_auc(graph, phi_in, phi_out, rng, n_pairs=2000):
    """AUC of dot-product scores: positive edges vs non-edges."""
    from benchmarks.common import link_prediction_auc
    return link_prediction_auc(graph, phi_in, rng, n_pairs=n_pairs)


@pytest.mark.slow
def test_link_prediction_auc(medium_graph, rng):
    """DistGER embeddings must separate edges from non-edges (Table 4: the
    paper reports AUC 0.92-0.98 on real graphs). Paper-regime recipe: grow
    the CORPUS (delta -> more walk rounds) and make one decayed pass — the
    word2vec convention — rather than cycling epochs at high lr."""
    cfg = EmbedConfig(dim=32, epochs=1, lr=0.05, delta=1e-4, max_len=40,
                      min_len=10, window=6, negatives=4)
    phi_in, phi_out = embed_graph(medium_graph, cfg, num_shards=2)
    auc = _link_prediction_auc(medium_graph, phi_in, phi_out, rng)
    assert auc > 0.8, auc


def test_generality_methods_run(small_graph):
    """§6.6: deepwalk / node2vec / huge all run on the same engine, with
    info-centric termination or their routine configuration."""
    for method in ("deepwalk", "node2vec", "huge"):
        cfg = EmbedConfig(method=method, dim=8, epochs=1, max_len=20,
                          min_len=6, p=2.0, q=0.5)
        phi_in, _ = embed_graph(small_graph, cfg)
        assert phi_in.shape == (small_graph.num_nodes, 8)
        assert not np.isnan(phi_in).any(), method


def test_routine_vs_info_corpus_size(small_graph):
    """Info-centric termination generates a SMALLER corpus than routine
    L=80, r=10 (the paper's efficiency source: -63% L, -18% r)."""
    from repro.core.api import sample_corpus
    info = sample_corpus(small_graph, EmbedConfig(
        method="deepwalk", info_termination=True, max_len=80, min_len=8))
    routine = sample_corpus(small_graph, EmbedConfig(
        method="deepwalk", info_termination=False, fixed_len=80,
        fixed_rounds=10))
    assert info.total_tokens < routine.total_tokens


def test_frequency_order_roundtrip(small_graph):
    from repro.core.api import EmbedConfig, sample_corpus
    corpus = sample_corpus(small_graph, EmbedConfig(max_len=20, min_len=6))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    # rank 0 must be the most frequent node
    assert corpus.ocn[order.to_node[0]] == corpus.ocn.max()
    sorted_ocn = order.sorted_ocn
    assert (np.diff(sorted_ocn) <= 0).all()
    # relabel and back (to_node inverts to_rank)
    walks = corpus.walks[:4]
    rr = order.relabel_walks(walks)
    back = np.where(rr >= 0, order.to_node[np.maximum(rr, 0)], -1)
    np.testing.assert_array_equal(back, walks)


def test_hotness_blocks_partition_ranks(small_graph):
    from repro.core.api import EmbedConfig, sample_corpus
    corpus = sample_corpus(small_graph, EmbedConfig(max_len=20, min_len=6))
    order = FrequencyOrder.from_ocn(corpus.ocn)
    starts, ends = order.hotness_blocks()
    assert starts[0] == 0
    assert ends[-1] == len(order.sorted_ocn)
    assert (starts[1:] == ends[:-1]).all()      # contiguous cover
    occ = order.sorted_ocn
    for s, e in zip(starts, ends):
        assert len(set(occ[s:e].tolist())) == 1  # equal-frequency blocks
