"""Checkpointing: atomic save/restore, elastic re-shard."""

from repro.ckpt.checkpoint import (  # noqa: F401
    save_checkpoint, load_checkpoint, restore_into, latest_step,
    reshard_to_mesh,
)
