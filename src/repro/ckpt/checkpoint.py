"""Atomic, elastic checkpointing.

Layout: ``<root>/step_<N>/`` holding one ``.npy`` per tree leaf (keyed by
its tree path) plus ``manifest.json`` (leaf index, dtypes, user metadata:
data cursor, RNG key, mesh shape at save time). Writes go to
``step_<N>.tmp`` and are committed by a single atomic ``rename`` — a
half-written checkpoint is never visible, so crash-during-save is safe
(classic fault-tolerance posture).

Durability: every leaf file and the manifest are fsynced, then the tmp
directory itself, *before* the rename, and the parent directory after it.
Rename-atomicity alone is not enough on a real filesystem — a crash after
the rename can otherwise commit a directory whose data blocks never hit
disk (truncated ``.npy``s behind a valid-looking name). Stale ``.tmp``
directories from crashed saves are swept on the next save.

Reads are defensive: ``latest_step``/``load_checkpoint`` treat a step
directory with a corrupt or missing ``manifest.json`` (or missing leaf
files) as non-existent and fall back to the newest *valid* step — a torn
checkpoint from a pre-fsync writer or a partial copy must cost one
snapshot of progress, not the whole run.

Elastic restore: leaves are saved as FULL (unsharded) host arrays, so a
checkpoint written on one mesh restores onto ANY mesh — ``reshard_to_mesh``
device_puts with the new shardings. (At real 1000-node scale the same
layout shards the .npy files per host; the manifest schema already carries
the mesh shape for that.)
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _sweep_stale_tmp(root: str) -> None:
    """Remove leftover ``step_*.tmp`` dirs from crashed saves."""
    if not os.path.isdir(root):
        return
    for d in os.listdir(root):
        if d.startswith("step_") and d.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def save_checkpoint(
    root: str,
    step: int,
    tree: Any,
    meta: Optional[Dict[str, Any]] = None,
) -> str:
    """Write tree leaves + manifest; fsync everything; atomic rename commit.
    Returns path."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    _sweep_stale_tmp(root)            # includes our own tmp if it survived
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    index = {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or true_dtype not in (
                "float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint64", "uint32", "uint16", "uint8", "bool"):
            # exotic dtypes (bfloat16, fp8): store the raw bits — views are
            # bit-exact, np.save of ml_dtypes is not round-trippable
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
        fpath = os.path.join(tmp, fname)
        with open(fpath, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        index[_path_str(path)] = {
            "file": fname, "dtype": true_dtype, "shape": list(arr.shape)}
    manifest = {"step": step, "leaves": index, "meta": meta or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)                   # leaf entries durable before commit
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)             # atomic commit
    _fsync_dir(root)                  # the rename itself durable
    return final


def _read_manifest(root: str, step: int) -> Optional[Dict[str, Any]]:
    """Manifest of step, or None if the checkpoint is torn/corrupt."""
    d = os.path.join(root, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for info in manifest["leaves"].values():
            if not os.path.exists(os.path.join(d, info["file"])):
                return None
        return manifest
    except (OSError, ValueError, KeyError):
        return None


def _step_candidates(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return sorted(steps, reverse=True)


def latest_step(root: str) -> Optional[int]:
    """Newest step with a VALID manifest (torn checkpoints are skipped)."""
    for step in _step_candidates(root):
        if _read_manifest(root, step) is not None:
            return step
    return None


def prune_steps(root: str, keep_last: int) -> int:
    """Bounded snapshot retention: delete all but the newest ``keep_last``
    VALID checkpoints (torn/corrupt step dirs older than the newest kept
    one are swept too — they can never be restored from). Long-running
    self-healing pipelines snapshot every few rounds forever; without
    retention the checkpoint root grows without bound. Returns the number
    of step directories removed. Never removes the newest valid step, so
    rollback/recovery always keeps a base."""
    keep_last = max(int(keep_last), 1)
    kept = 0
    removed = 0
    for step in _step_candidates(root):
        valid = _read_manifest(root, step) is not None
        if valid and kept < keep_last:
            kept += 1
            continue
        if not valid and kept == 0:
            continue      # torn-but-newest: the reader skips it anyway
        shutil.rmtree(os.path.join(root, f"step_{step:08d}"),
                      ignore_errors=True)
        removed += 1
    if removed:
        _fsync_dir(root)
    return removed


def read_meta(root: str, step: Optional[int] = None
              ) -> Tuple[int, Dict[str, Any]]:
    """(step, meta) of the newest valid checkpoint without loading arrays
    (recovery drivers peek at cursors — e.g. the WAL applied-seq — before
    deciding what to restore)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoints under {root}")
    manifest = _read_manifest(root, step)
    if manifest is None:
        raise FileNotFoundError(
            f"checkpoint step {step} under {root} is missing or torn")
    return manifest["step"], manifest["meta"]


def load_checkpoint(root: str, step: Optional[int] = None,
                    only: Optional[Iterable[str]] = None,
                    ) -> Tuple[int, Dict[str, np.ndarray], Dict[str, Any]]:
    """Returns (step, {path: array}, meta).

    With ``step=None`` the newest VALID checkpoint is loaded — a corrupt
    or missing manifest (a torn write, a partial copy) makes that step
    invisible and the next-newest valid one is used instead. An explicitly
    requested step that is torn still raises (the caller asked for *that*
    state; silently substituting another would be worse than failing).

    ``only`` restricts loading to leaves whose tree path equals one of the
    given prefixes or lives under it (``"phi_in"`` matches ``phi_in`` and
    ``phi_in/..."``). A serving process that just needs the embedding
    tables must not pay for the corpus ring.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def wanted(path: str) -> bool:
        if only is None:
            return True
        return any(path == p or path.startswith(p + "/") for p in only)

    arrays = {
        path: np.load(os.path.join(d, info["file"]))
        for path, info in manifest["leaves"].items() if wanted(path)
    }
    return manifest["step"], arrays, manifest["meta"]


def restore_into(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    """Fill a structurally-matching template tree with loaded leaves."""
    def fill(path, leaf):
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs {leaf.shape}")
        ldt = np.dtype(leaf.dtype)
        if arr.dtype != ldt and arr.dtype.kind in "u" and \
                arr.dtype.itemsize == ldt.itemsize:
            return arr.view(ldt)          # raw-bits view (bfloat16 etc.)
        return arr.astype(ldt)

    return jax.tree_util.tree_map_with_path(fill, template)


def reshard_to_mesh(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """Elastic re-shard: place a (host) tree onto a possibly-different mesh."""
    from repro.dist.sharding import resolve_spec

    def put(leaf, spec):
        s = resolve_spec(spec, mesh, np.shape(leaf))
        return jax.device_put(leaf, NamedSharding(mesh, s))

    return jax.tree_util.tree_map(
        put, tree, specs, is_leaf=lambda s: isinstance(s, P))
