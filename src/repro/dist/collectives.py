"""shard_map/psum forms of the paper's cross-replica exchanges.

``hotness_sync_spmd`` is the SPMD realization of §4.2-III: every device
holds its own replica of the frequency-ordered embedding matrices; one sync
period averages exactly the sampled hotness rows across the replica axis
(O(blocks · d · m) bytes, not O(|V| · d · m)). ``repro.core.sync`` holds
the logical replica-list form with identical semantics.

``compressed_allreduce`` is a top-|g| sparsified all-reduce with error
feedback (residual carried to the next step) — the gradient-volume analogue
of the hotness idea, available to the LM training configs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def hotness_sync_spmd(
    phi_in: jax.Array,    # (N, d) f32 — this replica's matrix (replicated spec)
    phi_out: jax.Array,   # (N, d) f32
    rows: jax.Array,      # (R,) int32 sampled hotness rows
    mesh: Mesh,
    axis: str,
) -> Tuple[jax.Array, jax.Array, float]:
    """Average the sampled rows across the ``axis`` replicas and write them
    back into both matrices. Returns (phi_in', phi_out', bytes_moved)."""
    m = int(mesh.shape[axis])

    def body(pi, po, r):
        mean_in = jax.lax.pmean(pi[r], axis)
        mean_out = jax.lax.pmean(po[r], axis)
        return pi.at[r].set(mean_in), po.at[r].set(mean_out)

    pi2, po2 = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P()), out_specs=(P(), P()),
        check_rep=False,
    )(phi_in, phi_out, rows)
    dim = int(phi_in.shape[-1])
    nbytes = float(int(rows.shape[0]) * dim * 4 * m * 2)
    return pi2, po2, nbytes


def psum_union(tree, mask: jax.Array, axis: str):
    """Exactly-one-sender union exchange over a named axis.

    Every shard contributes its leaves masked by ``mask`` (lanes it is
    sending); the psum reconstructs each lane's payload EXACTLY — including
    negative sentinel values — because at most one shard sends any lane per
    round (all other contributions are literal zeros). This is the
    collective behind the walk engine's InCoM message hand-off
    (``repro.core.shard_engine``): one all-reduce moves the packed
    constant-size messages, and the byte volume measured from the masked
    rows is the paper's Example-1 traffic.

    Must be called inside shard_map / vmap with ``axis`` bound. ``mask`` is
    broadcast against each leaf's leading dimensions.
    """
    def one(x):
        m = mask
        while m.ndim < x.ndim:
            m = m[..., None]
        return jax.lax.psum(jnp.where(m, x, jnp.zeros((), x.dtype)), axis)

    return jax.tree_util.tree_map(one, tree)


def local_mesh(num_devices: int, axis: str) -> "Mesh | None":
    """A 1-axis mesh over the first ``num_devices`` local devices, or None
    when the host has fewer (callers fall back to a stacked vmap emulation
    of the same program)."""
    import numpy as np
    devs = jax.devices()
    if len(devs) < num_devices:
        return None
    return Mesh(np.asarray(devs[:num_devices]), (axis,))


def compressed_allreduce(
    grad: jax.Array,      # per-shard gradient block
    error: jax.Array,     # per-shard error-feedback residual (same shape)
    ratio: float,         # fraction of entries to keep (0 < ratio <= 1)
    axis: str,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k sparsified all-reduce with error feedback.

    Must be called INSIDE shard_map: keeps the largest-|.| ``ratio`` fraction
    of (grad + error), pmeans only those entries across ``axis``, and returns
    the dense synced result plus the residual to carry forward. The sparse
    part + residual always equals grad + error exactly (no signal is lost,
    only delayed)."""
    acc = grad + error
    flat = acc.reshape(-1)
    k = max(int(ratio * flat.shape[0]), 1)
    topk = jax.lax.top_k(jnp.abs(flat), k)[0]
    thresh = topk[-1]
    mask = (jnp.abs(flat) >= thresh).astype(acc.dtype).reshape(acc.shape)
    sparse = acc * mask
    residual = acc - sparse
    synced = jax.lax.pmean(sparse, axis)
    return synced, residual
