"""shard_map/psum forms of the paper's cross-replica exchanges.

``hotness_sync_spmd`` is the SPMD realization of §4.2-III: every device
holds its own replica of the frequency-ordered embedding matrices; one sync
period averages exactly the sampled hotness rows across the replica axis
(O(blocks · d · m) bytes, not O(|V| · d · m)). ``repro.core.sync`` holds
the logical replica-list form with identical semantics.

``compressed_allreduce`` is a top-|g| sparsified all-reduce with error
feedback (residual carried to the next step) — the gradient-volume analogue
of the hotness idea, available to the LM training configs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def hotness_sync_spmd(
    phi_in: jax.Array,    # (N, d) f32 — this replica's matrix (replicated spec)
    phi_out: jax.Array,   # (N, d) f32
    rows: jax.Array,      # (R,) int32 sampled hotness rows
    mesh: Mesh,
    axis: str,
) -> Tuple[jax.Array, jax.Array, float]:
    """Average the sampled rows across the ``axis`` replicas and write them
    back into both matrices. Returns (phi_in', phi_out', bytes_moved)."""
    m = int(mesh.shape[axis])

    def body(pi, po, r):
        mean_in = jax.lax.pmean(pi[r], axis)
        mean_out = jax.lax.pmean(po[r], axis)
        return pi.at[r].set(mean_in), po.at[r].set(mean_out)

    pi2, po2 = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P()), out_specs=(P(), P()),
        check_rep=False,
    )(phi_in, phi_out, rows)
    dim = int(phi_in.shape[-1])
    nbytes = float(int(rows.shape[0]) * dim * 4 * m * 2)
    return pi2, po2, nbytes


def psum_union(tree, mask: jax.Array, axis: str):
    """Exactly-one-sender union exchange over a named axis.

    Every shard contributes its leaves masked by ``mask`` (lanes it is
    sending); the psum reconstructs each lane's payload EXACTLY — including
    negative sentinel values — because at most one shard sends any lane per
    round (all other contributions are literal zeros). This is the
    collective behind the walk engine's InCoM message hand-off
    (``repro.core.shard_engine``): one all-reduce moves the packed
    constant-size messages, and the byte volume measured from the masked
    rows is the paper's Example-1 traffic.

    Must be called inside shard_map / vmap with ``axis`` bound. ``mask`` is
    broadcast against each leaf's leading dimensions.
    """
    def one(x):
        m = mask
        while m.ndim < x.ndim:
            m = m[..., None]
        return jax.lax.psum(jnp.where(m, x, jnp.zeros((), x.dtype)), axis)

    return jax.tree_util.tree_map(one, tree)


def rank_search(csum: jax.Array, queries: jax.Array) -> jax.Array:
    """Unrolled vectorized lower-bound search: for each q in ``queries``
    the first index i with csum[i] >= q. Plain selects + gathers — no
    lax.scan/while (jnp.searchsorted's scan lowering inside a vmapped
    while-loop measured ~1 ms/call on CPU; this is ~10 fused vector ops).
    ``csum`` must be non-decreasing (a mask cumsum)."""
    n = csum.shape[0]
    lo = jnp.zeros(queries.shape, jnp.int32)
    hi = jnp.full(queries.shape, n, jnp.int32)
    for _ in range(max(n, 1).bit_length()):      # ceil(log2(n + 1)) halvings
        mid = (lo + hi) // 2
        go = csum[jnp.clip(mid, 0, n - 1)] < queries
        lo = jnp.where(go, mid + 1, lo)
        hi = jnp.where(go, hi, mid)
    return lo


def take_ranked(payload, mask: jax.Array, count: int):
    """Gather-compact the first ``count`` mask-set lanes, scatter-free.

    Slot j of the output holds the j-th mask-set lane (ascending lane
    order): one cumsum + one vectorized binary search + one gather per
    leaf — no scatter (XLA CPU scatters serialize; this path runs inside
    the walk superstep). Returns (packed leaves with leading dim
    ``count``, valid (count,) bool)."""
    csum = jnp.cumsum(mask.astype(jnp.int32))
    n = csum[-1] if mask.shape[0] else jnp.int32(0)
    j = jnp.arange(count, dtype=jnp.int32)
    src = jnp.clip(rank_search(csum, j + 1), 0, max(mask.shape[0] - 1, 0))
    valid = j < n
    packed = jax.tree_util.tree_map(lambda x: x[src], payload)
    return packed, valid


def packed_all_gather(
    payload,              # pytree of (P, ...) per-lane leaves
    pending: jax.Array,   # (P,) bool — lanes that still need to ship
    cap: int,             # max records per source shard per round
    axis: str,
):
    """Compacted sparse exchange, broadcast transport (stacked path).

    Each shard gather-compacts up to ``cap`` of its pending lanes into a
    (cap, ...) record buffer and one ``lax.all_gather`` publishes it:
    every shard receives (k, cap, ...) — k·cap·fields wire volume instead
    of the dense all-lane psum. Receivers filter records by destination
    themselves (the destination is derivable from the record, e.g.
    owner[cand]). Lanes beyond ``cap`` stay pending for the caller's next
    spill round.

    Returns ``(records, valid, sent)``: records leaves (k, cap, ...) with
    row s = shard s's packed batch, ``valid`` (k, cap) bool, ``sent`` the
    (P,) bool mask of lanes this shard shipped this round.
    """
    rank = jnp.cumsum(pending.astype(jnp.int32)) - 1
    sent = pending & (rank < cap)
    packed, valid = take_ranked(payload, pending, cap)
    records, arr_valid = jax.tree_util.tree_map(
        lambda x: jax.lax.all_gather(x, axis), (packed, valid))
    return records, arr_valid, sent


def packed_all_to_all(
    payload,              # pytree of (P, ...) per-lane leaves
    dest: jax.Array,      # (P,) int32 destination shard per lane
    pending: jax.Array,   # (P,) bool — lanes that still need to ship
    num_shards: int,
    cap: int,             # max records per (source, destination) pair
    axis: str,
):
    """Compacted sparse migrant exchange over a named axis.

    Each shard prefix-scans its ``pending`` lanes per destination, scatters
    the first ``cap`` of each bucket into a (k, cap, ...) send buffer, and
    one ``lax.all_to_all`` swaps the buckets — shard d receives row s =
    the records shard s addressed to d. Wire volume is O(k · cap · fields)
    per shard instead of the dense all-lane psum the walk engine used
    before; lanes beyond ``cap`` stay pending and ship on the caller's next
    spill round (``sent`` reports what left this round, so the caller's
    spill loop terminates: every non-empty bucket moves >= 1 record).

    Works identically under ``vmap`` (stacked emulation — all_to_all has a
    batching rule over named axes) and ``shard_map`` (real point-to-point
    collectives on a mesh).

    Returns ``(arrivals, arr_valid, sent)``: arrivals leaves are
    (k, cap, ...) with row s = records from shard s (zero-filled where
    invalid), ``arr_valid`` is the matching (k, cap) bool validity mask,
    ``sent`` the (P,) bool mask of lanes this shard shipped.
    """
    k = num_shards
    onehot = (dest[None, :] == jnp.arange(k, dtype=dest.dtype)[:, None]) \
        & pending[None, :]                                       # (k, P)
    rank = jnp.cumsum(onehot.astype(jnp.int32), axis=1) - 1      # (k, P)
    rank_of = jnp.sum(jnp.where(onehot, rank, 0), axis=0)        # (P,)
    sent = pending & (rank_of < cap)
    slot = jnp.where(sent, dest * cap + rank_of, k * cap)        # OOB = drop

    def pack(x):
        buf = jnp.zeros((k * cap,) + x.shape[1:], x.dtype)
        buf = buf.at[slot].set(x, mode="drop")
        return buf.reshape((k, cap) + x.shape[1:])

    packed = jax.tree_util.tree_map(pack, payload)
    valid = pack(sent)
    arrivals, arr_valid = jax.tree_util.tree_map(
        lambda b: jax.lax.all_to_all(b, axis, split_axis=0, concat_axis=0),
        (packed, valid))
    return arrivals, arr_valid, sent


def local_mesh(num_devices: int, axis: str) -> "Mesh | None":
    """A 1-axis mesh over the first ``num_devices`` local devices, or None
    when the host has fewer (callers fall back to a stacked vmap emulation
    of the same program)."""
    import numpy as np
    devs = jax.devices()
    if len(devs) < num_devices:
        return None
    return Mesh(np.asarray(devs[:num_devices]), (axis,))


def compressed_allreduce(
    grad: jax.Array,      # per-shard gradient block
    error: jax.Array,     # per-shard error-feedback residual (same shape)
    ratio: float,         # fraction of entries to keep (0 < ratio <= 1)
    axis: str,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k sparsified all-reduce with error feedback.

    Must be called INSIDE shard_map: keeps the largest-|.| ``ratio`` fraction
    of (grad + error), pmeans only those entries across ``axis``, and returns
    the dense synced result plus the residual to carry forward. The sparse
    part + residual always equals grad + error exactly (no signal is lost,
    only delayed)."""
    acc = grad + error
    flat = acc.reshape(-1)
    k = max(int(ratio * flat.shape[0]), 1)
    topk = jax.lax.top_k(jnp.abs(flat), k)[0]
    thresh = topk[-1]
    mask = (jnp.abs(flat) >= thresh).astype(acc.dtype).reshape(acc.shape)
    sparse = acc * mask
    residual = acc - sparse
    synced = jax.lax.pmean(sparse, axis)
    return synced, residual
