"""repro.dist — the SPMD distribution layer.

Four small modules, one contract each:

* ``sharding``    — PartitionSpec vocabulary (batch/model axes) and spec
                    resolution against a concrete mesh (drop missing axes,
                    drop non-divisible dims) -> NamedSharding trees.
* ``context``     — an ambient (mesh, seq_shard) context so model code can
                    pin activations / scan inputs / grad trees without
                    threading a mesh argument through every layer.
* ``collectives`` — shard_map/psum forms of the paper's exchanges: the
                    hotness-block embedding sync (§4.2-III) and a top-k
                    compressed all-reduce with error feedback.
* ``pipeline``    — GPipe-style microbatch pipeline over a mesh axis
                    (ppermute ring), used by the pipeline-parallel configs.

Everything here is importable on a single CPU device: specs resolve to
no-op shardings and the context helpers are identity when no mesh is
active, so the same model code runs from laptop tests to the 512-chip
dry-run unchanged.
"""

from repro.dist import collectives, context, pipeline, sharding  # noqa: F401
