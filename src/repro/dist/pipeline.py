"""GPipe-style pipeline parallelism over one mesh axis.

Stage s of an S-stage pipeline lives on device s of the ``axis`` ring
(stage params sharded ``P(axis)`` on their leading dim). The input batch is
split into M microbatches; the classic (S + M - 1)-tick schedule keeps
every device busy once the pipeline fills, and a ``ppermute`` ring shifts
activations stage -> stage + 1 each tick. Forward matches the sequential
composition of the stages exactly, and reverse-mode differentiates through
the ppermute ring, so grads match the sequential program too (both are
asserted by tests/test_dist.py on 8 fake devices).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def microbatch(x: jax.Array, num_microbatches: int) -> jax.Array:
    """(M*mb, ...) -> (M, mb, ...) microbatch stream."""
    m = num_microbatches
    if x.shape[0] % m != 0:
        raise ValueError(
            f"batch {x.shape[0]} not divisible into {m} microbatches")
    return x.reshape(m, x.shape[0] // m, *x.shape[1:])


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,        # pytree, leaves (S, ...) — leading dim = stage
    xs: jax.Array,            # (M, mb, ...) microbatch stream
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run ``xs`` through S pipelined stages; returns (M, mb, ...) outputs."""
    num_stages = int(mesh.shape[axis])
    num_micro = int(xs.shape[0])
    ring = [(i, (i + 1) % num_stages) for i in range(num_stages)]

    def body(w_blk, stream):
        # w_blk leaves are (1, ...): this device's stage parameters.
        w = jax.tree_util.tree_map(lambda a: a[0], w_blk)
        stage_id = jax.lax.axis_index(axis)
        state = jnp.zeros_like(stream[0])
        outs = jnp.zeros_like(stream)
        for tick in range(num_stages + num_micro - 1):
            feed = stream[tick] if tick < num_micro else jnp.zeros_like(
                stream[0])
            inp = jnp.where(stage_id == 0, feed, state)
            out = stage_fn(w, inp)
            slot = tick - (num_stages - 1)
            if slot >= 0:
                done = jnp.where(stage_id == num_stages - 1, out,
                                 jnp.zeros_like(out))
                outs = outs.at[slot].add(done)
            state = jax.lax.ppermute(out, axis, ring)
        # Only the last stage wrote non-zeros; psum replicates its stream.
        return jax.lax.psum(outs, axis)

    return shard_map(
        body, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
        check_rep=False,
    )(stage_params, xs)
