"""PartitionSpec vocabulary + resolution against a concrete mesh.

The repo writes *production* specs everywhere — batch dims over
``("pod", "data")``, tensor dims over ``"model"`` — and resolves them at
jit-boundary time against whatever mesh is actually present. Resolution
drops axes the mesh does not have (a 1-pod mesh has no "pod" axis) and
axes that do not divide the dimension they shard, so one spec tree serves
every mesh from a single CPU device to the 512-chip multi-pod dry-run.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Production tensor-parallel degree: the "model" axis of the v5e pod mesh.
# Divisibility padding decisions (expert counts, vocab rows) key off this.
PRODUCTION_MODEL_AXIS = 16

# Every batch-parallel dim composes the pod and data axes so pod count
# scales purely additively (launch.mesh docstring).
BATCH_AXES = ("pod", "data")

AxisEntry = Union[None, str, Tuple[str, ...]]


def batch_spec(*rest: AxisEntry) -> P:
    """P((pod, data), *rest) — the canonical batch-leading spec."""
    return P(BATCH_AXES, *rest)


def mesh_axis_size(mesh: Mesh, axis: AxisEntry) -> int:
    """Total device count behind an axis entry (None -> 1, tuples multiply).
    Axes the mesh lacks count as 1, mirroring ``resolve_spec``'s drop."""
    if axis is None:
        return 1
    if isinstance(axis, str):
        return int(mesh.shape.get(axis, 1))
    return int(np.prod([mesh_axis_size(mesh, a) for a in axis], dtype=np.int64))


def _entry_names(entry: AxisEntry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def resolve_spec(spec: P, mesh: Mesh,
                 shape: Optional[Sequence[int]] = None) -> P:
    """Resolve a production spec against a concrete mesh.

    Per dimension entry: keep only axis names the mesh has; if ``shape`` is
    given and the surviving axes' total size does not divide that dim, drop
    the whole entry (replicate) rather than produce an invalid sharding.
    Single-name tuples collapse to the bare name so resolved specs compare
    equal to hand-written ones (P("data"), not P(("data",)))."""
    entries = []
    for i, entry in enumerate(tuple(spec)):
        names = [a for a in _entry_names(entry) if a in mesh.shape]
        if names and shape is not None:
            total = int(np.prod([mesh.shape[a] for a in names], dtype=np.int64))
            if int(shape[i]) % total != 0:
                names = []
        if not names:
            entries.append(None)
        elif len(names) == 1:
            entries.append(names[0])
        else:
            entries.append(tuple(names))
    return P(*entries)


def resolve_specs(tree: Any, mesh: Mesh) -> Any:
    """``resolve_spec`` over a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda s: resolve_spec(s, mesh),
        tree, is_leaf=lambda s: isinstance(s, P))


def _leaf_shape(leaf: Any) -> Tuple[int, ...]:
    shape = getattr(leaf, "shape", None)
    if shape is None:
        shape = np.shape(leaf)
    return tuple(int(s) for s in shape)


def sharding_tree(specs: Any, mesh: Mesh, shapes: Any) -> Any:
    """Resolve a spec tree against a shape tree -> NamedSharding tree.

    ``specs`` may be a single PartitionSpec (broadcast over every leaf of
    ``shapes``) or a tree whose P leaves align with the shape leaves —
    covering both ``sharding_tree(batch_spec("model"), mesh, logits_shape)``
    and full param/opt trees."""
    def resolve_leaf(spec: P, leaf: Any) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(spec, mesh, _leaf_shape(leaf)))

    if isinstance(specs, P):
        return jax.tree_util.tree_map(
            lambda leaf: resolve_leaf(specs, leaf), shapes)
    return jax.tree_util.tree_map(resolve_leaf, specs, shapes,
                                  is_leaf=lambda s: isinstance(s, P))
