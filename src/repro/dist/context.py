"""Ambient activation-sharding context.

Model code deep inside a scan cannot reasonably thread a mesh argument
through every layer, so the jit *caller* opens ``activation_sharding(mesh)``
and the layers call the ``constrain_*`` helpers, which become
``with_sharding_constraint`` under the active mesh and exact no-ops when no
mesh is active (single-device tests, benches).

Two layout rules are encoded here:

* **Megatron-SP** (``seq_shard=True``): between blocks, (B, S, d)
  activations shard the sequence dim over "model" so norms/residuals are
  TP-parallel; inside attention/FFN the matmuls re-gather as needed.
* **Scan inputs stay batch-sharded**: a recurrent scan whose per-step
  slices are sequence-sharded is pathological (every step would be a
  cross-device slice); ``constrain_scan_inputs`` pins the batch dim to the
  batch axes and replicates everything else.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import BATCH_AXES, resolve_spec

_STATE = threading.local()


def current_context() -> Optional[Tuple[Mesh, bool]]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, seq_shard: bool = True):
    """Activate (mesh, seq_shard) for all ``constrain_*`` calls below —
    spanning jit *tracing*, so open it around ``jax.jit(...)`` / ``lower``."""
    prev = current_context()
    _STATE.ctx = (mesh, bool(seq_shard))
    try:
        yield mesh
    finally:
        _STATE.ctx = prev


def _constrain(x: jax.Array, spec: P) -> jax.Array:
    ctx = current_context()
    if ctx is None:
        return x
    mesh, _ = ctx
    resolved = resolve_spec(spec, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, resolved))


def constrain_activations(x: jax.Array) -> jax.Array:
    """Pin a (B, S, d) inter-block activation: batch over (pod, data) and —
    when Megatron-SP is on — sequence over "model"."""
    ctx = current_context()
    if ctx is None:
        return x
    _, seq_shard = ctx
    entries: list = [BATCH_AXES] + [None] * (x.ndim - 1)
    if seq_shard and x.ndim >= 3:
        entries[1] = "model"
    return _constrain(x, P(*entries))


def constrain_scan_inputs(x: jax.Array, batch_dim: int = 0) -> jax.Array:
    """Pin a scan input to batch-sharded-only layout so every step slice is
    device-local (see module docstring)."""
    if current_context() is None:
        return x
    entries: list = [None] * x.ndim
    entries[batch_dim] = BATCH_AXES
    return _constrain(x, P(*entries))


def constrain_tree(tree: Any, specs: Any) -> Any:
    """``with_sharding_constraint`` a whole tree (e.g. grads against the
    param specs during gradient accumulation)."""
    if current_context() is None:
        return tree
    return jax.tree_util.tree_map(_constrain, tree, specs)
