"""Serving launcher CLI (batched prefill + decode over the runtime server).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 6 --new-tokens 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--new-tokens", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    args = p.parse_args()

    from repro.configs import get_config
    from repro.models.zoo import init_params, reduce_config
    from repro.runtime.server import Request, Server, ServerConfig, \
        throughput_stats

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, ServerConfig(batch_slots=args.slots,
                                           max_len=args.max_len))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    done = srv.serve(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done)
    print(json.dumps({"requests": len(done), **throughput_stats(n_tok, dt)}))


if __name__ == "__main__":
    main()
