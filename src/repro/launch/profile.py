"""Dry-run 'profiler': attribute trip-count-scaled HLO bytes/flops to model
regions via op_name metadata (jaxpr paths survive into optimized HLO).

This is the §Perf napkin-math engine: it tells you WHICH subsystem owns the
dominant roofline term before you change anything.
"""

from __future__ import annotations

import re
from collections import Counter
from typing import Dict, Optional, Tuple

from repro.launch.hlo_cost import HloCostModel

REGIONS = (
    # Embedding-pipeline regions first — their op names are the most
    # specific and several shadow later keywords ("train_chunk_checked"
    # contains "train_chunk"; "update_norm" contains "norm"), so order is
    # load-bearing: checked-train before dsgl_train before norm.
    ("train_checked", ("train_chunk_checked", "update_norm", "nonfinite",
                       "health_check")),
    ("dsgl_train", ("train_chunk", "skipgram", "dsgl", "chunk_scan",
                    "neg_sample")),
    ("refresh", ("refresh", "ring_replace", "splice", "rewalk")),
    ("walk_engine", ("walk", "incom", "superstep", "exchange_step",
                     "transition")),
    ("attention", ("attention", "dot_product", "mha", "flash")),
    ("ssd_scan", ("ssd", "mamba", "mixer", "mlstm", "slstm")),
    ("moe", ("moe", "router", "expert")),
    ("mlp", ("mlp", "ffn", "silu", "swiglu")),
    ("loss_vocab", ("unembed", "logsumexp", "log_softmax", "cross_entropy",
                    "nll", "take_along_axis")),
    ("embed", ("embed",)),
    ("norm", ("rmsnorm", "norm")),
    ("optimizer", ("adamw", "opt_update", "clip", "global_norm", "upd")),
    ("rope", ("rope",)),
)


def _region_of(op_name: str) -> str:
    low = op_name.lower()
    for region, keys in REGIONS:
        if any(k in low for k in keys):
            return region
    if "transpose(" in low or "jvp(" in low:
        return "backward_other"
    return "other"


def attribute(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns region -> {bytes, flops, collective_bytes} with while-loop
    trip multipliers applied."""
    m = HloCostModel(hlo_text)
    acc: Dict[str, Counter] = {}

    def bump(region: str, field: str, v: float):
        acc.setdefault(region, Counter())[field] += v

    def walk(name: str, mult: float):
        comp = m.comps.get(name)
        if comp is None:
            return
        in_fusion = name in m.fusion_comps
        for op in comp.ops:
            meta = re.search(r'op_name="([^"]*)"', op.attrs)
            region = _region_of(meta.group(1)) if meta else "other"
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if bm:
                    walk(bm.group(1), mult * m._trip_count(op))
                continue
            if op.opcode == "fusion":
                km = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                bump(region, "bytes", mult * m._fusion_bytes(comp, op))
                if km:
                    bump(region, "flops",
                         mult * m.comp_cost(km.group(1)).flops)
                continue
            if op.opcode == "call":
                am = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if am:
                    walk(am.group(1), mult)
                continue
            base = m._coll_base(op.opcode)
            if base is not None:
                bump(region, "collective_bytes",
                     mult * m._op_coll_bytes(comp, op))
            if not in_fusion:
                if op.opcode == "dynamic-update-slice":
                    from repro.launch.hlo_cost import _type_bytes
                    upd = (comp.types.get(op.args[1], "")
                           if len(op.args) > 1 else "")
                    b = 2.0 * _type_bytes(upd)
                else:
                    b = m._op_bytes(comp, op)
                bump(region, "bytes", mult * b)
            bump(region, "flops", mult * m._op_flops(comp, op))

    walk(m.entry, 1.0)
    return {r: dict(c) for r, c in acc.items()}


def print_profile(hlo_text: str, top: int = 12) -> Dict[str, Dict[str, float]]:
    prof = attribute(hlo_text)
    rows = sorted(prof.items(),
                  key=lambda kv: -kv[1].get("bytes", 0.0))[:top]
    print(f"{'region':16s} {'bytes':>12s} {'flops':>12s} {'coll_bytes':>12s}")
    for region, c in rows:
        print(f"{region:16s} {c.get('bytes', 0):12.3e} "
              f"{c.get('flops', 0):12.3e} "
              f"{c.get('collective_bytes', 0):12.3e}")
    return prof
