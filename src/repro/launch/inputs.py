"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, never allocates device memory — the dry-run
pattern. Three step kinds:

  train   -> {"batch": {...}, "step": ()}                for train_step
  prefill -> {"batch": {...}}                            for prefill_step
  decode  -> {"caches": ..., "token": (B,1), "cache_len": ()}  for serve_step

Enc-dec cells split seq_len as S_src = S_tgt = seq_len // 2 (train/prefill)
and use a CROSS_SRC_LEN encoder memory for decode (models/zoo.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import zoo
from repro.models.config import ModelConfig, SHAPES, ShapeConfig

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.encdec:
        half = s // 2
        batch = {
            "frames": _sds((b, half, cfg.d_model), F32),
            "tokens": _sds((b, half), I32),
            "labels": _sds((b, half), I32),
        }
    else:
        batch = {"tokens": _sds((b, s), I32), "labels": _sds((b, s), I32)}
    return {"batch": batch, "step": _sds((), I32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.encdec:
        half = s // 2
        batch = {
            "frames": _sds((b, half, cfg.d_model), F32),
            "tokens": _sds((b, half), I32),
        }
    else:
        batch = {"tokens": _sds((b, s), I32)}
    return {"batch": batch}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        functools.partial(zoo.init_caches, cfg, b, s))
    return {
        "caches": caches,
        "token": _sds((b, 1), I32),
        "cache_len": _sds((), I32),
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(zoo.init_params, jax.random.PRNGKey(0), cfg))
