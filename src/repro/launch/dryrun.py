import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms.

The two lines ABOVE this docstring must run before any jax import — jax
locks the device count at first init. Do not set the flag globally: smoke
tests and benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Each cell writes a JSON artifact with lower/compile timings, per-device
FLOPs/bytes, collective schedule (op counts + bytes), memory analysis and
the three roofline terms; EXPERIMENTS.md §Dry-run/§Roofline are generated
from these artifacts.
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config, normalize
from repro.dist.context import activation_sharding
from repro.launch import inputs as inputs_mod
from repro.launch import roofline as rf
from repro.launch import steps as steps_mod
from repro.launch.mesh import chips, make_production_mesh
from repro.models.config import SHAPES, shape_applicable

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/artifacts/dryrun")


def dryrun_distger(multi_pod: bool = False,
                   num_nodes: int = 41_652_230,   # Twitter |V| (Table 2)
                   dim: int = 128, g_cnt: int = 4096, w_cnt: int = 2,
                   t_len: int = 80, k_neg: int = 5) -> Dict[str, Any]:
    """The paper's OWN workload on the production mesh: one DSGL lifetime
    step (multi-window shared-negative SGNS) at Twitter scale, embedding
    tables vocab-sharded over "model", lifetimes batched over "data", plus
    the hotness-block sync collective. This is the cell that directly
    rooflines DistGER's contribution."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import resolve_spec
    from repro.launch import roofline as rf

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = chips(mesh)
    num_nodes = -(-num_nodes // 16) * 16     # pad vocab rows for TP16

    def distger_step(phi_in, phi_out, walks, negs, lr):
        from repro.core.dsgl import lifetime_step
        pi, po, loss = lifetime_step.__wrapped__(  # un-jitted inner
            phi_in, phi_out, walks, negs, lr, 10, False)
        # periodic hotness sync modeled as one sampled-row pmean exchange
        return pi, po, loss

    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    args = (
        sds((num_nodes, dim), f32),             # phi_in
        sds((num_nodes, dim), f32),             # phi_out
        sds((g_cnt, w_cnt, t_len), i32),        # walks (rank ids)
        sds((g_cnt, t_len, k_neg), i32),        # negatives
        sds((), f32),                           # lr
    )
    vocab_spec = resolve_spec(P("model", None), mesh, (num_nodes, dim))
    batch_spec_ = resolve_spec(P(("pod", "data"), None, None), mesh,
                               (g_cnt, w_cnt, t_len))
    neg_spec = resolve_spec(P(("pod", "data"), None, None), mesh,
                            (g_cnt, t_len, k_neg))
    in_sh = (NamedSharding(mesh, vocab_spec), NamedSharding(mesh, vocab_spec),
             NamedSharding(mesh, batch_spec_), NamedSharding(mesh, neg_spec),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, vocab_spec), NamedSharding(mesh, vocab_spec),
              NamedSharding(mesh, P()))

    rec: Dict[str, Any] = {"arch": "distger", "shape": "twitter_lifetime",
                           "mesh": dict(mesh.shape), "chips": n_chips,
                           "kind": "train", "status": "ok"}
    t0 = time.time()
    lowered = jax.jit(distger_step, in_shardings=in_sh,
                      out_shardings=out_sh).lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    from repro.launch.hlo_cost import HloCostModel
    cost = HloCostModel(compiled.as_text()).entry_cost()
    terms = rf.roofline_terms(cost.flops, cost.bytes_fused, cost.coll_bytes)
    # useful flops: one lifetime batch trains G*W walks x T positions x
    # 2w context rows x (W+K) targets x 2d MACs, fwd+bwd ~ 3x
    useful = 3 * 2.0 * g_cnt * w_cnt * t_len * 2 * 10 * (w_cnt + k_neg) * dim
    rec.update({
        "per_device_flops": cost.flops,
        "per_device_bytes": cost.bytes_fused,
        "per_device_collective_bytes": cost.coll_bytes,
        "collective_counts": {k: int(v) for k, v in cost.coll_counts.items()},
        **terms,
        "model_flops": useful,
        "roofline_fraction": (useful / n_chips / rf.PEAK_FLOPS)
        / max(terms["step_s_lower_bound"], 1e-12),
    })
    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes)}
    print(ma)
    return rec


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                seq_shard: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = chips(mesh)
    specs = inputs_mod.input_specs(cfg, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "kind": shape.kind, "status": "ok",
    }
    t0 = time.time()
    if shape.kind == "train":
        fn = steps_mod.build_train_step(cfg)
        in_sh, out_sh, (pshapes, oshapes) = steps_mod.train_shardings(
            cfg, mesh, specs)
        args = (pshapes, oshapes, specs["batch"], specs["step"])
    elif shape.kind == "prefill":
        fn = steps_mod.build_prefill_step(cfg, shape.seq_len)
        in_sh, out_sh, pshapes = steps_mod.prefill_shardings(
            cfg, mesh, specs, prefill_fn=fn)
        args = (pshapes, specs["batch"])
    else:  # decode
        fn = steps_mod.build_serve_step(cfg)
        in_sh, out_sh, pshapes = steps_mod.serve_shardings(
            cfg, mesh, specs, serve_fn=fn)
        args = (pshapes, specs["caches"], specs["token"], specs["cache_len"])

    with activation_sharding(mesh, seq_shard=seq_shard and cfg.act_seq_shard):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    rec.update(rf.analyze_compiled(compiled, cfg, shape, n_chips))
    print(compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca[0] if isinstance(ca, list) else ca).items()
           if k in ("flops", "bytes accessed")})
    return rec


def run_cells(archs, shapes, multi_pod: bool, out_dir: str,
              seq_shard: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = "pod2" if multi_pod else "pod1"
    for arch in archs:
        for shape_name in shapes:
            name = f"{normalize(arch)}__{shape_name}__{tag}"
            path = os.path.join(out_dir, name + ".json")
            print(f"=== {name} ===", flush=True)
            t0 = time.time()
            try:
                rec = dryrun_cell(arch, shape_name, multi_pod,
                                  seq_shard=seq_shard)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "status": "failed",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print("FAILED:", rec["error"], flush=True)
            rec["wall_s"] = round(time.time() - t0, 2)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec.get("status")
            if status == "ok":
                print(f"    ok  lower {rec['lower_s']}s compile "
                      f"{rec['compile_s']}s bound={rec['bound']} "
                      f"roofline_frac={rec['roofline_fraction']:.3f}",
                      flush=True)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--no-seq-shard", action="store_true",
                   help="disable Megatron-SP activation sharding (baseline)")
    p.add_argument("--out", default=os.path.normpath(DEFAULT_OUT))
    args = p.parse_args()

    seq_shard = not args.no_seq_shard
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.arch == "distger":
        os.makedirs(args.out, exist_ok=True)
        for mp in meshes:
            tag = "pod2" if mp else "pod1"
            rec = dryrun_distger(multi_pod=mp)
            with open(os.path.join(args.out,
                                   f"distger__twitter_lifetime__{tag}.json"),
                      "w") as f:
                json.dump(rec, f, indent=1)
            print(f"distger {tag}: bound={rec['bound']} "
                  f"compute={rec['compute_s']:.4f}s "
                  f"memory={rec['memory_s']:.4f}s "
                  f"collective={rec['collective_s']:.4f}s")
        return
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for mp in meshes:
        run_cells(archs, shapes, mp, args.out, seq_shard=seq_shard)


if __name__ == "__main__":
    main()
