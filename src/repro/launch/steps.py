"""Step-function + sharding builders: the SAME functions the trainer/server
execute are what the dry-run lowers.

``train_step``  : params, opt_state, batch, step -> params', opt_state', metrics
``prefill_step``: params, batch -> (last-token logits, caches)
``serve_step``  : params, caches, token, cache_len -> (logits, caches')

Gradient accumulation (cfg.grad_accum > 1) scans over microbatches with a
cfg.grad_accum_dtype accumulator — the 405B recipe (bf16 accumulators, bf16
Adam moments, FSDP, remat, Megatron-SP activations).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import resolve_specs, sharding_tree
from repro.models import zoo
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import dtype_of
from repro.optim.optimizers import (
    AdamWConfig, init_opt_state, opt_specs, opt_update,
)
from repro.optim.schedules import cosine_warmup


def default_opt(cfg: ModelConfig) -> AdamWConfig:
    return AdamWConfig(moment_dtype=cfg.opt_state_dtype)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                     total_steps: int = 10_000, lr: float = 3e-4):
    opt_cfg = opt_cfg or default_opt(cfg)
    schedule = cosine_warmup(lr, min(2000, total_steps // 10 + 1), total_steps)
    loss_of = zoo.loss_fn(cfg)
    accum = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch, step):
        if accum > 1:
            from repro.dist.context import constrain_tree
            pspecs = zoo.param_specs(cfg)
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            adt = dtype_of(cfg.grad_accum_dtype)

            def body(gacc, b):
                loss, g = jax.value_and_grad(loss_of)(params, b)
                g = jax.tree_util.tree_map(lambda gg: gg.astype(adt), g)
                # cast to the accumulator dtype BEFORE the cross-data
                # reduction and pin the carry to the FSDP layout — else
                # XLA re-reduces full-f32 weight grads every microbatch
                # (51 TB/device measured on llama3-405b; §Perf)
                g = constrain_tree(g, pspecs)
                gacc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg, gacc, g)
                return constrain_tree(gacc, pspecs), loss

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, adt), params)
            g0 = constrain_tree(g0, pspecs)
            gacc, losses = jax.lax.scan(body, g0, mb)
            grads = jax.tree_util.tree_map(lambda g: g / accum, gacc)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params, opt_state, gnorm = opt_update(
            grads, opt_state, params, opt_cfg, schedule(step))
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int):
    return zoo.prefill_fn(cfg, max_len)


def build_serve_step(cfg: ModelConfig):
    return zoo.decode_fn(cfg)


# ---------------------------------------------------------------------------
# Shardings (resolved NamedSharding trees per mesh)
# ---------------------------------------------------------------------------

def train_shardings(cfg: ModelConfig, mesh: Mesh, specs_in: Dict[str, Any],
                    opt_cfg: Optional[AdamWConfig] = None):
    """Returns (in_shardings, out_shardings) for train_step given the
    input-spec dict from launch.inputs.train_input_specs."""
    opt_cfg = opt_cfg or default_opt(cfg)
    pspecs = zoo.param_specs(cfg)
    pshapes = jax.eval_shape(
        functools.partial(zoo.init_params, jax.random.PRNGKey(0), cfg))
    params_sh = sharding_tree(pspecs, mesh, pshapes)

    ospecs = opt_specs(pspecs, opt_cfg)
    oshapes = jax.eval_shape(
        functools.partial(init_opt_state, pshapes, opt_cfg))
    opt_sh = sharding_tree(ospecs, mesh, oshapes)

    bspecs = zoo.train_batch_specs(cfg)
    batch_sh = sharding_tree(bspecs, mesh, specs_in["batch"])
    step_sh = NamedSharding(mesh, P())

    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "gnorm": NamedSharding(mesh, P())}
    in_sh = (params_sh, opt_sh, batch_sh, step_sh)
    out_sh = (params_sh, opt_sh, metrics_sh)
    return in_sh, out_sh, (pshapes, oshapes)


def prefill_shardings(cfg: ModelConfig, mesh: Mesh, specs_in: Dict[str, Any],
                      prefill_fn=None, max_len: int = 0):
    from repro.dist.sharding import batch_spec
    pspecs = zoo.param_specs(cfg)
    pshapes = jax.eval_shape(
        functools.partial(zoo.init_params, jax.random.PRNGKey(0), cfg))
    params_sh = sharding_tree(pspecs, mesh, pshapes)
    bspecs = {k: v for k, v in zoo.train_batch_specs(cfg).items()
              if k in specs_in["batch"]}
    batch_sh = sharding_tree(bspecs, mesh, specs_in["batch"])
    in_sh = (params_sh, batch_sh)
    # outputs: (last-token logits (B,V) vocab-sharded, caches) — resolved
    # against the ACTUAL output shapes via eval_shape.
    fn = prefill_fn or build_prefill_step(cfg, max_len)
    logits_shape, caches_shapes = jax.eval_shape(
        fn, pshapes, specs_in["batch"])
    logits_sh = sharding_tree(batch_spec("model"), mesh, logits_shape)
    caches_sh = sharding_tree(zoo.cache_specs(cfg), mesh, caches_shapes)
    out_sh = (logits_sh, caches_sh)
    return in_sh, out_sh, pshapes


def serve_shardings(cfg: ModelConfig, mesh: Mesh, specs_in: Dict[str, Any],
                    serve_fn=None):
    from repro.dist.sharding import batch_spec
    pspecs = zoo.param_specs(cfg)
    pshapes = jax.eval_shape(
        functools.partial(zoo.init_params, jax.random.PRNGKey(0), cfg))
    params_sh = sharding_tree(pspecs, mesh, pshapes)
    cspecs = zoo.cache_specs(cfg)
    caches_sh = sharding_tree(cspecs, mesh, specs_in["caches"])
    token_sh = sharding_tree(batch_spec(None), mesh, specs_in["token"])
    clen_sh = NamedSharding(mesh, P())
    fn = serve_fn or build_serve_step(cfg)
    logits_shape, _ = jax.eval_shape(
        fn, pshapes, specs_in["caches"], specs_in["token"],
        specs_in["cache_len"])
    logits_sh = sharding_tree(batch_spec("model"), mesh, logits_shape)
    in_sh = (params_sh, caches_sh, token_sh, clen_sh)
    out_sh = (logits_sh, caches_sh)
    return in_sh, out_sh, pshapes
