"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = collective_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` reports the per-device (post-SPMD) program, so
per-device terms need no further division. Collective bytes are NOT in
cost_analysis: we parse the optimized HLO and sum OPERAND sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(simple size model: one traversal of the payload over the link; ring
constants ~2(N-1)/N are absorbed into the interpretation, stated in
EXPERIMENTS.md).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# dtype[1,2,3]{layout} — layout part optional
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Sum operand bytes of every collective op in (per-device) HLO text."""
    by_op: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op lines look like:  %name = TYPE op-name(OPERANDS), attrs
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        op = m.group(1)
        # normalize all-reduce-start / all-gather-done etc.
        base = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        # operands are inside the call parens; everything before "=" plus the
        # result type also matches _SHAPE_RE, so split at the op name first.
        operands_part = stripped.split(op + "(", 1)[1]
        total = 0
        for dt, dims in _SHAPE_RE.findall(operands_part):
            total += _nbytes(dt, dims)
        by_op[base] += float(total)
    return sum(by_op.values()), by_op


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)\(", line.strip())
        if not m:
            continue
        op = m.group(1)
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                counts[c] += 1
    return counts


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell: 6·N·D train / 2·N·D inference
    (N = active params, D = tokens processed by the step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch        # decode: one token per row


def roofline_terms(
    per_device_flops: float,
    per_device_bytes: float,
    per_device_collective_bytes: float,
) -> Dict[str, float]:
    terms = {
        "compute_s": per_device_flops / PEAK_FLOPS,
        "memory_s": per_device_bytes / HBM_BW,
        "collective_s": per_device_collective_bytes / ICI_BW,
    }
    terms["bound"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    terms["step_s_lower_bound"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms


def analyze_compiled(compiled, cfg, shape, chips: int) -> Dict[str, object]:
    """Extract the full §Roofline record from one compiled artifact.

    Primary costs come from the trip-count-aware HLO text model
    (launch.hlo_cost) — XLA's own cost_analysis() counts while (scan)
    bodies once, understating a 28-layer stack 28x; its numbers are kept
    under xla_cost_analysis for reference."""
    from repro.launch.hlo_cost import HloCostModel

    hlo = compiled.as_text()
    cost = HloCostModel(hlo).entry_cost()
    flops = cost.flops
    byts = cost.bytes_fused
    coll_bytes = cost.coll_bytes
    coll_by_op = dict(cost.coll_by_op)
    coll_counts = {k: int(v) for k, v in cost.coll_counts.items()}
    terms = roofline_terms(flops, byts, coll_bytes)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]

    mf = model_flops(cfg, shape)
    hlo_global_flops = flops * chips
    rec: Dict[str, object] = {
        "per_device_flops": flops,
        "per_device_bytes": byts,
        "per_device_bytes_strict": cost.bytes,
        "per_device_collective_bytes": coll_bytes,
        "collective_bytes_by_op": coll_by_op,
        "collective_counts": coll_counts,
        **terms,
        "model_flops": mf,
        "hlo_global_flops": hlo_global_flops,
        "useful_flops_ratio": (mf / hlo_global_flops) if hlo_global_flops else 0.0,
        "roofline_fraction": (
            (mf / chips / PEAK_FLOPS) / terms["step_s_lower_bound"]
            if terms["step_s_lower_bound"] > 0 else 0.0),
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "while bodies counted once by XLA; see hlo_cost",
        },
    }
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:                      # CPU backend may not support
        rec["memory_analysis"] = {"error": str(e)}
    return rec
