"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but every scan (layer stack, grad-accum, SSD chunks, chunked attention)
lowers to a while loop — so its FLOPs/bytes understate the program by the
trip count (e.g. 28x for a 28-layer stack). The same hole would corrupt
collective-byte sums. This module parses the HLO text into computations
with a per-computation symbol table (operands print WITHOUT inline types in
optimized HLO), evaluates per-computation costs, and multiplies while
bodies by their trip counts (``backend_config.known_trip_count``, falling
back to the loop condition's compare constant).

Cost conventions (per device):
  * flops — dot: 2 x prod(result dims) x prod(contracted dims); counted
    inside fusions too. convolution: 2 x result x kernel-work.
  * bytes — per top-level op: result bytes + operand bytes (symbol-table
    lookup); fusions count boundary operands/result only (XLA convention);
    parameter/constant/tuple/get-tuple-element/bitcast are free.
  * collective bytes — operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute: one payload traversal
    per op (ring constants are interpretation, stated in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(k for k in _DTYPE_BYTES if k != "token")
    + r")\[([0-9,]*)\](?:\{[^}]*\})?")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "opt-barrier", "partition-id", "replica-id"}

# Ops a TPU-grade fusion pass would fold into neighbors. The CPU backend
# leaves many of these standalone, which inflates a naive bytes-accessed sum
# ~5x vs what the TPU compiler would materialize. We therefore track TWO
# byte counters: strict (every top-level op) and fused (elementwise ops
# assumed fused) — the roofline memory term uses `fused` as the TPU
# estimate and reports `strict` as the upper bound.
ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "convert", "broadcast", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "tanh", "sine", "cosine", "sqrt", "rsqrt", "cbrt",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "is-finite",
    "clamp", "iota", "reduce-precision", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "atan2", "erf",
    "logistic", "real", "imag", "complex", "expm1", "log1p", "reverse",
    "concatenate", "pad", "slice",
}

_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")


def _dims(dims_str: str) -> List[int]:
    return [int(d) for d in dims_str.split(",")] if dims_str else []


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_type_and_rest(rest: str) -> Tuple[str, str]:
    """'f32[2,3]{1,0} dot(...)' -> ('f32[2,3]{1,0}', 'dot(...)');
    handles tuple types with nested parens and /*index*/ comments."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:].lstrip()
        return rest, ""
    sp = rest.find(" ")
    if sp < 0:
        return rest, ""
    return rest[:sp], rest[sp + 1:].lstrip()


def _split_top_commas(s: str) -> List[str]:
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    if s[start:].strip():
        out.append(s[start:])
    return out


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0            # strict: every top-level op
    bytes_fused: float = 0.0      # TPU estimate: elementwise assumed fused
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_fused += mult * other.bytes_fused
        self.coll_bytes += mult * other.coll_bytes
        for k in COLLECTIVES:
            self.coll_by_op[k] += mult * other.coll_by_op[k]
            self.coll_counts[k] += mult * other.coll_counts[k]

    def as_dict(self) -> Dict[str, object]:
        return {
            "flops": self.flops, "bytes": self.bytes,
            "bytes_fused": self.bytes_fused,
            "collective_bytes": self.coll_bytes,
            "collective_bytes_by_op": dict(self.coll_by_op),
            "collective_counts": {k: int(v) for k, v in self.coll_counts.items()},
        }


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_type: str
    args: List[str]                   # operand op names (no %)
    attrs: str                        # text after the operand parens
    raw_operands: str = ""            # raw text inside the op parens


class _Comp:
    def __init__(self, name: str, params: Dict[str, str]):
        self.name = name
        self.types: Dict[str, str] = dict(params)   # symbol -> type string
        self.ops: List[_Op] = []


class HloCostModel:
    def __init__(self, text: str):
        self.comps: Dict[str, _Comp] = {}
        self.entry: Optional[str] = None
        self.fusion_comps: set = set()
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str) -> None:
        cur: Optional[_Comp] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _HDR_RE.match(line.strip())
                if m:
                    is_entry, name, params_str = m.groups()
                    params: Dict[str, str] = {}
                    for part in _split_top_commas(params_str):
                        if ":" in part:
                            pname, ptype = part.split(":", 1)
                            params[pname.strip().lstrip("%")] = ptype.strip()
                    cur = _Comp(name, params)
                    self.comps[name] = cur
                    if is_entry:
                        self.entry = name
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OPLINE_RE.match(line)
            if not m:
                continue
            opname, rest = m.groups()
            rtype, tail = _split_type_and_rest(rest)
            om = re.match(r"([a-z][\w\-\$.]*)\(", tail)
            if not om:
                cur.types[opname] = rtype
                continue
            opcode = om.group(1)
            # operand list: up to the matching close paren
            depth, i0 = 0, len(om.group(0)) - 1
            operands_str, attrs = "", ""
            for i in range(i0, len(tail)):
                if tail[i] == "(":
                    depth += 1
                elif tail[i] == ")":
                    depth -= 1
                    if depth == 0:
                        operands_str = tail[i0 + 1: i]
                        attrs = tail[i + 1:]
                        break
            args = re.findall(r"%([\w.\-]+)", operands_str)
            cur.types[opname] = rtype
            op = _Op(opname, opcode, rtype, args, attrs, operands_str)
            cur.ops.append(op)
            km = re.search(r"calls=%?([\w.\-]+)", attrs)
            if km:
                self.fusion_comps.add(km.group(1))

    # --------------------------------------------------------------- helpers
    def _trip_count(self, op: _Op) -> int:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
        if m:
            return int(m.group(1))
        cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
        if cm and cm.group(1) in self.comps:
            best = 1
            for o in self.comps[cm.group(1)].ops:
                if o.opcode == "constant":
                    k = re.search(r"constant\((\d+)\)", o.attrs or "")
                    # constant value prints inside the op parens, re-find:
                    k = k or re.search(r"constant\((\d+)\)", o.result_type)
                    if k:
                        best = max(best, int(k.group(1)))
            return best
        return 1

    def _arg_type(self, comp: _Comp, arg: str) -> str:
        return comp.types.get(arg, "")

    def _op_flops(self, comp: _Comp, op: _Op) -> float:
        if op.opcode == "dot":
            r_elems = 1
            rshapes = _SHAPE_RE.findall(op.result_type)
            if not rshapes:
                return 0.0
            for d in _dims(rshapes[0][1]):
                r_elems *= d
            lhs_type = self._arg_type(comp, op.args[0]) if op.args else ""
            lshapes = _SHAPE_RE.findall(lhs_type)
            lhs_dims = _dims(lshapes[0][1]) if lshapes else []
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
            contract = 1
            if m and m.group(1):
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            return 2.0 * r_elems * contract
        if op.opcode == "convolution":
            rshapes = _SHAPE_RE.findall(op.result_type)
            if not rshapes:
                return 0.0
            r_elems = 1
            for d in _dims(rshapes[0][1]):
                r_elems *= d
            k_type = self._arg_type(comp, op.args[1]) if len(op.args) > 1 else ""
            k_elems = max(_type_bytes(k_type) // 2, 1)   # elems ~ bytes/2 bf16
            rd = _dims(rshapes[0][1])
            out_ch = rd[-1] if rd else 1
            return 2.0 * r_elems * (k_elems / max(out_ch, 1))
        return 0.0

    def _op_bytes(self, comp: _Comp, op: _Op) -> float:
        if op.opcode in FREE_OPS:
            return 0.0
        total = _type_bytes(op.result_type)
        for a in op.args:
            total += _type_bytes(self._arg_type(comp, a))
        return float(total)

    def _fusion_bytes(self, comp: _Comp, op: _Op) -> float:
        """Fusion boundary bytes with slice/in-place-aware accounting.

        Two systematic overcounts to avoid (both arise from scans):
        * operand side — a scan body's fusion takes the WHOLE stacked
          parameter array as an operand but reads one dynamic-slice per
          iteration: charge the sliced bytes, not the buffer;
        * result side — grad-accumulation fusions ROOT in a
          dynamic-update-slice into a stacked buffer, which XLA aliases
          in place: charge 2x the update-slice bytes (read-modify-write),
          not the buffer; the aliased input operand is charged 0.
        """
        km = re.search(r"calls=%?([\w.\-]+)", op.attrs)
        eff, root_bytes, aliased = (
            self._fusion_analysis(km.group(1)) if km else ({}, None, set()))
        total = float(root_bytes if root_bytes is not None
                      else _type_bytes(op.result_type))
        for i, a in enumerate(op.args):
            if i in aliased:
                continue
            full = _type_bytes(self._arg_type(comp, a))
            total += min(eff.get(i, full), full) if i in eff else full
        return total

    def _fusion_analysis(self, fname: str):
        """Returns (param_idx -> effective read bytes,
                    root write bytes or None,
                    set of param indices aliased by in-place DUS roots)."""
        if not hasattr(self, "_fusion_memo"):
            self._fusion_memo = {}
        if fname in self._fusion_memo:
            return self._fusion_memo[fname]
        eff: Dict[int, float] = {}
        root_bytes = None
        aliased: set = set()
        fcomp = self.comps.get(fname)
        if fcomp is not None and fcomp.ops:
            pidx: Dict[str, int] = {}
            for o in fcomp.ops:
                if o.opcode == "parameter":
                    mi = re.match(r"\s*(\d+)", o.raw_operands)
                    pidx[o.name] = int(mi.group(1)) if mi else len(pidx)
            by_name = {o.name: o for o in fcomp.ops}
            root = fcomp.ops[-1]

            def dus_write_bytes(dus: _Op) -> float:
                upd = (by_name.get(dus.args[1]) if len(dus.args) > 1 else None)
                if upd is not None:
                    return 2.0 * _type_bytes(upd.result_type)
                t = fcomp.types.get(dus.args[1], "") if len(dus.args) > 1 else ""
                return 2.0 * _type_bytes(t)

            # root write accounting (DUS roots are in-place)
            dus_ops: List[_Op] = []
            if root.opcode == "dynamic-update-slice":
                root_bytes = dus_write_bytes(root)
                dus_ops = [root]
            elif root.opcode == "tuple":
                rb = 0.0
                for a in root.args:
                    o = by_name.get(a)
                    if o is not None and o.opcode == "dynamic-update-slice":
                        rb += dus_write_bytes(o)
                        dus_ops.append(o)
                    elif o is not None:
                        rb += _type_bytes(o.result_type)
                    else:
                        rb += _type_bytes(fcomp.types.get(a, ""))
                root_bytes = rb

            # operand-side effective reads
            for pname, i in pidx.items():
                consumers = [o for o in fcomp.ops if pname in o.args]
                if not consumers:
                    eff[i] = 0.0
                    continue
                if all(o.opcode in ("dynamic-slice", "slice", "gather")
                       for o in consumers):
                    eff[i] = float(sum(
                        _type_bytes(o.result_type) for o in consumers))
                elif all(o in dus_ops and o.args and o.args[0] == pname
                         for o in consumers):
                    # param is only the in-place destination of a root DUS
                    aliased.add(i)
        self._fusion_memo[fname] = (eff, root_bytes, aliased)
        return self._fusion_memo[fname]

    def _coll_base(self, opcode: str) -> Optional[str]:
        for c in COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-start"):
                return c
        return None

    def _op_coll_bytes(self, comp: _Comp, op: _Op) -> float:
        total = 0.0
        for a in op.args:
            total += _type_bytes(self._arg_type(comp, a))
        return total

    # ------------------------------------------------------------------ eval
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        self._memo[name] = cost
        if comp is None:
            return cost
        in_fusion = name in self.fusion_comps
        for op in comp.ops:
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if bm:
                    cost.add(self.comp_cost(bm.group(1)),
                             mult=float(self._trip_count(op)))
                continue
            if op.opcode == "fusion":
                km = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                b = self._fusion_bytes(comp, op)
                cost.bytes += b
                cost.bytes_fused += b
                if km:
                    cost.flops += self.comp_cost(km.group(1)).flops
                continue
            if op.opcode in ("call", "async-start"):
                am = re.search(r"(?:to_apply|called_computation)=%?([\w.\-]+)",
                               op.attrs)
                if am:
                    cost.add(self.comp_cost(am.group(1)))
                continue
            if op.opcode == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}"
                    r"|true_computation=%?([\w.\-]+)"
                    r"|false_computation=%?([\w.\-]+))", op.attrs)
                names: List[str] = []
                for grp in branches:
                    for g in grp:
                        if g:
                            names.extend(
                                x.strip().lstrip("%") for x in g.split(","))
                if names:
                    branch_costs = [self.comp_cost(n) for n in names]
                    cost.add(max(branch_costs,
                                 key=lambda c: c.flops + c.bytes))
                continue
            cost.flops += self._op_flops(comp, op)
            base = self._coll_base(op.opcode)
            if base is not None:
                cb = self._op_coll_bytes(comp, op)
                cost.coll_bytes += cb
                cost.coll_by_op[base] += cb
                cost.coll_counts[base] += 1
            if not in_fusion:
                if op.opcode == "dynamic-update-slice":
                    # in-place: read+write the update slice, not the buffer
                    upd = (self._arg_type(comp, op.args[1])
                           if len(op.args) > 1 else "")
                    b = 2.0 * _type_bytes(upd)
                elif op.opcode == "dynamic-slice":
                    b = 2.0 * _type_bytes(op.result_type)
                else:
                    b = self._op_bytes(comp, op)
                cost.bytes += b
                if op.opcode not in ELEMENTWISE_OPS:
                    cost.bytes_fused += b
        self._memo[name] = cost
        return cost

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_text(text: str) -> Cost:
    return HloCostModel(text).entry_cost()
