"""Production meshes.

Single pod: (16, 16) over ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.

The "pod" axis composes with "data" for every batch-parallel sharding
(``dist.sharding.BATCH_AXES``), so pod count scales purely additively —
the same specs serve 1 pod or N pods (N × 256 chips; the dry-run proves
N=2 and nothing in the spec tree is pod-count-specific).

Defined as FUNCTIONS so importing this module never touches jax device
state (the 512-device XLA flag is set only by dryrun.py / tests).
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:need]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake) devices the process has —
    used by multi-device tests (8 fake devices)."""
    need = data * model
    devices = np.asarray(jax.devices()[:need]).reshape(data, model)
    return jax.sharding.Mesh(devices, ("data", "model"))


def chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
