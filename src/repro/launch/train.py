"""Training launcher CLI.

CPU-feasible entry point over the same step functions the dry-run lowers:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 20 --batch 4 --seq 64
Use --distger to train graph embeddings (the paper's workload) instead of
an LM arch.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--reduced", action="store_true",
                   help="CPU-smoke config of the same family")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    p.add_argument("--ckpt-every", type=int, default=10)
    p.add_argument("--distger", action="store_true",
                   help="run the paper's graph-embedding workload instead")
    p.add_argument("--graph-nodes", type=int, default=2000)
    p.add_argument("--shards", type=int, default=2)
    args = p.parse_args()

    if args.distger:
        from repro.configs.distger import PAPER_EMBED
        from repro.core.api import embed_graph
        from repro.graph.generators import rmat_graph
        g = rmat_graph(args.graph_nodes, 10, seed=0)
        t0 = time.time()
        phi_in, _ = embed_graph(g, PAPER_EMBED, num_shards=args.shards)
        print(json.dumps({"nodes": g.num_nodes, "edges": g.num_edges,
                          "dim": int(phi_in.shape[1]),
                          "seconds": round(time.time() - t0, 2)}))
        return

    from repro.configs import get_config
    from repro.models.zoo import reduce_config
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, batch=args.batch,
                         seq_len=args.seq)
    out = Trainer(cfg, tcfg).run_with_restarts()
    last = out["metrics"][-1] if out["metrics"] else {}
    print(json.dumps({"final_step": out["final_step"],
                      "restarts": out["restarts"],
                      "last_loss": last.get("loss"),
                      "straggler_stats": out["straggler_stats"]}))


if __name__ == "__main__":
    main()
