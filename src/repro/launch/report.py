"""Generate EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.report \
      --baseline benchmarks/artifacts/dryrun_baseline \
      --optimized benchmarks/artifacts/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional


def load_dir(d: str) -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        rec = json.load(open(path))
        key = os.path.basename(path)[:-5]
        out[key] = rec
    return out


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x * 1e3:.2f}m" if x >= 1e-3 else f"{x * 1e6:.0f}u"


def roofline_table(recs: Dict[str, dict], tag: str = "pod1") -> List[str]:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, r in sorted(recs.items()):
        if not key.endswith(tag):
            continue
        arch, shape, _ = key.rsplit("__", 2)
        if r.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | - | - | - | skipped | - | - |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | - | - | - | FAILED | - | - |")
            continue
        lines.append(
            f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bound'].replace('_s','')} | "
            f"{r.get('useful_flops_ratio', 0.0):.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return lines


def dryrun_table(recs: Dict[str, dict]) -> List[str]:
    lines = [
        "| arch | shape | mesh | lower s | compile s | arg GB | temp GB | "
        "collectives (AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, r in sorted(recs.items()):
        arch, shape, tag = key.rsplit("__", 2)
        mesh = "2x16x16" if tag == "pod2" else "16x16"
        if r.get("status") == "skipped":
            lines.append(
                f"| {arch} | {shape} | {mesh} | - | - | - | - | skipped: "
                f"{r.get('reason','')[:40]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | - | - | - | - | "
                         f"FAILED |")
            continue
        ma = r.get("memory_analysis", {})
        c = r.get("collective_counts", {})
        cc = (f"{c.get('all-reduce',0)}/{c.get('all-gather',0)}/"
              f"{c.get('reduce-scatter',0)}/{c.get('all-to-all',0)}/"
              f"{c.get('collective-permute',0)}")
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r['lower_s']} | "
            f"{r['compile_s']} | {ma.get('argument_bytes', 0)/1e9:.1f} | "
            f"{ma.get('temp_bytes', 0)/1e9:.1f} | {cc} |")
    return lines


def compare_table(base: Dict[str, dict], opt: Dict[str, dict]) -> List[str]:
    lines = [
        "| arch | shape | baseline bound (s) | optimized bound (s) | "
        "speedup | baseline frac | optimized frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        if not key.endswith("pod1"):
            continue
        b, o = base.get(key, {}), opt.get(key, {})
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        arch, shape, _ = key.rsplit("__", 2)
        sb = b["step_s_lower_bound"]
        so = o["step_s_lower_bound"]
        lines.append(
            f"| {arch} | {shape} | {fmt_s(sb)} | {fmt_s(so)} | "
            f"{sb / so:.2f}x | {b['roofline_fraction']:.3f} | "
            f"{o['roofline_fraction']:.3f} |")
    return lines


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--baseline", default="benchmarks/artifacts/dryrun_baseline")
    p.add_argument("--optimized", default="benchmarks/artifacts/dryrun")
    p.add_argument("--section", default="all",
                   choices=("all", "roofline", "dryrun", "compare"))
    args = p.parse_args()

    base = load_dir(args.baseline) if os.path.isdir(args.baseline) else {}
    opt = load_dir(args.optimized)

    if args.section in ("all", "dryrun"):
        print("### Dry-run (optimized build, both meshes)\n")
        print("\n".join(dryrun_table(opt)))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline — optimized build (single pod, 256 chips)\n")
        print("\n".join(roofline_table(opt)))
        print()
        if base:
            print("### Roofline — paper-faithful baseline build\n")
            print("\n".join(roofline_table(base)))
            print()
    if args.section in ("all", "compare") and base:
        print("### Baseline vs optimized (step-time lower bound)\n")
        print("\n".join(compare_table(base, opt)))


if __name__ == "__main__":
    main()
