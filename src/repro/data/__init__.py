"""Host-side data pipeline: deterministic sharded streams, prefetch,
straggler mitigation."""

from repro.data.pipeline import (  # noqa: F401
    TokenStream, WalkCorpusStream, Prefetcher, BackupShardFetcher,
)
