"""Deterministic, sharded, restartable host data pipeline.

Design rules for 1000+-node runs:

* **Pure-function batches**: ``batch_at(step)`` is a pure function of
  (seed, step, shard) — any worker can (re)materialize any batch, which is
  what makes checkpoint-resume bit-exact and backup-shard speculation
  trivially consistent.
* **Prefetch**: a daemon thread keeps a bounded queue of upcoming batches.
* **Straggler mitigation**: ``BackupShardFetcher`` races the primary fetch
  against a backup replica after a deadline; first result wins (both are
  deterministic, so the race is benign). Delay injection hooks let tests
  exercise the policy.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Token stream (LM training)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Synthetic-but-deterministic LM token stream with next-token labels.

    Serves the role of a tokenized corpus reader; batch contents depend only
    on (seed, step, shard_id), never on wall-clock or fetch order.
    """

    vocab_size: int
    batch_per_shard: int
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.shard_id)
        toks = rng.integers(
            0, self.vocab_size,
            size=(self.batch_per_shard, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class WalkCorpusStream:
    """Batches of random-walk lifetimes from a materialized corpus (the
    DistGER learner's input). Shuffle order is a pure function of
    (seed, epoch); the cursor (epoch, step) checkpoints the stream."""

    walks: np.ndarray            # (n_walks, T) int32, -1 padded
    group_size: int              # G lifetimes per batch
    multi_windows: int           # W walks per lifetime
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1

    def _order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7919 + epoch)
        order = rng.permutation(self.walks.shape[0])
        return order[self.shard_id::self.num_shards]

    def steps_per_epoch(self) -> int:
        per = self.group_size * self.multi_windows
        return max(len(self._order(0)) // per, 1)

    def batch_at(self, epoch: int, step: int) -> np.ndarray:
        order = self._order(epoch)
        per = self.group_size * self.multi_windows
        if len(order) < per:   # tiny corpora: tile
            order = np.tile(order, -(-per // max(len(order), 1)))
        lo = (step * per) % max(len(order) - per + 1, 1)
        sel = order[lo:lo + per]
        return self.walks[sel].reshape(
            self.group_size, self.multi_windows, self.walks.shape[1])

    def chunk_at(self, epoch: int, step: int, chunk: int) -> np.ndarray:
        """``chunk`` consecutive batches stacked to (C, G, W, T) — the unit
        the device-resident trainer uploads ONCE per fused-scan dispatch
        (``core.dsgl.train_chunk``) instead of once per lifetime."""
        return np.stack(
            [self.batch_at(epoch, step + c) for c in range(chunk)])


def stacked_shard_chunk(
    streams: "Sequence[WalkCorpusStream]", epoch: int, step: int, chunk: int
) -> np.ndarray:
    """Chunks from every shard's stream stacked to (C, S, G, W, T) — the
    replica-axis layout ``train_chunk`` consumes (shard s trains on its own
    corpus slice; the leading C axis is the fused lax.scan)."""
    return np.stack(
        [s.chunk_at(epoch, step, chunk) for s in streams], axis=1)


def ring_chunk_indices(
    key, base: int, pool: int, count: int, shards: int, groups: int,
    windows: int,
):
    """Device-side (C, S, G, W) ring-slot index tensor.

    Samples ``count`` lifetimes per shard without replacement (tiling when
    the pool is smaller than one chunk) from ring slots
    [``base``, ``base + pool``) — the slot range one walk round (or, for
    the schedule-completion tail, the whole filled ring) occupies. The
    returned indices drive ONE device gather ``ring.walks[idx]`` that
    assembles the (C, S, G, W, T) chunk ``train_chunk`` consumes: walks
    never leave the device between the sampler and the learner.
    """
    import jax
    import jax.numpy as jnp

    need = count * shards * groups * windows
    perm = jax.random.permutation(key, pool)
    if need > pool:
        perm = jnp.resize(perm, (need,))
    return base + perm[:need].reshape(count, shards, groups, windows)


# ---------------------------------------------------------------------------
# Prefetch
# ---------------------------------------------------------------------------

class Prefetcher:
    """Bounded background prefetch over any ``batch_at(step)`` source."""

    def __init__(self, fetch: Callable[[int], object], depth: int = 2,
                 start_step: int = 0):
        self._fetch = fetch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fetch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 60.0):
        return self._q.get(timeout=timeout)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Straggler mitigation: backup-shard speculative fetch
# ---------------------------------------------------------------------------

class BackupShardFetcher:
    """Race a primary fetch against a backup after ``deadline_s``.

    Because batches are pure functions of (step, shard), the backup replica
    produces the identical bytes — speculation never changes training data.
    ``delay_injector(step) -> seconds`` simulates slow primaries in tests.
    """

    def __init__(
        self,
        primary: Callable[[int], object],
        backup: Callable[[int], object],
        deadline_s: float = 0.5,
        delay_injector: Optional[Callable[[int], float]] = None,
    ):
        self.primary = primary
        self.backup = backup
        self.deadline_s = deadline_s
        self.delay_injector = delay_injector
        self.stats = {"primary": 0, "backup": 0}

    def fetch(self, step: int):
        result = {}
        done = threading.Event()

        def run_primary():
            if self.delay_injector:
                time.sleep(self.delay_injector(step))
            out = self.primary(step)
            if not done.is_set():
                result.setdefault("value", out)
                result.setdefault("source", "primary")
                done.set()

        t = threading.Thread(target=run_primary, daemon=True)
        t.start()
        if done.wait(self.deadline_s):
            self.stats["primary"] += 1
            return result["value"]
        # deadline passed: speculative backup fetch
        out = self.backup(step)
        if not done.is_set():
            result.setdefault("value", out)
            result.setdefault("source", "backup")
            done.set()
        if result.get("source") == "backup":
            self.stats["backup"] += 1
        else:
            self.stats["primary"] += 1
        return result["value"]
