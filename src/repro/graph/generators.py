"""Synthetic graph generators.

R-MAT [Chakrabarti et al., SDM'04] is the generator the paper uses for its
scalability study (§6.3, "synthetic graphs with a fixed node degree of 10 and
the number of nodes from 1e5 to 1e9"). We implement it vectorized in numpy.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def rmat_edges(
    num_nodes: int,
    num_edges: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> np.ndarray:
    """Vectorized R-MAT edge sampling. num_nodes is rounded up to a power of 2
    internally; ids are taken mod num_nodes so the output range is exact."""
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(max(num_nodes, 2)))))
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    # Quadrant probabilities (a, b, c, d) with d = 1-a-b-c.
    p_src1 = c + (1.0 - a - b - c)  # P(src bit = 1)
    for level in range(scale):
        src_bit = rng.random(num_edges) < p_src1
        # conditional P(dst bit = 1 | src bit)
        p_dst1_given0 = b / (a + b)
        p_dst1_given1 = (1.0 - a - b - c) / (c + (1.0 - a - b - c))
        p = np.where(src_bit, p_dst1_given1, p_dst1_given0)
        dst_bit = rng.random(num_edges) < p
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    src %= num_nodes
    dst %= num_nodes
    return np.stack([src, dst], axis=1)


def rmat_graph(
    num_nodes: int,
    avg_degree: int = 10,
    *,
    seed: int = 0,
    undirected: bool = True,
    weighted: bool = False,
) -> CSRGraph:
    edges = rmat_edges(num_nodes, num_nodes * avg_degree, seed=seed)
    weights = None
    if weighted:
        # Paper appendix 8.1: weights uniform at random from [1, 5).
        rng = np.random.default_rng(seed + 1)
        weights = rng.uniform(1.0, 5.0, size=len(edges)).astype(np.float32)
    return build_csr(edges, num_nodes, undirected=undirected, weights=weights)


def erdos_renyi_graph(
    num_nodes: int, avg_degree: int = 8, *, seed: int = 0
) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = num_nodes * avg_degree // 2
    edges = rng.integers(0, num_nodes, size=(m, 2), dtype=np.int64)
    return build_csr(edges, num_nodes, undirected=True)


def barabasi_albert_graph(
    num_nodes: int, m: int = 4, *, seed: int = 0
) -> CSRGraph:
    """Preferential attachment — produces the power-law degree distribution
    that HuGE's walk-count heuristic (Eq. 6) assumes."""
    rng = np.random.default_rng(seed)
    if num_nodes <= m:
        raise ValueError("num_nodes must exceed m")
    # Repeated-node list trick for preferential attachment.
    targets = list(range(m))
    repeated: list[int] = []
    edges = []
    for v in range(m, num_nodes):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        idx = rng.integers(0, len(repeated), size=m)
        targets = list({repeated[i] for i in idx})
        while len(targets) < m:
            targets.append(int(rng.integers(0, v + 1)))
            targets = list(set(targets))
    return build_csr(np.asarray(edges, dtype=np.int64), num_nodes, undirected=True)


def connected_rmat_graph(
    num_nodes: int, avg_degree: int = 10, *, seed: int = 0
) -> CSRGraph:
    """R-MAT plus a random ring so every node has degree >= 2 (walkable)."""
    edges = rmat_edges(num_nodes, num_nodes * avg_degree, seed=seed)
    perm = np.random.default_rng(seed + 7).permutation(num_nodes)
    ring = np.stack([perm, np.roll(perm, 1)], axis=1)
    return build_csr(
        np.concatenate([edges, ring], axis=0), num_nodes, undirected=True
    )


def undirected_edges(graph: CSRGraph) -> np.ndarray:
    """(m, 2) undirected edge list (u < v) recovered from the CSR arcs."""
    g = graph.to_numpy()
    indptr = np.asarray(g.indptr, np.int64)
    indices = np.asarray(g.indices, np.int64)
    src = np.repeat(np.arange(len(indptr) - 1, dtype=np.int64),
                    np.diff(indptr))
    keep = src < indices
    return np.stack([src[keep], indices[keep]], axis=1)


def churn_batch(
    graph: CSRGraph,
    frac: float = 0.05,
    *,
    seed: int = 0,
    pool_frac: float = 0.08,
    delete_share: float = 0.04,
):
    """Synthetic LOCALIZED edge churn for dynamic-graph benchmarks/tests.

    Mutates ``frac`` of the undirected edges, concentrated the way real
    churn is (a community updates; a cohort of users joins): all inserts
    and preferentially the deletes fall inside a POOL of the
    ``pool_frac`` lowest-degree (nonzero) vertices, so the affected
    region — and with it the incremental re-walk set — stays a small
    slice of the graph instead of a uniform sprinkle whose endpoints
    alone would touch most vertices. ``delete_share`` of the churn is
    deletions (chosen among pool-incident edges, lowest degree-sum first
    — the edges real decay removes and the ones walks traverse least);
    the rest are fresh intra-pool insertions.

    Returns a ``repro.graph.delta.EdgeBatch``.
    """
    from repro.graph.delta import EdgeBatch

    rng = np.random.default_rng(seed)
    und = undirected_edges(graph)
    deg = np.asarray(graph.degrees(), np.int64)
    n = graph.num_nodes
    n_total = max(1, int(frac * len(und)))
    n_del = max(1, int(n_total * delete_share))
    n_ins = max(0, n_total - n_del)

    nonzero = np.nonzero(deg > 0)[0]
    pool_sz = max(8, int(pool_frac * n))
    pool = nonzero[np.argsort(deg[nonzero], kind="stable")][:pool_sz]
    in_pool = np.zeros(n, bool)
    in_pool[pool] = True

    # Deletes: pool-incident edges, lowest degree-sum first (both-endpoint
    # pool edges sort ahead naturally since pool degrees are smallest).
    cand = und[in_pool[und[:, 0]] | in_pool[und[:, 1]]]
    order = np.argsort(deg[cand[:, 0]] + deg[cand[:, 1]], kind="stable")
    delete = cand[order[:min(n_del, len(cand))]]

    # Inserts: fresh intra-pool pairs.
    existing = set(map(tuple, np.sort(und, axis=1).tolist()))
    dele_set = set(map(tuple, np.sort(delete, axis=1).tolist()))
    seen = set()
    ins = []
    tries = 0
    while len(ins) < n_ins and tries < 50 * max(n_ins, 1):
        tries += 1
        a, b = rng.choice(pool, 2, replace=False)
        key = (min(int(a), int(b)), max(int(a), int(b)))
        if key in existing or key in seen or key in dele_set:
            continue
        seen.add(key)
        ins.append(key)
    insert = (np.asarray(ins, np.int64).reshape(-1, 2)
              if ins else np.zeros((0, 2), np.int64))
    return EdgeBatch(insert=insert, delete=delete)
