from repro.graph.csr import CSRGraph, build_csr, edge_common_neighbors
from repro.graph.delta import DeltaCSR, EdgeBatch
from repro.graph.generators import rmat_graph, erdos_renyi_graph, barabasi_albert_graph
from repro.graph.io import load_edge_list, save_edge_list

__all__ = [
    "CSRGraph",
    "build_csr",
    "edge_common_neighbors",
    "DeltaCSR",
    "EdgeBatch",
    "rmat_graph",
    "erdos_renyi_graph",
    "barabasi_albert_graph",
    "load_edge_list",
    "save_edge_list",
]
