"""Graph statistics used by the paper's heuristics and our tests.

HuGE's walk-count heuristic (Eq. 6) compares the node-degree distribution
p(v) with the corpus-occurrence distribution q(v) via relative entropy; both
distributions live here, together with a power-law tail check.
"""

from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def degree_distribution(graph: CSRGraph) -> np.ndarray:
    """p(v) = deg(v) / sum_deg (Eq. 6 numerator)."""
    deg = np.asarray(graph.degrees(), dtype=np.float64)
    total = deg.sum()
    if total == 0:
        return np.zeros_like(deg)
    return deg / total


def occurrence_distribution(ocn: np.ndarray) -> np.ndarray:
    """q(v) = ocn(v) / sum ocn (Eq. 6 denominator)."""
    ocn = np.asarray(ocn, dtype=np.float64)
    total = ocn.sum()
    if total == 0:
        return np.zeros_like(ocn)
    return ocn / total


def relative_entropy(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """D(p || q) = sum p log(p/q), guarded against zeros (Eq. 6)."""
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    mask = p > 0
    return float(np.sum(p[mask] * np.log((p[mask]) / (q[mask] + eps))))


def powerlaw_alpha_mle(degrees: np.ndarray, dmin: int = 1) -> float:
    """Continuous MLE for the power-law exponent of the degree tail."""
    deg = np.asarray(degrees, dtype=np.float64)
    deg = deg[deg >= dmin]
    if deg.size == 0:
        return float("nan")
    return 1.0 + deg.size / np.sum(np.log(deg / (dmin - 0.5)))


def edge_locality(graph: CSRGraph, assignment: np.ndarray) -> float:
    """Fraction of arcs whose both endpoints land in the same partition.

    This is the quantity MPGP maximizes (a proxy for "walker stays local",
    i.e. fewer cross-machine messages — Fig. 10(c))."""
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    deg = indptr[1:] - indptr[:-1]
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    a = np.asarray(assignment)
    same = a[src] == a[indices]
    return float(np.mean(same)) if len(same) else 1.0


def partition_balance(assignment: np.ndarray, num_parts: int) -> float:
    """max partition size / mean partition size (1.0 = perfectly balanced)."""
    counts = np.bincount(np.asarray(assignment), minlength=num_parts)
    return float(counts.max() / max(counts.mean(), 1e-9))
