"""CSR graph container (paper §2: "DistGER uses the CSR format").

Undirected edges are stored twice (both directions), directed once, exactly
as the paper describes. Neighbor lists are kept **sorted** so that set
intersections (common-neighbor counts, MPGP proximity scores) can use
galloping/binary search.

The container is a pytree of device arrays so it can be donated to jitted
walk kernels, sharded, or kept on host as numpy transparently.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row adjacency.

    indptr:   (|V|+1,) int32  — row offsets
    indices:  (|E|,)   int32  — sorted neighbor ids per row
    weights:  (|E|,)   float32 or None — edge weights (None = unweighted)
    edge_cm:  (|E|,)   int32 or None — per-edge common-neighbor counts
                                       (precomputed; see DESIGN.md §2)
    """

    indptr: jax.Array
    indices: jax.Array
    weights: Optional[jax.Array] = None
    edge_cm: Optional[jax.Array] = None

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.weights, self.edge_cm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- basic properties --------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def num_edges(self) -> int:
        """Number of stored directed arcs (2x undirected edge count)."""
        return int(self.indices.shape[0])

    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    @property
    def max_degree(self) -> int:
        return int(np.max(np.asarray(self.degrees())))

    def neighbors(self, u: int) -> np.ndarray:
        lo, hi = int(self.indptr[u]), int(self.indptr[u + 1])
        return np.asarray(self.indices[lo:hi])

    def to_numpy(self) -> "CSRGraph":
        return CSRGraph(
            indptr=np.asarray(self.indptr),
            indices=np.asarray(self.indices),
            weights=None if self.weights is None else np.asarray(self.weights),
            edge_cm=None if self.edge_cm is None else np.asarray(self.edge_cm),
        )

    def to_device(self) -> "CSRGraph":
        return CSRGraph(
            indptr=jnp.asarray(self.indptr, jnp.int32),
            indices=jnp.asarray(self.indices, jnp.int32),
            weights=None if self.weights is None else jnp.asarray(self.weights, jnp.float32),
            edge_cm=None if self.edge_cm is None else jnp.asarray(self.edge_cm, jnp.int32),
        )

    def with_edge_cm(self) -> "CSRGraph":
        if self.edge_cm is not None:
            return self
        cm = edge_common_neighbors(self)
        return dataclasses.replace(self, edge_cm=jnp.asarray(cm, jnp.int32))


def build_csr(
    edges: np.ndarray,
    num_nodes: Optional[int] = None,
    *,
    undirected: bool = True,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
) -> CSRGraph:
    """Build a CSR graph from an (m, 2) int edge array.

    Self-loops are dropped. With ``undirected=True`` each edge is stored in
    both directions (paper §2). Neighbor lists come out sorted.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edges must be (m, 2), got {edges.shape}")
    mask = edges[:, 0] != edges[:, 1]
    edges = edges[mask]
    w = None
    if weights is not None:
        w = np.asarray(weights, dtype=np.float32)[mask]

    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
        if w is not None:
            w = np.concatenate([w, w], axis=0)

    if num_nodes is None:
        num_nodes = int(edges.max()) + 1 if edges.size else 0

    # Sort by (src, dst) so rows are contiguous and neighbor lists sorted.
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    if w is not None:
        w = w[order]

    if dedup and edges.size:
        keep = np.ones(len(edges), dtype=bool)
        keep[1:] = np.any(edges[1:] != edges[:-1], axis=1)
        edges = edges[keep]
        if w is not None:
            w = w[keep]

    counts = np.bincount(edges[:, 0], minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    return CSRGraph(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(edges[:, 1], jnp.int32),
        weights=None if w is None else jnp.asarray(w, jnp.float32),
        edge_cm=None,
    )


def edge_common_neighbors(graph: CSRGraph) -> np.ndarray:
    """Per-edge common-neighbor counts Cm(u, v), CSR-aligned.

    One sorted-merge intersection per arc. This is the cached form of the
    HuGE transition numerator (Eq. 3); ``repro.core.transition`` also has an
    on-the-fly reference used to validate this precompute.
    """
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    cm = np.zeros(len(indices), dtype=np.int32)
    for u in range(n):
        lo, hi = indptr[u], indptr[u + 1]
        nu = indices[lo:hi]
        if nu.size == 0:
            continue
        for k in range(lo, hi):
            v = indices[k]
            nv = indices[indptr[v]:indptr[v + 1]]
            # galloping-style: binary-search the smaller set into the larger
            if nu.size <= nv.size:
                small, large = nu, nv
            else:
                small, large = nv, nu
            pos = np.searchsorted(large, small)
            pos = np.minimum(pos, large.size - 1)
            cm[k] = int(np.sum(large[pos] == small))
    return cm


def edge_common_neighbors_fast(graph: CSRGraph) -> np.ndarray:
    """Vectorized Cm for all arcs at once (memory: O(|E|*avg_deg) chunked)."""
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    deg = (indptr[1:] - indptr[:-1]).astype(np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = indices
    cm = np.zeros(len(indices), dtype=np.int32)
    # Process arcs in chunks; for each arc, intersect sorted N(u) with N(v)
    # by searching each element of N(u) in N(v).
    chunk = 1 << 16
    for start in range(0, len(dst), chunk):
        end = min(start + chunk, len(dst))
        for k in range(start, end):
            u, v = src[k], dst[k]
            nu = indices[indptr[u]:indptr[u + 1]]
            nv = indices[indptr[v]:indptr[v + 1]]
            if nu.size > nv.size:
                nu, nv = nv, nu
            pos = np.searchsorted(nv, nu)
            pos = np.minimum(pos, nv.size - 1)
            cm[k] = int(np.sum(nv[pos] == nu)) if nv.size else 0
    return cm


def subgraph_partition_pad(
    graph: CSRGraph, assignment: np.ndarray, num_parts: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Split a CSR graph into per-partition padded CSR slices.

    Returns (indptr_p, indices_p, owned_nodes_p, max_nodes) where arrays are
    stacked per partition and padded so every partition has identical shapes
    (required for shard_map). Node ids stay GLOBAL; each partition stores the
    adjacency of the nodes it owns.
    """
    parts = _partition_slices(graph, assignment, num_parts)
    return (parts["indptr"], parts["indices"], parts["owned"],
            parts["max_nodes"])


def _partition_slices(
    graph: CSRGraph, assignment: np.ndarray, num_parts: int
) -> dict:
    """Vectorized per-partition CSR slicing (host numpy, O(|V| + |E|)).

    Within a partition, rows are ordered by ascending GLOBAL node id and
    each row keeps its sorted neighbor list, so the slice row for node v is
    bit-for-bit the global CSR row for v.
    """
    g = graph.to_numpy()
    indptr = np.asarray(g.indptr, np.int64)
    indices = np.asarray(g.indices, np.int64)
    n = len(indptr) - 1
    asn = np.asarray(assignment, np.int64)
    deg = indptr[1:] - indptr[:-1]

    counts = np.bincount(asn, minlength=num_parts)
    max_nodes = max(int(counts.max()), 1) if n else 1
    node_starts = np.zeros(num_parts + 1, np.int64)
    np.cumsum(counts, out=node_starts[1:])
    order = np.argsort(asn, kind="stable")       # ascending ids within part
    local_of = np.empty(max(n, 1), np.int64)
    local_of[order] = np.arange(n) - np.repeat(node_starts[:-1], counts)
    owned = np.full((num_parts, max_nodes), -1, np.int64)
    if n:
        owned[asn, local_of[:n]] = np.arange(n)

    deg_p = np.zeros((num_parts, max_nodes), np.int64)
    if n:
        deg_p[asn, local_of[:n]] = deg
    indptr_p = np.zeros((num_parts, max_nodes + 1), np.int64)
    np.cumsum(deg_p, axis=1, out=indptr_p[:, 1:])

    # Arcs grouped by partition; the original arc order is src-major with
    # ascending src, so a stable sort by partition keeps each partition's
    # arcs in ascending-local-row order — exactly the indptr_p layout.
    src = np.repeat(np.arange(n), deg)
    arc_order = np.argsort(asn[src], kind="stable") if len(src) else src
    e_counts = np.bincount(asn[src], minlength=num_parts).astype(np.int64)
    max_edges = max(int(e_counts.max()), 1) if len(src) else 1
    e_starts = np.zeros(num_parts + 1, np.int64)
    np.cumsum(e_counts, out=e_starts[1:])
    indices_p = np.full((num_parts, max_edges), -1, np.int64)
    arc_p = asn[src][arc_order]
    arc_pos = np.arange(len(src)) - np.repeat(e_starts[:-1], e_counts)
    dst = indices[arc_order]
    if len(src):
        indices_p[arc_p, arc_pos] = dst

    def edge_aligned(values, fill, dtype):
        out = np.full((num_parts, max_edges), fill, dtype)
        if len(src):
            out[arc_p, arc_pos] = values
        return out

    return {
        "indptr": indptr_p, "indices": indices_p, "owned": owned,
        "max_nodes": max_nodes, "local_of": local_of[:n].astype(np.int64),
        "num_owned": counts.astype(np.int64), "deg": deg,
        "arc_dst": dst, "edge_aligned": edge_aligned,
        "arc_order": arc_order,
    }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardCSR:
    """Per-shard padded CSR slice in LOCAL row ids + edge-aligned halo
    metadata (DESIGN.md §9). Stacked form has a leading (k,) axis; inside a
    ``vmap``/``shard_map`` program the leading axis is mapped away and the
    same class holds one shard's slice.

    indptr:    (k, max_nodes+1) int32 — local row offsets
    indices:   (k, max_edges)   int32 — GLOBAL neighbor ids (-1 pad)
    nbr_owner: (k, max_edges)   int32 — owning shard of each neighbor (the
                                        halo remap: owner[] lookups for
                                        candidates never touch a global map)
    nbr_deg:   (k, max_edges)   int32 — degree of each neighbor (HuGE Eq. 3)
    weights:   (k, max_edges)   f32 or None — edge weights, slice-aligned
    edge_cm:   (k, max_edges)   int32 or None — Cm(u,v), slice-aligned
    """

    indptr: jax.Array
    indices: jax.Array
    nbr_owner: jax.Array
    nbr_deg: jax.Array
    weights: Optional[jax.Array] = None
    edge_cm: Optional[jax.Array] = None

    def tree_flatten(self):
        return (self.indptr, self.indices, self.nbr_owner, self.nbr_deg,
                self.weights, self.edge_cm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def take_shard(self) -> "ShardCSR":
        """Drop the leading length-1 axis a shard_map block carries."""
        return jax.tree_util.tree_map(lambda x: x[0], self)


@dataclasses.dataclass(frozen=True)
class PartitionedCSR:
    """Host-level partition-local graph store: stacked ``ShardCSR`` slices
    plus the replicated O(|V|) node metadata the walk engine needs.

    ``slices`` scale as O(|V|/k + |E|/k) per shard — the memory the paper's
    per-partition cost model (Eq. 14–15) budgets per machine; ``local_of``
    (global node -> local row at its owner) is O(|V|) node metadata,
    replicated like the MPGP ``assignment`` itself.
    """

    slices: ShardCSR              # stacked (k, ...) device arrays
    local_of: jax.Array           # (|V|,) int32, replicated
    owned: np.ndarray             # (k, max_nodes) int64, host
    num_owned: np.ndarray         # (k,) int64, host
    num_parts: int

    def shard_csr_nbytes(self) -> np.ndarray:
        """Per-shard bytes of the CSR slice proper (indptr + indices +
        optional weights/cm) — the quantity BENCH_walk reports against the
        |V|/k + |E|/k model."""
        per = (self.slices.indptr.shape[-1] * 4
               + self.slices.indices.shape[-1] * 4)
        if self.slices.weights is not None:
            per += self.slices.weights.shape[-1] * 4
        if self.slices.edge_cm is not None:
            per += self.slices.edge_cm.shape[-1] * 4
        return np.full(self.num_parts, per, np.int64)


def build_partitioned_csr(
    graph: CSRGraph, assignment: np.ndarray, num_parts: int
) -> PartitionedCSR:
    """Build the partition-local store the sharded walk engine runs on.

    Each shard's slice holds the adjacency of the nodes it owns in local
    row ids, with neighbor ids kept global (they name the message
    destination and the path entry) and the per-edge halo metadata —
    neighbor owner and neighbor degree — precomputed so phase A never
    indexes a global O(|E|) array.
    """
    parts = _partition_slices(graph, assignment, num_parts)
    g = graph.to_numpy()
    asn = np.asarray(assignment, np.int64)
    deg = parts["deg"]
    dst = parts["arc_dst"]
    edge_aligned = parts["edge_aligned"]

    nbr_owner = edge_aligned(asn[dst] if len(dst) else dst, -1, np.int64)
    nbr_deg = edge_aligned(deg[dst] if len(dst) else dst, 0, np.int64)
    weights_p = None
    if g.weights is not None:
        w = np.asarray(g.weights, np.float32)[parts["arc_order"]]
        weights_p = edge_aligned(w, 0.0, np.float32)
    edge_cm_p = None
    if g.edge_cm is not None:
        cm = np.asarray(g.edge_cm, np.int64)[parts["arc_order"]]
        edge_cm_p = edge_aligned(cm, 0, np.int64)

    slices = ShardCSR(
        indptr=jnp.asarray(parts["indptr"], jnp.int32),
        indices=jnp.asarray(parts["indices"], jnp.int32),
        nbr_owner=jnp.asarray(nbr_owner, jnp.int32),
        nbr_deg=jnp.asarray(nbr_deg, jnp.int32),
        weights=None if weights_p is None else jnp.asarray(weights_p),
        edge_cm=None if edge_cm_p is None else jnp.asarray(edge_cm_p,
                                                           jnp.int32),
    )
    return PartitionedCSR(
        slices=slices,
        local_of=jnp.asarray(parts["local_of"], jnp.int32),
        owned=parts["owned"],
        num_owned=parts["num_owned"],
        num_parts=num_parts,
    )


def _fit_row(row: np.ndarray, width: int, fill) -> np.ndarray:
    """Pad (with ``fill``) or truncate a 1-D slice row to ``width``. Rebuilt
    partitions change the padded slice dims; survivor rows only ever gain or
    lose PADDING (their real entries always fit), so fit is lossless."""
    if row.shape[0] >= width:
        return row[:width]
    out = np.full(width, fill, dtype=row.dtype)
    out[:row.shape[0]] = row
    return out


def reassign_partitioned_csr(
    graph: CSRGraph,
    new_assignment: np.ndarray,
    num_parts: int,
    *,
    old: PartitionedCSR,
    old_assignment: np.ndarray,
    old_of_new: np.ndarray,
) -> Tuple[PartitionedCSR, int]:
    """Partial rebuild of a ``PartitionedCSR`` after elastic shard
    reconfiguration (DESIGN.md §12).

    Direction-agnostic: ``new_assignment`` is either the COMPACTED
    k-1-way assignment of a shard death (``mpgp.reassign_dead_shard`` +
    ``compact_assignment``) or the k+1-way assignment of a re-JOIN/split
    (``mpgp.rejoin_shard``). ``old`` is the store being replaced and
    ``old_of_new[s]`` maps new shard s back to its original shard id,
    with ``-1`` marking a brand-new shard (re-join). Shards whose node
    set is untouched — neither gained nodes nor (in the split direction)
    donated any — keep their O(|E|/k) slice rows (indices, nbr_deg,
    weights, edge_cm) copied from the old device slices (refit to the
    new padded dims) instead of re-scattered; only changed shards'
    rows rebuild, with the arc scatter masked to their arcs.
    ``nbr_owner`` is recomputed for EVERY shard (any edge into a moved
    node changes owner) straight from the slice's global neighbor ids.
    Node-level layout (owned/local_of/indptr) is O(|V|) vectorized and
    recomputed outright.

    Returns ``(store, reused)`` where ``reused`` counts survivor shards
    whose edge rows were copied, and the store is bit-identical to
    ``build_partitioned_csr(graph, new_assignment, num_parts)``.
    """
    g = graph.to_numpy()
    indptr = np.asarray(g.indptr, np.int64)
    indices = np.asarray(g.indices, np.int64)
    n = len(indptr) - 1
    asn = np.asarray(new_assignment, np.int64)
    old_asn = np.asarray(old_assignment, np.int64)
    old_of_new = np.asarray(old_of_new, np.int64)
    deg = indptr[1:] - indptr[:-1]

    # -- node-level layout (cheap, recomputed) ------------------------------
    counts = np.bincount(asn, minlength=num_parts)
    max_nodes = max(int(counts.max()), 1) if n else 1
    node_starts = np.zeros(num_parts + 1, np.int64)
    np.cumsum(counts, out=node_starts[1:])
    order = np.argsort(asn, kind="stable")
    local_of = np.empty(max(n, 1), np.int64)
    local_of[order] = np.arange(n) - np.repeat(node_starts[:-1], counts)
    owned = np.full((num_parts, max_nodes), -1, np.int64)
    if n:
        owned[asn, local_of[:n]] = np.arange(n)
    deg_p = np.zeros((num_parts, max_nodes), np.int64)
    if n:
        deg_p[asn, local_of[:n]] = deg
    indptr_p = np.zeros((num_parts, max_nodes + 1), np.int64)
    np.cumsum(deg_p, axis=1, out=indptr_p[:, 1:])

    e_counts = np.zeros(num_parts, np.int64)
    np.add.at(e_counts, asn, deg)
    num_edges = int(indptr[-1])
    max_edges = max(int(e_counts.max()), 1) if num_edges else 1

    # -- changed-shard detection (direction-agnostic) -----------------------
    # A node "moved" iff its old shard is not the old counterpart of its
    # new shard (a brand-new shard's -1 counterpart never matches, so all
    # its nodes are moved). A shard rebuilds iff it gained moved nodes
    # (the shard-death direction: orphans stream into survivors) OR, as a
    # surviving shard, lost some (the re-join/split direction: donors
    # stream out). Both reduce to the same two scatters.
    changed = np.zeros(num_parts, dtype=bool)
    if old_of_new.size:
        changed[old_of_new < 0] = True
    if n:
        moved = old_of_new[asn] != old_asn
        if moved.any():
            changed[np.unique(asn[moved])] = True            # gainers
            size = 1 + int(max(old_asn.max(),
                               old_of_new.max() if old_of_new.size else -1))
            new_of_old = np.full(size, -1, np.int64)
            keep = old_of_new >= 0
            new_of_old[old_of_new[keep]] = np.flatnonzero(keep)
            donors = new_of_old[old_asn[moved]]
            donors = donors[donors >= 0]                     # dead → gone
            if donors.size:
                changed[np.unique(donors)] = True            # losers

    has_w = old.slices.weights is not None
    has_cm = old.slices.edge_cm is not None
    indices_p = np.full((num_parts, max_edges), -1, np.int64)
    nbr_deg = np.zeros((num_parts, max_edges), np.int64)
    weights_p = np.zeros((num_parts, max_edges), np.float32) if has_w else None
    edge_cm_p = np.zeros((num_parts, max_edges), np.int64) if has_cm else None

    # -- survivors: copy edge rows from the old device slices ---------------
    reused = 0
    old_indices = np.asarray(old.slices.indices, np.int64)
    old_nbr_deg = np.asarray(old.slices.nbr_deg, np.int64)
    old_w = np.asarray(old.slices.weights, np.float32) if has_w else None
    old_cm = np.asarray(old.slices.edge_cm, np.int64) if has_cm else None
    for s in range(num_parts):
        if changed[s]:
            continue
        o = int(old_of_new[s])
        indices_p[s] = _fit_row(old_indices[o], max_edges, -1)
        nbr_deg[s] = _fit_row(old_nbr_deg[o], max_edges, 0)
        if has_w:
            weights_p[s] = _fit_row(old_w[o], max_edges, 0.0)
        if has_cm:
            edge_cm_p[s] = _fit_row(old_cm[o], max_edges, 0)
        reused += 1

    # -- gainers: masked arc scatter (O(|E_changed|)) -----------------------
    if n and changed.any():
        src = np.repeat(np.arange(n), deg)
        asn_src = asn[src]
        sel = np.flatnonzero(changed[asn_src])
        # Stable sort by shard keeps the ascending-src arc order within each
        # shard — the indptr_p row layout (see _partition_slices).
        sub = sel[np.argsort(asn_src[sel], kind="stable")]
        sub_p = asn_src[sub]
        sub_counts = np.bincount(sub_p, minlength=num_parts)
        sub_starts = np.zeros(num_parts + 1, np.int64)
        np.cumsum(sub_counts, out=sub_starts[1:])
        sub_pos = np.arange(len(sub)) - np.repeat(sub_starts[:-1], sub_counts)
        dst = indices[sub]
        indices_p[sub_p, sub_pos] = dst
        nbr_deg[sub_p, sub_pos] = deg[dst]
        if has_w:
            weights_p[sub_p, sub_pos] = np.asarray(g.weights,
                                                   np.float32)[sub]
        if has_cm:
            edge_cm_p[sub_p, sub_pos] = np.asarray(g.edge_cm, np.int64)[sub]

    # -- nbr_owner: global remap, recomputed for all shards -----------------
    valid = indices_p >= 0
    nbr_owner = np.where(valid, asn[np.where(valid, indices_p, 0)], -1)

    slices = ShardCSR(
        indptr=jnp.asarray(indptr_p, jnp.int32),
        indices=jnp.asarray(indices_p, jnp.int32),
        nbr_owner=jnp.asarray(nbr_owner, jnp.int32),
        nbr_deg=jnp.asarray(nbr_deg, jnp.int32),
        weights=None if weights_p is None else jnp.asarray(weights_p),
        edge_cm=None if edge_cm_p is None else jnp.asarray(edge_cm_p,
                                                           jnp.int32),
    )
    store = PartitionedCSR(
        slices=slices,
        local_of=jnp.asarray(local_of[:n], jnp.int32),
        owned=owned,
        num_owned=counts.astype(np.int64),
        num_parts=num_parts,
    )
    return store, reused
