"""CSR graph container (paper §2: "DistGER uses the CSR format").

Undirected edges are stored twice (both directions), directed once, exactly
as the paper describes. Neighbor lists are kept **sorted** so that set
intersections (common-neighbor counts, MPGP proximity scores) can use
galloping/binary search.

The container is a pytree of device arrays so it can be donated to jitted
walk kernels, sharded, or kept on host as numpy transparently.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row adjacency.

    indptr:   (|V|+1,) int32  — row offsets
    indices:  (|E|,)   int32  — sorted neighbor ids per row
    weights:  (|E|,)   float32 or None — edge weights (None = unweighted)
    edge_cm:  (|E|,)   int32 or None — per-edge common-neighbor counts
                                       (precomputed; see DESIGN.md §2)
    """

    indptr: jax.Array
    indices: jax.Array
    weights: Optional[jax.Array] = None
    edge_cm: Optional[jax.Array] = None

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.weights, self.edge_cm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- basic properties --------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def num_edges(self) -> int:
        """Number of stored directed arcs (2x undirected edge count)."""
        return int(self.indices.shape[0])

    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    @property
    def max_degree(self) -> int:
        return int(np.max(np.asarray(self.degrees())))

    def neighbors(self, u: int) -> np.ndarray:
        lo, hi = int(self.indptr[u]), int(self.indptr[u + 1])
        return np.asarray(self.indices[lo:hi])

    def to_numpy(self) -> "CSRGraph":
        return CSRGraph(
            indptr=np.asarray(self.indptr),
            indices=np.asarray(self.indices),
            weights=None if self.weights is None else np.asarray(self.weights),
            edge_cm=None if self.edge_cm is None else np.asarray(self.edge_cm),
        )

    def to_device(self) -> "CSRGraph":
        return CSRGraph(
            indptr=jnp.asarray(self.indptr, jnp.int32),
            indices=jnp.asarray(self.indices, jnp.int32),
            weights=None if self.weights is None else jnp.asarray(self.weights, jnp.float32),
            edge_cm=None if self.edge_cm is None else jnp.asarray(self.edge_cm, jnp.int32),
        )

    def with_edge_cm(self) -> "CSRGraph":
        if self.edge_cm is not None:
            return self
        cm = edge_common_neighbors(self)
        return dataclasses.replace(self, edge_cm=jnp.asarray(cm, jnp.int32))


def build_csr(
    edges: np.ndarray,
    num_nodes: Optional[int] = None,
    *,
    undirected: bool = True,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
) -> CSRGraph:
    """Build a CSR graph from an (m, 2) int edge array.

    Self-loops are dropped. With ``undirected=True`` each edge is stored in
    both directions (paper §2). Neighbor lists come out sorted.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edges must be (m, 2), got {edges.shape}")
    mask = edges[:, 0] != edges[:, 1]
    edges = edges[mask]
    w = None
    if weights is not None:
        w = np.asarray(weights, dtype=np.float32)[mask]

    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
        if w is not None:
            w = np.concatenate([w, w], axis=0)

    if num_nodes is None:
        num_nodes = int(edges.max()) + 1 if edges.size else 0

    # Sort by (src, dst) so rows are contiguous and neighbor lists sorted.
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    if w is not None:
        w = w[order]

    if dedup and edges.size:
        keep = np.ones(len(edges), dtype=bool)
        keep[1:] = np.any(edges[1:] != edges[:-1], axis=1)
        edges = edges[keep]
        if w is not None:
            w = w[keep]

    counts = np.bincount(edges[:, 0], minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    return CSRGraph(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(edges[:, 1], jnp.int32),
        weights=None if w is None else jnp.asarray(w, jnp.float32),
        edge_cm=None,
    )


def edge_common_neighbors(graph: CSRGraph) -> np.ndarray:
    """Per-edge common-neighbor counts Cm(u, v), CSR-aligned.

    One sorted-merge intersection per arc. This is the cached form of the
    HuGE transition numerator (Eq. 3); ``repro.core.transition`` also has an
    on-the-fly reference used to validate this precompute.
    """
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    cm = np.zeros(len(indices), dtype=np.int32)
    for u in range(n):
        lo, hi = indptr[u], indptr[u + 1]
        nu = indices[lo:hi]
        if nu.size == 0:
            continue
        for k in range(lo, hi):
            v = indices[k]
            nv = indices[indptr[v]:indptr[v + 1]]
            # galloping-style: binary-search the smaller set into the larger
            if nu.size <= nv.size:
                small, large = nu, nv
            else:
                small, large = nv, nu
            pos = np.searchsorted(large, small)
            pos = np.minimum(pos, large.size - 1)
            cm[k] = int(np.sum(large[pos] == small))
    return cm


def edge_common_neighbors_fast(graph: CSRGraph) -> np.ndarray:
    """Vectorized Cm for all arcs at once (memory: O(|E|*avg_deg) chunked)."""
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    deg = (indptr[1:] - indptr[:-1]).astype(np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = indices
    cm = np.zeros(len(indices), dtype=np.int32)
    # Process arcs in chunks; for each arc, intersect sorted N(u) with N(v)
    # by searching each element of N(u) in N(v).
    chunk = 1 << 16
    for start in range(0, len(dst), chunk):
        end = min(start + chunk, len(dst))
        for k in range(start, end):
            u, v = src[k], dst[k]
            nu = indices[indptr[u]:indptr[u + 1]]
            nv = indices[indptr[v]:indptr[v + 1]]
            if nu.size > nv.size:
                nu, nv = nv, nu
            pos = np.searchsorted(nv, nu)
            pos = np.minimum(pos, nv.size - 1)
            cm[k] = int(np.sum(nv[pos] == nu)) if nv.size else 0
    return cm


def subgraph_partition_pad(
    graph: CSRGraph, assignment: np.ndarray, num_parts: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Split a CSR graph into per-partition padded CSR slices.

    Returns (indptr_p, indices_p, owned_nodes_p, max_nodes) where arrays are
    stacked per partition and padded so every partition has identical shapes
    (required for shard_map). Node ids stay GLOBAL; each partition stores the
    adjacency of the nodes it owns.
    """
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    assignment = np.asarray(assignment)
    owned = [np.where(assignment == p)[0] for p in range(num_parts)]
    max_nodes = max((len(o) for o in owned), default=0)
    max_edges = 0
    for o in owned:
        deg = indptr[o + 1] - indptr[o]
        max_edges = max(max_edges, int(deg.sum()))
    indptr_p = np.zeros((num_parts, max_nodes + 1), dtype=np.int64)
    indices_p = np.full((num_parts, max(max_edges, 1)), -1, dtype=np.int64)
    owned_p = np.full((num_parts, max_nodes), -1, dtype=np.int64)
    for p, o in enumerate(owned):
        owned_p[p, : len(o)] = o
        off = 0
        for i, u in enumerate(o):
            lo, hi = indptr[u], indptr[u + 1]
            indices_p[p, off : off + (hi - lo)] = indices[lo:hi]
            off += hi - lo
            indptr_p[p, i + 1] = off
        indptr_p[p, len(o) + 1 :] = off
    return indptr_p, indices_p, owned_p, max_nodes
