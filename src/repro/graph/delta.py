"""Delta-CSR edge churn: batched insert/delete overlay over ``CSRGraph``.

DistGER's incremental claim (and the NOMAD lesson in PAPERS.md) is that a
serving-scale embedding system must absorb graph deltas without paying a
full rebuild per batch. This module is the storage half of that lifecycle
(``repro.core.incremental`` is the refresh half):

* ``EdgeBatch`` — one batch of undirected edge inserts/deletes (host numpy;
  churn arrives from the outside world, not from a device program).
* ``DeltaCSR`` — an overlay on a base ``CSRGraph``. Applying a batch is
  O(|Δ| log |E|) (deletes tombstone base arcs located by one vectorized
  binary search over the row-major arc codes; inserts append to a pending
  arc list) — no O(|E|) work per batch. The merged ``graph()`` view is
  built by ONE vectorized compaction (lexsort + bincount over
  surviving + pending arcs) when first asked for, cached until the next
  mutation, and promoted into the new base by ``compact()`` once pending
  churn passes ``compact_threshold``. Rows stay sorted, so every consumer
  of the CSR contract — galloping intersections, MPGP proximity scores,
  ``build_partitioned_csr``'s slice/halo layout — works unmodified.
* ``incremental_edge_cm`` — Cm(u, v) refresh that recomputes only arcs
  with a TOUCHED endpoint (N(u) or N(v) changed) and gathers every other
  value from the old graph: churn touching t vertices costs
  O(deg(t) · log deg) instead of the O(|E| · deg) full precompute.
* ``graph_version`` / ``bump_graph_version`` — a monotonic per-object
  mutation counter the walk-engine caches key on, so a graph mutated
  through the overlay can never be served a stale ``PartitionedCSR`` or
  occupancy-cached slot pool (see ``shard_engine.partitioned_csr_for``).

The overlay itself is immutable-by-construction toward consumers: a served
``CSRGraph`` view is never mutated in place — mutation invalidates the
cached view and the next ``graph()`` call builds a fresh object (whose
version starts ahead of the retired view's, covering id() reuse).
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Optional, Tuple

import numpy as np

from repro.graph.csr import CSRGraph


# ---------------------------------------------------------------------------
# Graph mutation versions (cache-invalidation contract)
# ---------------------------------------------------------------------------

# id(graph) -> [version, weakref]. The weakref guards id() recycling: a
# dead referent means the id may belong to a brand-new object, which must
# start from a version later than anything the dead object ever reported.
_VERSIONS: dict = {}
_NEXT_VERSION = [1]


def graph_version(graph: object) -> int:
    """Monotonic mutation counter for ``graph`` (0 = never registered).

    Cache keys that pair ``id(graph)`` with ``graph_version(graph)`` stay
    correct even against in-place mutation of a held object: any code that
    changes a graph's content through the delta layer bumps its version.
    """
    ent = _VERSIONS.get(id(graph))
    if ent is None or ent[1]() is not graph:
        return 0
    return ent[0]


def bump_graph_version(graph: object) -> int:
    """Register a new mutation of ``graph``; returns the new version."""
    v = _NEXT_VERSION[0]
    _NEXT_VERSION[0] += 1
    _VERSIONS[id(graph)] = [v, weakref.ref(graph)]
    if len(_VERSIONS) > 256:  # drop dead entries, bounded housekeeping
        dead = [k for k, e in _VERSIONS.items() if e[1]() is None]
        for k in dead:
            _VERSIONS.pop(k, None)
    return v


# ---------------------------------------------------------------------------
# Edge batches
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """One batch of undirected edge churn (host numpy).

    insert:  (mi, 2) int — edges to add (self-loops dropped, duplicates of
             existing edges ignored).
    delete:  (md, 2) int — edges to remove (missing edges ignored).
    insert_weights: optional (mi,) f32 weights for the inserted edges.
    """

    insert: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))
    delete: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))
    insert_weights: Optional[np.ndarray] = None

    def __post_init__(self):
        object.__setattr__(self, "insert",
                           np.asarray(self.insert, np.int64).reshape(-1, 2))
        object.__setattr__(self, "delete",
                           np.asarray(self.delete, np.int64).reshape(-1, 2))
        if self.insert_weights is not None:
            object.__setattr__(
                self, "insert_weights",
                np.asarray(self.insert_weights, np.float32).reshape(-1))

    @property
    def num_changes(self) -> int:
        return int(len(self.insert) + len(self.delete))

    def changed_edges(self) -> np.ndarray:
        """(m, 2) union of inserted + deleted edges (one direction each)."""
        return np.concatenate([self.insert, self.delete], axis=0)


def validate_edge_batch(
    batch: EdgeBatch,
    num_nodes: int,
    *,
    self_loops: str = "drop",
    duplicates: str = "allow",
) -> EdgeBatch:
    """Admission control for churn batches — runs in ``IngestDriver.submit``
    BEFORE the WAL append, so a malformed batch is rejected with a clear
    error instead of becoming durable and poisoning every future replay of
    the log (a WAL record that crashes ``apply`` crashes recovery forever).

    Always rejected: out-of-range or negative vertex ids, non-finite
    insert weights, a weights vector whose length disagrees with
    ``insert``. Policy-controlled: ``self_loops`` and ``duplicates``
    (repeated undirected pairs WITHIN the batch) are each ``"drop"``
    (silently filtered), ``"forbid"`` (raise), or ``"allow"`` (pass
    through; downstream CSR semantics drop self-loops and dedup arcs
    anyway). Returns the (possibly filtered) batch.
    """
    if self_loops not in ("drop", "forbid", "allow"):
        raise ValueError(f"unknown self_loops policy {self_loops!r}")
    if duplicates not in ("drop", "forbid", "allow"):
        raise ValueError(f"unknown duplicates policy {duplicates!r}")

    for name in ("insert", "delete"):
        arr = getattr(batch, name)
        if arr.size and (arr.min() < 0 or arr.max() >= num_nodes):
            bad = arr[np.any((arr < 0) | (arr >= num_nodes), axis=1)]
            raise ValueError(
                f"EdgeBatch.{name}: {len(bad)} edge(s) reference vertices "
                f"outside [0, {num_nodes}), e.g. {bad[0].tolist()}")
    w = batch.insert_weights
    if w is not None:
        if len(w) != len(batch.insert):
            raise ValueError(
                f"EdgeBatch.insert_weights has {len(w)} entries for "
                f"{len(batch.insert)} inserted edges")
        if not np.all(np.isfinite(w)):
            bad = int(np.sum(~np.isfinite(w)))
            raise ValueError(
                f"EdgeBatch.insert_weights: {bad} non-finite value(s) "
                "(NaN/inf weights would propagate into the alias table)")

    ins, dele = batch.insert, batch.delete
    loops_i = ins[:, 0] == ins[:, 1] if len(ins) else np.zeros(0, bool)
    loops_d = dele[:, 0] == dele[:, 1] if len(dele) else np.zeros(0, bool)
    if self_loops == "forbid" and (loops_i.any() or loops_d.any()):
        raise ValueError(
            f"EdgeBatch contains {int(loops_i.sum() + loops_d.sum())} "
            "self-loop(s) and the ingest self-loop policy is 'forbid'")
    if self_loops == "drop" and (loops_i.any() or loops_d.any()):
        ins = ins[~loops_i]
        if w is not None:
            w = w[~loops_i]
        dele = dele[~loops_d]

    if duplicates != "allow" and len(ins):
        und = np.sort(ins, axis=1)
        _, first = np.unique(und[:, 0] * np.int64(max(num_nodes, 1))
                             + und[:, 1], return_index=True)
        if len(first) != len(ins):
            if duplicates == "forbid":
                raise ValueError(
                    f"EdgeBatch.insert contains "
                    f"{len(ins) - len(first)} duplicate undirected "
                    "edge(s) and the ingest duplicate policy is 'forbid'")
            keep = np.sort(first)          # keep-first, preserve order
            ins = ins[keep]
            if w is not None:
                w = w[keep]

    if ins is batch.insert and dele is batch.delete:
        return batch
    return EdgeBatch(insert=ins, delete=dele, insert_weights=w)


def _both_directions(edges: np.ndarray,
                     w: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    arcs = np.concatenate([edges, edges[:, ::-1]], axis=0)
    if w is not None:
        w = np.concatenate([w, w], axis=0)
    return arcs, w


def _arc_codes(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Row-major arc encoding; the base CSR's arcs are SORTED under it."""
    return src.astype(np.int64) * np.int64(max(n, 1)) + dst.astype(np.int64)


# ---------------------------------------------------------------------------
# The overlay
# ---------------------------------------------------------------------------


class DeltaCSR:
    """Batched insert/delete overlay with periodic compaction.

    The base graph handed to the constructor is never mutated; ``graph()``
    returns merged ``CSRGraph`` views (fresh objects per mutation epoch)
    and ``compact()`` promotes the current view to the new base, clearing
    the overlay. ``take_changes()`` drains the churn log accumulated since
    the last drain — the input of affected-vertex detection.
    """

    def __init__(self, base: CSRGraph, *, undirected: bool = True,
                 compact_threshold: float = 0.25):
        g = base.to_numpy()
        self._indptr = np.asarray(g.indptr, np.int64)
        self._indices = np.asarray(g.indices, np.int64)
        # _weights is OWNED (resurrected arcs re-price it in place); an
        # asarray alias of the caller's buffer must never be mutated.
        self._weights = (None if g.weights is None
                         else np.array(g.weights, np.float32))
        self._edge_cm = (None if g.edge_cm is None
                         else np.asarray(g.edge_cm, np.int32))
        self.undirected = undirected
        self.compact_threshold = float(compact_threshold)
        self._num_nodes = len(self._indptr) - 1
        self._deleted = np.zeros(len(self._indices), bool)
        self._ext_src = np.zeros(0, np.int64)
        self._ext_dst = np.zeros(0, np.int64)
        self._ext_w = None if self._weights is None else np.zeros(0,
                                                                  np.float32)
        self._view: Optional[CSRGraph] = None
        self._log_insert: list = []
        self._log_delete: list = []
        self.version = 0
        self.compactions = 0
        self._codes: Optional[np.ndarray] = None   # per-base-epoch memo
        self._base_src: Optional[np.ndarray] = None
        self._codes_n = -1

    # -- introspection -----------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self._num_nodes

    @property
    def pending_arcs(self) -> int:
        """Overlay size: tombstoned base arcs + pending inserted arcs."""
        return int(self._deleted.sum()) + len(self._ext_src)

    def _base_codes(self) -> np.ndarray:
        """Sorted row-major codes of the base arcs, memoized per base
        epoch (they change only at compact() or |V| growth) — this is
        what keeps apply_batch at O(|Δ| log |E|) instead of paying an
        O(|E|) rebuild per batch."""
        if self._codes is None or self._codes_n != self._num_nodes:
            self._base_src = np.repeat(
                np.arange(len(self._indptr) - 1, dtype=np.int64),
                np.diff(self._indptr))
            self._codes = (_arc_codes(self._base_src, self._indices,
                                      self._num_nodes)
                           if len(self._indices) else np.zeros(0, np.int64))
            self._codes_n = self._num_nodes
        return self._codes

    # -- mutation ----------------------------------------------------------
    def apply_batch(self, batch: EdgeBatch) -> "DeltaCSR":
        """Apply one churn batch to the overlay. O(|Δ| log |E|)."""
        ins = batch.insert
        dele = batch.delete
        ins = ins[ins[:, 0] != ins[:, 1]]
        dele = dele[dele[:, 0] != dele[:, 1]]
        w_ins = batch.insert_weights
        if w_ins is not None:
            w_ins = w_ins[batch.insert[:, 0] != batch.insert[:, 1]]

        if self.undirected:
            del_arcs, _ = _both_directions(dele)
            ins_arcs, w_arcs = _both_directions(ins, w_ins)
        else:
            del_arcs, ins_arcs, w_arcs = dele, ins, w_ins

        # Grow the vertex set if inserts reference new ids.
        if len(ins_arcs):
            top = int(ins_arcs.max()) + 1
            if top > self._num_nodes:
                grow = top - self._num_nodes
                self._indptr = np.concatenate(
                    [self._indptr,
                     np.full(grow, self._indptr[-1], np.int64)])
                self._num_nodes = top

        n = self._num_nodes
        codes = self._base_codes()

        if len(del_arcs):
            # An endpoint outside the vertex set names a necessarily
            # missing edge ("missing edges ignored") — and MUST be
            # dropped before encoding: u*n + v with v >= n aliases the
            # code of an unrelated in-range arc.
            in_range = ((del_arcs >= 0) & (del_arcs < n)).all(axis=1)
            del_arcs = del_arcs[in_range]
        if len(del_arcs):
            want = _arc_codes(del_arcs[:, 0], del_arcs[:, 1], n)
            pos = np.searchsorted(codes, want)
            pos_c = np.minimum(pos, max(len(codes) - 1, 0))
            found = (len(codes) > 0) & (codes[pos_c] == want)
            live = found & ~self._deleted[pos_c]
            self._deleted[pos_c[live]] = True
            # Deletes also cancel matching PENDING inserts.
            if len(self._ext_src):
                ext_codes = _arc_codes(self._ext_src, self._ext_dst, n)
                hit_ext = np.isin(ext_codes, want)
                if hit_ext.any():
                    keep = ~hit_ext
                    self._ext_src = self._ext_src[keep]
                    self._ext_dst = self._ext_dst[keep]
                    if self._ext_w is not None:
                        self._ext_w = self._ext_w[keep]

        if len(ins_arcs):
            # Drop inserts already present (live base arcs or pending).
            want = _arc_codes(ins_arcs[:, 0], ins_arcs[:, 1], n)
            pos = np.searchsorted(codes, want)
            pos_c = np.minimum(pos, max(len(codes) - 1, 0))
            in_base = ((len(codes) > 0) & (codes[pos_c] == want)
                       & ~self._deleted[pos_c])
            # Un-tombstone re-inserted base arcs instead of duplicating;
            # the resurrected arc takes the INSERT's weight (the caller
            # re-added the edge, possibly re-priced), not the stale one.
            was_deleted = ((len(codes) > 0) & (codes[pos_c] == want)
                           & self._deleted[pos_c])
            self._deleted[pos_c[was_deleted]] = False
            if self._weights is not None and was_deleted.any():
                new_w = (w_arcs[was_deleted] if w_arcs is not None
                         else np.ones(int(was_deleted.sum()), np.float32))
                self._weights[pos_c[was_deleted]] = new_w
            pending = (np.isin(want, _arc_codes(self._ext_src, self._ext_dst,
                                                n))
                       if len(self._ext_src) else np.zeros(len(want), bool))
            fresh = ~in_base & ~was_deleted & ~pending
            # Dedup within the batch itself.
            _, first = np.unique(want[fresh], return_index=True)
            keep_idx = np.nonzero(fresh)[0][np.sort(first)]
            self._ext_src = np.concatenate(
                [self._ext_src, ins_arcs[keep_idx, 0]])
            self._ext_dst = np.concatenate(
                [self._ext_dst, ins_arcs[keep_idx, 1]])
            if self._ext_w is not None:
                add_w = (w_arcs[keep_idx] if w_arcs is not None
                         else np.ones(len(keep_idx), np.float32))
                self._ext_w = np.concatenate([self._ext_w, add_w])

        self._log_insert.append(np.asarray(ins, np.int64))
        self._log_delete.append(np.asarray(dele, np.int64))
        self._invalidate()
        if (self.compact_threshold > 0
                and self.pending_arcs
                > self.compact_threshold * max(len(self._indices), 1)):
            self.compact()
        return self

    def _invalidate(self):
        if self._view is not None:
            # A consumer may still pass the retired view to the engine
            # caches; bump ITS version so any (id, version) key goes stale
            # even if the id is later recycled by a fresh view object.
            bump_graph_version(self._view)
        self._view = None
        self.version += 1

    # -- views + compaction ------------------------------------------------
    def _merged_arrays(self):
        n = self._num_nodes
        keep = ~self._deleted
        base_src = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(self._indptr))
        src = np.concatenate([base_src[keep], self._ext_src])
        dst = np.concatenate([self._indices[keep], self._ext_dst])
        w = None
        if self._weights is not None:
            w = np.concatenate([self._weights[keep],
                                self._ext_w if self._ext_w is not None
                                else np.zeros(0, np.float32)])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        if w is not None:
            w = w[order]
        counts = np.bincount(src, minlength=n)
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return indptr, dst, w

    def graph(self) -> CSRGraph:
        """The merged CSR view (vectorized compaction; cached per epoch).

        Carries incrementally refreshed ``edge_cm`` when the base had one.
        """
        if self._view is not None:
            return self._view
        import jax.numpy as jnp

        indptr, indices, w = self._merged_arrays()
        cm = None
        if self._edge_cm is not None:
            old = CSRGraph(indptr=self._indptr, indices=self._indices,
                           weights=None, edge_cm=self._edge_cm)
            new = CSRGraph(indptr=indptr, indices=indices, weights=None)
            cm = incremental_edge_cm(old, new, self._overlay_touched())
        view = CSRGraph(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices, jnp.int32),
            weights=None if w is None else jnp.asarray(w, jnp.float32),
            edge_cm=None if cm is None else jnp.asarray(cm, jnp.int32),
        )
        self._view = view
        return view

    def compact(self) -> CSRGraph:
        """Promote the merged view into the new base; clears the overlay
        (but not the churn log — ``take_changes`` owns that)."""
        view = self.graph()
        g = view.to_numpy()
        self._indptr = np.asarray(g.indptr, np.int64)
        self._indices = np.asarray(g.indices, np.int64)
        self._weights = (None if g.weights is None
                         else np.array(g.weights, np.float32))
        self._edge_cm = (None if g.edge_cm is None
                         else np.asarray(g.edge_cm, np.int32))
        self._deleted = np.zeros(len(self._indices), bool)
        self._ext_src = np.zeros(0, np.int64)
        self._ext_dst = np.zeros(0, np.int64)
        self._ext_w = None if self._weights is None else np.zeros(0,
                                                                  np.float32)
        self._codes = None                     # new base epoch
        self._base_src = None
        self.compactions += 1
        return view

    def _overlay_touched(self) -> np.ndarray:
        """Endpoints of every change currently IN THE OVERLAY (tombstoned
        base arcs + pending inserts) — the rows whose content differs
        between the base and the merged view, independent of the churn
        log's drain state."""
        self._base_codes()                      # ensures _base_src
        base_src = self._base_src
        parts = [base_src[self._deleted], self._indices[self._deleted],
                 self._ext_src, self._ext_dst]
        return np.unique(np.concatenate(parts)) if any(
            len(p) for p in parts) else np.zeros(0, np.int64)

    # -- churn log ---------------------------------------------------------
    def touched_nodes(self) -> np.ndarray:
        """Distinct endpoints of every change since the last drain."""
        parts = self._log_insert + self._log_delete
        if not parts:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate([p.reshape(-1) for p in parts]))

    def pending_changes(self) -> Tuple[np.ndarray, np.ndarray]:
        """(inserted_edges, deleted_edges) accumulated since last drain."""
        ins = (np.concatenate(self._log_insert)
               if self._log_insert else np.zeros((0, 2), np.int64))
        dele = (np.concatenate(self._log_delete)
                if self._log_delete else np.zeros((0, 2), np.int64))
        return ins, dele

    def take_changes(self) -> Tuple[np.ndarray, np.ndarray]:
        """Drain the churn log (the refresh driver calls this once per
        refresh so the next cycle only sees new churn)."""
        out = self.pending_changes()
        self._log_insert = []
        self._log_delete = []
        return out


# ---------------------------------------------------------------------------
# Incremental Cm(u, v)
# ---------------------------------------------------------------------------


def incremental_edge_cm(
    old: CSRGraph, new: CSRGraph, touched: np.ndarray
) -> np.ndarray:
    """Refresh per-arc common-neighbor counts after churn touching
    ``touched`` vertices.

    Cm(u, v) = |N(u) ∩ N(v)| changes only if N(u) or N(v) changed, i.e.
    only for arcs with a touched endpoint. Untouched rows are identical
    between ``old`` and ``new`` (same neighbors, same order), so their
    values move by a pure per-row offset gather; touched arcs are
    recomputed by sorted-merge intersection. With t touched vertices the
    cost is O(Σ_{touched} deg · log deg) + O(|E|) for the gather — not the
    O(|E| · deg) full precompute.
    """
    og, ng = old.to_numpy(), new.to_numpy()
    o_indptr = np.asarray(og.indptr, np.int64)
    o_indices = np.asarray(og.indices, np.int64)
    o_cm = np.asarray(og.edge_cm, np.int64)
    n_indptr = np.asarray(ng.indptr, np.int64)
    n_indices = np.asarray(ng.indices, np.int64)
    n_old = len(o_indptr) - 1
    n_new = len(n_indptr) - 1

    mark = np.zeros(max(n_old, n_new), bool)
    if len(touched):
        mark[np.asarray(touched, np.int64)] = True
    mark[n_old:] = True                       # brand-new vertices

    deg_new = np.diff(n_indptr)
    src = np.repeat(np.arange(n_new, dtype=np.int64), deg_new)
    dst = n_indices
    stale = mark[src] | mark[dst]

    cm = np.zeros(len(n_indices), np.int64)
    fresh = ~stale
    if fresh.any():
        # Row-aligned copy: untouched u has an identical row in old & new,
        # so arc j of u's new row is arc j of u's old row.
        offs = np.arange(len(src), dtype=np.int64) - np.repeat(
            n_indptr[:-1], deg_new)
        old_pos = o_indptr[src[fresh]] + offs[fresh]
        cm[fresh] = o_cm[old_pos]

    idx = np.nonzero(stale)[0]
    for k in idx:
        u, v = src[k], dst[k]
        nu = n_indices[n_indptr[u]:n_indptr[u + 1]]
        nv = n_indices[n_indptr[v]:n_indptr[v + 1]]
        if nu.size > nv.size:
            nu, nv = nv, nu
        if nv.size == 0:
            continue
        pos = np.searchsorted(nv, nu)
        pos = np.minimum(pos, nv.size - 1)
        cm[k] = int(np.sum(nv[pos] == nu))
    return cm.astype(np.int32)
