"""Edge-list IO (text + npz) for real-graph ingestion."""

from __future__ import annotations

import os
from typing import Optional

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def load_edge_list(
    path: str,
    *,
    undirected: bool = True,
    num_nodes: Optional[int] = None,
    comment: str = "#",
) -> CSRGraph:
    if path.endswith(".npz"):
        data = np.load(path)
        return build_csr(
            data["edges"],
            num_nodes=num_nodes or (int(data["num_nodes"]) if "num_nodes" in data else None),
            undirected=undirected,
            weights=data["weights"] if "weights" in data else None,
        )
    rows = []
    weights = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            parts = line.split()
            rows.append((int(parts[0]), int(parts[1])))
            if len(parts) > 2:
                weights.append(float(parts[2]))
    edges = np.asarray(rows, dtype=np.int64)
    w = np.asarray(weights, dtype=np.float32) if weights else None
    return build_csr(edges, num_nodes, undirected=undirected, weights=w)


def save_edge_list(graph: CSRGraph, path: str) -> None:
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    deg = indptr[1:] - indptr[:-1]
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    edges = np.stack([src, indices], axis=1)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if path.endswith(".npz"):
        payload = {"edges": edges, "num_nodes": np.int64(n)}
        if g.weights is not None:
            payload["weights"] = g.weights
        np.savez_compressed(path, **payload)
    else:
        with open(path, "w") as f:
            if g.weights is not None:
                # Weighted text round-trips: "src dst w" is the same
                # 3-column form load_edge_list parses; .9g keeps enough
                # digits for exact float32 round-trips.
                for (s, d), w in zip(edges, np.asarray(g.weights)):
                    f.write(f"{s} {d} {w:.9g}\n")
            else:
                for s, d in edges:
                    f.write(f"{s} {d}\n")
