"""Partition-sharded BSP walk engine (paper §3: walker-centric + InCoM).

Walkers live on the shard that owns their CURRENT node per the MPGP
``assignment``; one superstep is:

  phase A (at owner(cur))   candidate draw + walking-backtracking
                            acceptance (``walker.propose``);
  exchange                  walkers whose accepted node belongs to another
                            shard pack the paper's constant-size InCoM
                            message and hand off via a collective;
  phase B (at owner(cand))  n(v) from the LOCAL path fragment, Theorem 1 /
                            Eq. 13 info update, path append, Eq. 5
                            termination (``walker.absorb``).

Path storage follows the paper's ownership argument: node v's visits are
always appended on owner(v)'s fragment, so n(v) is a local count and the
walk itself never has to travel — only the 10-field / 80-byte message does
(Example 1). The final corpus path is the elementwise union of the shard
fragments (every position is written by exactly one shard). The fullpath
(HuGE-D) baseline instead carries the whole walk in its message: 24 + 8L
bytes, measured from the actual routed path payload.

Message layout: exactly ``incom.MSG_FIELDS`` (10 fields). The walker's step
count is globally known (BSP superstep index), so the ``steps`` slot
carries the sender's pre-step node instead — the predecessor that
second-order policies (node2vec) need on arrival — keeping the hand-off at
the paper's 80 bytes (DESIGN.md §9). ``reg_window`` mode appends the K-entry
H ring (80 + 8K bytes), matching ``incom.windowed_r_squared``'s cost note.

Two executions of the SAME per-shard program:

* ``vmap(..., axis_name="shards")`` — stacked emulation: k logical shards
  as a leading array axis on one device; ``lax.psum`` realizes the
  exchange. Always available, used by tests for shard-count invariance.
* ``shard_map`` over a k-device mesh — the SPMD form with real collectives
  (``make_walk_mesh``). Bit-identical by construction: per-lane RNG
  (``walker.step_uniforms``) and per-lane math do not depend on layout.

``msg_count``/``msg_bytes`` are derived from the packed message tensors
the exchange moves: per hand-off, the FIELD COUNT of the packed payload x
the paper's 8 B/field accounting (Example 1) — so a packing regression
(an extra field, a whole-batch ship) moves the number away from
``msg_bytes_analytic``, which carries the independent closed form.
Physical wire bytes differ: payloads are f32/i32 (4 B/field) and the
stacked emulation's psum is dense over all B lanes; the hand-off COUNT
and field inventory are what is measured, the 8 B/field model prices
them (DESIGN.md §9).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import incom
from repro.core import walker as wk
from repro.core.transition import Policy
from repro.graph.csr import CSRGraph

AXIS = "shards"   # the walk-shard mesh / vmap axis name


def make_walk_mesh(num_shards: int) -> Optional[Mesh]:
    """A ("shards",)-mesh over local devices, or None when the host does
    not have ``num_shards`` devices (callers then use the stacked
    emulation, which is the same program under vmap)."""
    from repro.dist.collectives import local_mesh
    return local_mesh(num_shards, AXIS)


# ---------------------------------------------------------------------------
# The per-shard BSP program (executed under vmap OR shard_map, axis="shards")
# ---------------------------------------------------------------------------


def _shard_program(
    graph: CSRGraph,
    owner: jax.Array,        # (|V|,) int32 partition id per node (replicated)
    sources: jax.Array,      # (B,) int32 (replicated; lanes are global slots)
    root_key: jax.Array,
    policy: Policy,
    spec: wk.WalkSpec,
):
    """Full walk loop for ONE shard; collectives over axis ``AXIS``."""
    b = sources.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    sid = lax.axis_index(AXIS)
    fullpath = spec.info_mode == "fullpath"
    h_len = spec.max_len if fullpath else 1
    k_ring = max(spec.reg_window, 1)
    cap = spec.supersteps_cap()

    resident0 = owner[sources] == sid
    # Fragment init: the source node's first visit is recorded at ITS owner.
    path0 = jnp.full((b, spec.max_len), -1, jnp.int32)
    path0 = path0.at[:, 0].set(jnp.where(resident0, sources, -1))

    st0 = dict(
        cur=sources,
        prev=sources,
        resident=resident0,
        active=jnp.ones((b,), bool),
        info=incom.InfoState.init(b),
        path=path0,
        h=jnp.zeros((b, h_len), jnp.float32),
        ring=jnp.zeros((b, k_ring), jnp.float32),
        t=jnp.zeros((), jnp.int32),
        accepts=jnp.zeros((), jnp.int32),
        rejects=jnp.zeros((), jnp.int32),
        msg_count=jnp.zeros((), jnp.int32),
        msg_bytes=jnp.zeros((), jnp.float32),
        msg_bytes_analytic=jnp.zeros((), jnp.float32),
    )

    def cond(st):
        live = jnp.sum((st["resident"] & st["active"]).astype(jnp.int32))
        return (lax.psum(live, AXIS) > 0) & (st["t"] < cap)

    def body(st):
        u1, u2 = wk.step_uniforms(root_key, st["t"], b)
        cand, _, accept_raw, has_nbrs = wk.propose(
            graph, policy, st["cur"], st["prev"], u1, u2)
        live = st["resident"] & st["active"]
        accept = live & accept_raw
        dead_end = live & ~has_nbrs
        mig = accept & (owner[cand] != sid)
        stay = accept & ~mig

        path = st["path"]
        if fullpath:
            # The HuGE-D message carries the walk INCLUDING the accepted
            # node (24 + 8*l_new bytes), so append at the origin; phase B's
            # re-append at the same slot is idempotent.
            idx = jnp.clip(st["info"].L.astype(jnp.int32), 0, spec.max_len - 1)
            path = jnp.where(accept[:, None], path.at[ids, idx].set(cand), path)

        # ---- pack + hand off (the measured exchange) ------------------------
        from repro.dist.collectives import psum_union

        info = st["info"]
        mig_i = mig.astype(jnp.int32)
        msg_i = jnp.stack([ids, st["cur"], cand], axis=1)
        msg_f = jnp.stack(
            [info.H, info.L, info.EH, info.EL, info.EHL, info.EH2, info.EL2],
            axis=1)
        payload = {"i": msg_i, "f": msg_f}
        if spec.reg_window:
            payload["ring"] = st["ring"]
        if fullpath:
            payload.update({"path": path, "h": st["h"]})
        arrivals = psum_union(payload, mig, AXIS)     # exact: <=1 sender/lane
        arr_i, arr_f = arrivals["i"], arrivals["f"]
        arr_ring = arrivals.get("ring", st["ring"])
        arrived = lax.psum(mig_i, AXIS) > 0           # (B,) any shard sent
        if fullpath:
            arr_path, arr_h = arrivals["path"], arrivals["h"]
        # Fields the hand-off actually ships, derived from the packed
        # tensors (NOT from the Example-1 closed form — packing an extra
        # field would move measured away from analytic and fail the tests).
        # In fullpath mode the walk itself is the payload: the 3 id fields
        # + one entry per shipped path position; the 7-stat ride-along is
        # excluded per the paper's 24+8L accounting (module docstring).
        shipped_fields = msg_i.shape[1] + msg_f.shape[1] + (
            arrivals["ring"].shape[1] if "ring" in payload else 0)

        incoming = arrived & (owner[arr_i[:, 2]] == sid)
        proc = stay | incoming

        # ---- merge arrivals into local lane state --------------------------
        sel = lambda a, b_: jnp.where(incoming, a, b_)
        cand_b = sel(arr_i[:, 2], cand)
        sender_cur = sel(arr_i[:, 1], st["cur"])      # walker's pre-step node
        info_b = incom.InfoState(
            H=sel(arr_f[:, 0], info.H), L=sel(arr_f[:, 1], info.L),
            EH=sel(arr_f[:, 2], info.EH), EL=sel(arr_f[:, 3], info.EL),
            EHL=sel(arr_f[:, 4], info.EHL), EH2=sel(arr_f[:, 5], info.EH2),
            EL2=sel(arr_f[:, 6], info.EL2))
        ring_b = jnp.where(incoming[:, None], arr_ring, st["ring"])
        if fullpath:
            path_b = jnp.where(incoming[:, None], arr_path, path)
            h_b = jnp.where(incoming[:, None], arr_h, st["h"])
        else:
            path_b, h_b = path, st["h"]

        info2, path2, h2, ring2, done_now = wk.absorb(
            spec, info_b, path_b, h_b, ring_b, cand_b, proc)

        # ---- residence / activity -------------------------------------------
        resident2 = (st["resident"] & ~mig) | incoming
        cur2 = jnp.where(proc, cand_b, st["cur"])
        prev2 = jnp.where(proc, sender_cur, st["prev"])
        active2 = jnp.where(proc, ~done_now,
                            jnp.where(dead_end, False, st["active"]))

        # ---- measured + analytic traffic ------------------------------------
        n_out = jnp.sum(mig_i)
        if fullpath:
            shipped = jnp.sum(((path >= 0) & mig[:, None]).astype(jnp.int32))
            add_meas = (8.0 * msg_i.shape[1]) * n_out + 8.0 * shipped
            add_an = jnp.sum(jnp.where(
                mig, incom.fullpath_msg_bytes(info.L + 1.0), 0.0))
        else:
            add_meas = jnp.float32(8.0 * shipped_fields) * n_out
            add_an = jnp.float32(incom.MSG_BYTES + 8 * (spec.reg_window or 0)
                                 ) * n_out

        return dict(
            cur=cur2, prev=prev2, resident=resident2, active=active2,
            info=info2, path=path2, h=h2, ring=ring2,
            t=st["t"] + 1,
            accepts=st["accepts"] + jnp.sum(accept).astype(jnp.int32),
            rejects=st["rejects"]
            + jnp.sum(live & has_nbrs & ~accept_raw).astype(jnp.int32),
            msg_count=st["msg_count"] + n_out,
            msg_bytes=st["msg_bytes"] + add_meas,
            msg_bytes_analytic=st["msg_bytes_analytic"] + add_an,
        )

    return lax.while_loop(cond, body, st0)


# ---------------------------------------------------------------------------
# Drivers: stacked emulation (vmap) and SPMD (shard_map)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("policy", "spec", "num_shards"))
def _run_stacked(graph, owner, sources, root_key, policy, spec, num_shards):
    def per_shard(_marker):
        return _shard_program(graph, owner, sources, root_key, policy, spec)

    return jax.vmap(per_shard, axis_name=AXIS)(jnp.arange(num_shards))


def _run_spmd(graph, owner, sources, root_key, policy, spec,
              num_shards: int, mesh: Mesh):
    from jax.experimental.shard_map import shard_map

    def per_shard(graph_, owner_, sources_, key_, _marker):
        out = _shard_program(graph_, owner_, sources_, key_, policy, spec)
        return jax.tree_util.tree_map(lambda x: x[None], out)

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(AXIS)),
        out_specs=P(AXIS),
        check_rep=False,
    )
    return fn(graph, owner, sources, root_key, jnp.arange(num_shards))


def _merge(out, spec: wk.WalkSpec, root_key) -> wk.WalkerBatchState:
    """Combine the (k, ...) per-shard outputs into one WalkerBatchState."""
    res = out["resident"]                                    # (k, B)
    pick = lambda x: jnp.sum(jnp.where(res, x, 0), axis=0)   # 1 resident/lane
    pickf = lambda x: jnp.sum(
        jnp.where(res[..., None], x, 0), axis=0)
    if spec.info_mode == "fullpath":
        # The walk travels whole; only the final resident copy is current.
        path = jnp.max(jnp.where(res[..., None], out["path"], -1), axis=0)
    else:
        # Fragment union: each position was written by exactly one owner.
        path = jnp.max(out["path"], axis=0)
    info = incom.InfoState(
        H=pick(out["info"].H), L=pick(out["info"].L),
        EH=pick(out["info"].EH), EL=pick(out["info"].EL),
        EHL=pick(out["info"].EHL), EH2=pick(out["info"].EH2),
        EL2=pick(out["info"].EL2))
    return wk.WalkerBatchState(
        cur=pick(out["cur"].astype(jnp.int32)),
        prev=pick(out["prev"].astype(jnp.int32)),
        path=path,
        info=info,
        h_series=pickf(out["h"]),
        hring=pickf(out["ring"]),
        active=jnp.any(out["resident"] & out["active"], axis=0),
        key=root_key,
        supersteps=out["t"][0],
        accepts=jnp.sum(out["accepts"]),
        rejects=jnp.sum(out["rejects"]),
        msg_count=jnp.sum(out["msg_count"]),
        msg_bytes=jnp.sum(out["msg_bytes"]),
        msg_bytes_analytic=jnp.sum(out["msg_bytes_analytic"]),
    )


def run_walk_sharded(
    graph: CSRGraph,
    sources: jax.Array,
    key: jax.Array,
    policy: Policy,
    spec: wk.WalkSpec,
    assignment: jax.Array,
    num_shards: int,
    mesh: Optional[Mesh] = None,
) -> wk.WalkerBatchState:
    """Run one walk per source on ``num_shards`` partition shards.

    ``assignment`` maps node -> owning shard (MPGP output). With ``mesh``
    (k devices) the program runs SPMD under shard_map; otherwise the k
    shards run as a stacked vmap axis on the local device. Results are
    bit-identical across both executions and across shard counts.
    """
    sources = jnp.asarray(sources, jnp.int32)
    owner = jnp.asarray(assignment, jnp.int32)
    if getattr(policy, "needs_edge_cm", False) and graph.edge_cm is None:
        graph = graph.with_edge_cm()
    if mesh is not None and int(mesh.shape[AXIS]) == num_shards:
        out = _run_spmd(graph, owner, sources, key, policy, spec,
                        num_shards, mesh)
    else:
        out = _run_stacked(graph, owner, sources, key, policy, spec,
                           num_shards)
    return _merge(out, spec, key)
