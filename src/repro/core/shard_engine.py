"""Partition-sharded BSP walk engine (paper §3: walker-centric + InCoM).

Walkers live on the shard that owns their CURRENT node per the MPGP
``assignment``; one superstep is:

  phase A (at owner(cur))   candidate draw + walking-backtracking
                            acceptance (``walker.propose``);
  exchange                  walkers whose accepted node belongs to another
                            shard pack the paper's constant-size InCoM
                            message and hand off via a collective;
  phase B (at owner(cand))  n(v) from the LOCAL path fragment, Theorem 1 /
                            Eq. 13 info update, path append, Eq. 5
                            termination (``walker.absorb``).

Path storage follows the paper's ownership argument: node v's visits are
always appended on owner(v)'s fragment, so n(v) is a local count and the
walk itself never has to travel — only the 10-field / 80-byte message does
(Example 1). The final corpus path is the elementwise union of the shard
fragments (every position is written by exactly one shard). The fullpath
(HuGE-D) baseline instead carries the whole walk in its message: 24 + 8L
bytes, measured from the actual routed path payload.

Two engines realize the per-shard program (DESIGN.md §9):

* **partition-local** (the scaling engine; default on a real mesh): each
  shard program indexes ONLY its ``graph.csr.build_partitioned_csr``
  slice — a local-row CSR of ~|V|/k nodes and ~|E|/k arcs with
  edge-aligned halo metadata (neighbor owner + degree), so ``owner[]``
  lookups for candidates never touch a global O(|E|) structure. Walker
  lanes are COMPACTED into a per-shard slot pool sized by the MPGP
  balance bound (``pool_factor``·B/k, grown to the observed occupancy on
  overflow), so phase-A/phase-B work scales with walkers-per-shard, not
  with the global batch. The exchange moves only migrant records —
  ``lax.all_to_all`` destination buckets with an overflow spill loop on
  the mesh, gather-compacted broadcasts on the stacked path — instead of
  the former dense all-lane psum.
* **replicated** (reference + single-device fast path): every shard reads
  the replicated CSR and carries all B lanes; the exchange is the dense
  ``psum_union``. Second-order policies that read N(prev) (node2vec)
  always route here, the stacked emulation defaults here (on one device
  the k per-shard programs serialize, so partition-locality saves no
  memory and the dense form wins wall-clock), and tests use it as the
  ground truth the partition-local engine must match walk-for-walk.

Message layout: exactly ``incom.MSG_FIELDS`` (10 fields). The walker's step
count is globally known (BSP superstep index), so the ``steps`` slot
carries the sender's pre-step node instead — the predecessor that
second-order policies (node2vec) need on arrival — keeping the hand-off at
the paper's 80 bytes (DESIGN.md §9). ``reg_window`` mode appends the K-entry
H ring (80 + 8K bytes), matching ``incom.windowed_r_squared``'s cost note.

Both engines execute the SAME per-shard program two ways:

* ``vmap(..., axis_name="shards")`` — stacked emulation: k logical shards
  as a leading array axis on one device. Always available, used by tests
  for shard-count invariance.
* ``shard_map`` over a k-device mesh — the SPMD form with real collectives
  (``make_walk_mesh``); the partition-local engine places only the owning
  CSR slice on each device. Bit-identical by construction: per-lane RNG
  (``walker.step_uniforms``) and per-lane math do not depend on layout.

``msg_count``/``msg_bytes`` are derived from the packed message tensors
the exchange moves: per hand-off, the FIELD COUNT of the packed payload x
the paper's 8 B/field accounting (Example 1) — so a packing regression
(an extra field, a whole-batch ship) moves the number away from
``msg_bytes_analytic``, which carries the independent closed form.
Physical wire bytes differ: payloads are f32/i32 (4 B/field); the hand-off
COUNT and field inventory are what is measured, the 8 B/field model prices
them (DESIGN.md §9).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import incom
from repro import obs
from repro.core import walker as wk
from repro.core.transition import Policy
from repro.graph.csr import CSRGraph, PartitionedCSR, ShardCSR, \
    build_partitioned_csr

AXIS = "shards"   # the walk-shard mesh / vmap axis name


def make_walk_mesh(num_shards: int) -> Optional[Mesh]:
    """A ("shards",)-mesh over local devices, or None when the host does
    not have ``num_shards`` devices (callers then use the stacked
    emulation, which is the same program under vmap)."""
    from repro.dist.collectives import local_mesh
    return local_mesh(num_shards, AXIS)


# ---------------------------------------------------------------------------
# Replicated reference program (full-width lanes, dense psum exchange)
# ---------------------------------------------------------------------------


def _shard_program_replicated(
    graph: CSRGraph,
    owner: jax.Array,        # (|V|,) int32 partition id per node (replicated)
    sources: jax.Array,      # (B,) int32 (replicated; lanes are global slots)
    root_key: jax.Array,
    policy: Policy,
    spec: wk.WalkSpec,
):
    """Full walk loop for ONE shard; collectives over axis ``AXIS``."""
    b = sources.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    sid = lax.axis_index(AXIS)
    fullpath = spec.info_mode == "fullpath"
    h_len = spec.max_len if fullpath else 1
    k_ring = max(spec.reg_window, 1)
    cap = spec.supersteps_cap()

    ufn = wk.make_uniform_fn(spec, sources)
    resident0 = owner[sources] == sid
    # Fragment init: the source node's first visit is recorded at ITS owner.
    path0 = jnp.full((b, spec.max_len), -1, jnp.int32)
    path0 = path0.at[:, 0].set(jnp.where(resident0, sources, -1))

    st0 = dict(
        cur=sources,
        prev=sources,
        resident=resident0,
        active=jnp.ones((b,), bool),
        info=incom.InfoState.init(b),
        path=path0,
        h=jnp.zeros((b, h_len), jnp.float32),
        ring=jnp.zeros((b, k_ring), jnp.float32),
        t=jnp.zeros((), jnp.int32),
        accepts=jnp.zeros((), jnp.int32),
        rejects=jnp.zeros((), jnp.int32),
        msg_count=jnp.zeros((), jnp.int32),
        msg_bytes=jnp.zeros((), jnp.float32),
        msg_bytes_analytic=jnp.zeros((), jnp.float32),
    )

    def cond(st):
        live = jnp.sum((st["resident"] & st["active"]).astype(jnp.int32))
        return (lax.psum(live, AXIS) > 0) & (st["t"] < cap)

    def body(st):
        u1, u2 = ufn(root_key, st["t"])
        cand, _, accept_raw, has_nbrs = wk.propose(
            graph, policy, st["cur"], st["prev"], u1, u2)
        live = st["resident"] & st["active"]
        accept = live & accept_raw
        dead_end = live & ~has_nbrs
        mig = accept & (owner[cand] != sid)
        stay = accept & ~mig

        path = st["path"]
        if fullpath:
            # The HuGE-D message carries the walk INCLUDING the accepted
            # node (24 + 8*l_new bytes), so append at the origin; phase B's
            # re-append at the same slot is idempotent.
            idx = jnp.clip(st["info"].L.astype(jnp.int32), 0, spec.max_len - 1)
            path = jnp.where(accept[:, None], path.at[ids, idx].set(cand), path)

        # ---- pack + hand off (the measured exchange) ------------------------
        from repro.dist.collectives import psum_union

        info = st["info"]
        mig_i = mig.astype(jnp.int32)
        msg_i = jnp.stack([ids, st["cur"], cand], axis=1)
        msg_f = jnp.stack(
            [info.H, info.L, info.EH, info.EL, info.EHL, info.EH2, info.EL2],
            axis=1)
        payload = {"i": msg_i, "f": msg_f}
        if spec.reg_window:
            payload["ring"] = st["ring"]
        if fullpath:
            payload.update({"path": path, "h": st["h"]})
        arrivals = psum_union(payload, mig, AXIS)     # exact: <=1 sender/lane
        arr_i, arr_f = arrivals["i"], arrivals["f"]
        arr_ring = arrivals.get("ring", st["ring"])
        arrived = lax.psum(mig_i, AXIS) > 0           # (B,) any shard sent
        if fullpath:
            arr_path, arr_h = arrivals["path"], arrivals["h"]
        # Fields the hand-off actually ships, derived from the packed
        # tensors (NOT from the Example-1 closed form — packing an extra
        # field would move measured away from analytic and fail the tests).
        # In fullpath mode the walk itself is the payload: the 3 id fields
        # + one entry per shipped path position; the 7-stat ride-along is
        # excluded per the paper's 24+8L accounting (module docstring).
        shipped_fields = msg_i.shape[1] + msg_f.shape[1] + (
            arrivals["ring"].shape[1] if "ring" in payload else 0)

        incoming = arrived & (owner[arr_i[:, 2]] == sid)
        proc = stay | incoming

        # ---- merge arrivals into local lane state --------------------------
        sel = lambda a, b_: jnp.where(incoming, a, b_)
        cand_b = sel(arr_i[:, 2], cand)
        sender_cur = sel(arr_i[:, 1], st["cur"])      # walker's pre-step node
        info_b = incom.InfoState(
            H=sel(arr_f[:, 0], info.H), L=sel(arr_f[:, 1], info.L),
            EH=sel(arr_f[:, 2], info.EH), EL=sel(arr_f[:, 3], info.EL),
            EHL=sel(arr_f[:, 4], info.EHL), EH2=sel(arr_f[:, 5], info.EH2),
            EL2=sel(arr_f[:, 6], info.EL2))
        ring_b = jnp.where(incoming[:, None], arr_ring, st["ring"])
        if fullpath:
            path_b = jnp.where(incoming[:, None], arr_path, path)
            h_b = jnp.where(incoming[:, None], arr_h, st["h"])
        else:
            path_b, h_b = path, st["h"]

        info2, path2, h2, ring2, done_now = wk.absorb(
            spec, info_b, path_b, h_b, ring_b, cand_b, proc)

        # ---- residence / activity -------------------------------------------
        resident2 = (st["resident"] & ~mig) | incoming
        cur2 = jnp.where(proc, cand_b, st["cur"])
        prev2 = jnp.where(proc, sender_cur, st["prev"])
        active2 = jnp.where(proc, ~done_now,
                            jnp.where(dead_end, False, st["active"]))

        # ---- measured + analytic traffic ------------------------------------
        n_out = jnp.sum(mig_i)
        if fullpath:
            shipped = jnp.sum(((path >= 0) & mig[:, None]).astype(jnp.int32))
            add_meas = (8.0 * msg_i.shape[1]) * n_out + 8.0 * shipped
            add_an = jnp.sum(jnp.where(
                mig, incom.fullpath_msg_bytes(info.L + 1.0), 0.0))
        else:
            add_meas = jnp.float32(8.0 * shipped_fields) * n_out
            add_an = jnp.float32(incom.MSG_BYTES + 8 * (spec.reg_window or 0)
                                 ) * n_out

        return dict(
            cur=cur2, prev=prev2, resident=resident2, active=active2,
            info=info2, path=path2, h=h2, ring=ring2,
            t=st["t"] + 1,
            accepts=st["accepts"] + jnp.sum(accept).astype(jnp.int32),
            rejects=st["rejects"]
            + jnp.sum(live & has_nbrs & ~accept_raw).astype(jnp.int32),
            msg_count=st["msg_count"] + n_out,
            msg_bytes=st["msg_bytes"] + add_meas,
            msg_bytes_analytic=st["msg_bytes_analytic"] + add_an,
        )

    return lax.while_loop(cond, body, st0)


# ---------------------------------------------------------------------------
# Partition-local compacted program (slot pool + packed sparse exchange)
# ---------------------------------------------------------------------------


def _info_select(take, arrived: incom.InfoState, old: incom.InfoState,
                 ) -> incom.InfoState:
    return jax.tree_util.tree_map(
        lambda a, o: jnp.where(take, a, o), arrived, old)


def _shard_program_local(
    shard: ShardCSR,         # THIS shard's slice (leading k-axis mapped away)
    local_of: jax.Array,     # (|V|,) int32 global node -> local row at owner
    owner: jax.Array,        # (|V|,) int32 partition id per node (replicated)
    sources: jax.Array,      # (B,) int32 global lane -> source node
    root_key: jax.Array,
    policy: Policy,
    spec: wk.WalkSpec,
    num_shards: int,
    pool: int,               # slot-pool size P (MPGP bound, grown on overflow)
    cap: int,                # packed-exchange records/source/round (0 = P)
    compact_every: int,      # supersteps unrolled per flush/repack block
    transport: str,          # "pool" | "gather" | "a2a"
):
    """Compacted walk loop for ONE shard over its partition-local slice.

    Lane state lives in a P-slot pool (P ~ pool_factor·B/k): slot i holds
    the GLOBAL lane id in ``lane[i]`` (-1 = free) plus that walker's
    cur/prev/info/ring and its owner-local path row. Phase A indexes only
    the local CSR slice; migrants ship compacted; arrivals claim free
    slots in deterministic (source shard, record position) order. Per-lane
    values never depend on slot position, which is what keeps walks
    bit-identical to the replicated reference at every k and under every
    transport/execution.

    The hot loop is engineered for XLA-CPU emulation as much as for real
    meshes: ZERO data-dependent scatters and ZERO nested control flow per
    superstep (batched scatters lower to serial per-entry loops, and
    inner while/cond blocks force per-iteration buffer copies — together
    they measured ~10x the actual compute). Concretely:

    * appends are one-hot selects; packing/placement are
      cumsum + compare + gather;
    * the "pool" transport all_gathers the P-wide lane payload masked by
      the migrant flags — one round always suffices, so there is no spill
      loop to execute; the packed "gather" (stacked default — its spill
      loop constant-folds away when migration is impossible and self-skips
      on migrant-free supersteps) and "a2a" (mesh default, where wire
      volume is real) transports keep the cap + spill-round while_loop;
    * terminated walkers tombstone in place, out-migrated walkers leave
      fragment GHOSTS (their owner-local path rows, resumed if the walker
      returns), and one unconditional flush per ``compact_every``-unrolled
      superstep block retires both through the engine's single batched
      scatter (the lane->slot inverse index).

    A walker that finds no free slot is counted in ``overflow`` and the
    driver re-runs with a doubled pool (P = B can never overflow: a lane
    occupies at most one slot per shard).
    """
    b = sources.shape[0]
    k = num_shards
    sid = lax.axis_index(AXIS)
    fullpath = spec.info_mode == "fullpath"
    h_len = spec.max_len if fullpath else 1
    k_ring = max(spec.reg_window, 1)
    step_cap = spec.supersteps_cap()
    p = pool
    max_nodes = shard.indptr.shape[0] - 1
    max_edges = shard.indices.shape[0]
    pids = jnp.arange(p, dtype=jnp.int32)
    flat = transport == "pool"
    r_cap = p if flat else cap
    n_rec = k * r_cap                     # records visible per round
    unroll = max(compact_every, 1)

    from repro.dist.collectives import (
        packed_all_gather, packed_all_to_all, rank_search, take_ranked)

    ufn = wk.make_uniform_fn(spec, sources)

    # ---- pool init: resident source lanes claim slots in lane order -------
    resident0 = owner[sources] == sid
    lane0_all, valid0 = take_ranked(
        jnp.arange(b, dtype=jnp.int32), resident0, p)
    lane0 = jnp.where(valid0, lane0_all, -1)
    occ0 = lane0 >= 0
    cur0 = jnp.where(occ0, sources[jnp.maximum(lane0, 0)], 0)
    overflow0 = jnp.maximum(
        jnp.sum(resident0.astype(jnp.int32)) - jnp.int32(p), 0)

    st0 = dict(
        lane=lane0,
        alive=occ0,
        term=jnp.zeros((p,), bool),
        cur=cur0,
        prev=cur0,
        info=incom.InfoState.init(p),
        ring=jnp.zeros((p, k_ring), jnp.float32),
        h=jnp.zeros((p, h_len), jnp.float32),
        # Pool-resident walk rows: the owner-local path FRAGMENT (incom /
        # fixed — appended in place, never shipped) or the travelling full
        # path (fullpath). One-hot selects keep every append vectorized.
        prow=jnp.full((p, spec.max_len), -1, jnp.int32
                      ).at[:, 0].set(jnp.where(occ0, cur0, -1)),
        # Lane-indexed fragment store: rows retire here from the pool at
        # flush ticks; the final corpus path is the max-union over shards.
        frag=jnp.full((b, spec.max_len), -1, jnp.int32),
        fin_cur=jnp.zeros((b,), jnp.int32),
        fin_prev=jnp.zeros((b,), jnp.int32),
        fin_info=incom.InfoState.init(b),
        fin_ring=jnp.zeros((b, k_ring), jnp.float32),
        fin_h=jnp.zeros((b, h_len), jnp.float32),
        fin_valid=jnp.zeros((b,), bool),
        fin_active=jnp.zeros((b,), bool),
        t=jnp.zeros((), jnp.int32),
        accepts=jnp.zeros((), jnp.int32),
        rejects=jnp.zeros((), jnp.int32),
        msg_count=jnp.zeros((), jnp.int32),
        msg_bytes=jnp.zeros((), jnp.float32),
        msg_bytes_analytic=jnp.zeros((), jnp.float32),
        overflow=overflow0,
        peak_occ=jnp.sum(occ0.astype(jnp.int32)),
    )
    if fullpath:
        st0["fin_path"] = jnp.full((b, spec.max_len), -1, jnp.int32)

    def flush_into(st, mask, active_mask):
        """Retire ``mask`` slots into the lane-indexed buffers (fragment
        store + fin state). ONE (P,)-entry scatter builds the lane->slot
        inverse index; every field then moves by (B,)-gather + select —
        the only batched scatter in the engine, paid once per unrolled
        block, never per superstep."""
        lane = st["lane"]
        slot_of = jnp.full((b,), p, jnp.int32).at[
            jnp.where(mask, lane, b)].set(pids, mode="drop")
        mo = slot_of < p                                  # (B,) lane flushed
        src = jnp.minimum(slot_of, p - 1)
        take = lambda x: x[src]
        mt = mo & take(st["term"])
        ma = mo & take(active_mask)
        mfin = mt | ma
        st = dict(st)
        if not fullpath:
            st["frag"] = jnp.where(mo[:, None], st["prow"][src], st["frag"])
        st["fin_cur"] = jnp.where(mfin, take(st["cur"]), st["fin_cur"])
        st["fin_prev"] = jnp.where(mfin, take(st["prev"]), st["fin_prev"])
        st["fin_info"] = jax.tree_util.tree_map(
            lambda xp, xf: jnp.where(mfin, xp[src], xf),
            st["info"], st["fin_info"])
        st["fin_ring"] = jnp.where(mfin[:, None], st["ring"][src],
                                   st["fin_ring"])
        st["fin_h"] = jnp.where(mfin[:, None], st["h"][src], st["fin_h"])
        st["fin_valid"] = st["fin_valid"] | mfin
        st["fin_active"] = st["fin_active"] | ma
        if fullpath:
            st["fin_path"] = jnp.where(mfin[:, None], st["prow"][src],
                                       st["fin_path"])
        return st

    def flush_and_repack(st):
        """Flush ghosts + tombstones out of the pool, then gather-repack
        the surviving live lanes to the front — all selects and gathers."""
        lane = st["lane"]
        nonlive = (lane >= 0) & ~st["alive"]
        st = flush_into(st, nonlive, jnp.zeros((p,), bool))
        lane = jnp.where(nonlive, -1, lane)
        live = lane >= 0
        keys = ("lane", "cur", "prev", "info", "ring", "h", "prow")
        packed, pvalid = take_ranked(
            {kk: (lane if kk == "lane" else st[kk]) for kk in keys}, live, p)
        sel = lambda a, o: jnp.where(
            pvalid if a.ndim == 1 else pvalid[:, None], a, o)
        st["lane"] = jnp.where(pvalid, packed["lane"], -1)
        st["alive"] = pvalid
        st["term"] = jnp.zeros((p,), bool)
        st["cur"] = sel(packed["cur"], jnp.zeros_like(st["cur"]))
        st["prev"] = sel(packed["prev"], jnp.zeros_like(st["prev"]))
        st["info"] = jax.tree_util.tree_map(
            lambda a: jnp.where(pvalid, a, 0.0), packed["info"])
        st["ring"] = sel(packed["ring"], jnp.zeros_like(st["ring"]))
        st["h"] = sel(packed["h"], jnp.zeros_like(st["h"]))
        st["prow"] = jnp.where(pvalid[:, None], packed["prow"], -1)
        return st

    def superstep(st):
        """One flat BSP superstep — straight-line code, no inner control
        flow on the default transport. Globally-dead supersteps (the tail
        of an unrolled block) are value-level no-ops with ``t`` frozen."""
        lane = st["lane"]
        occ = (lane >= 0) & st["alive"]      # ghosts/tombstones don't walk
        ls = jnp.maximum(lane, 0)
        live_n = lax.psum(jnp.sum(occ, dtype=jnp.int32), AXIS)
        stepping = (live_n > 0) & (st["t"] < step_cap)
        u1f, u2f = ufn(root_key, st["t"])
        u1, u2 = u1f[ls], u2f[ls]

        # ---- phase A on the local slice ------------------------------------
        cur = st["cur"]
        cur_l = jnp.clip(local_of[cur], 0, max_nodes - 1)
        deg = (shard.indptr[cur_l + 1]
               - shard.indptr[cur_l]).astype(jnp.float32)
        deg = jnp.where(occ, deg, 0.0)                 # free slots are stale
        has_nbrs = deg > 0
        j = jnp.minimum((u1 * deg).astype(jnp.int32),
                        jnp.maximum(deg.astype(jnp.int32) - 1, 0))
        eidx = jnp.clip(shard.indptr[cur_l].astype(jnp.int32) + j,
                        0, max_edges - 1)
        cand = shard.indices[eidx]                     # global neighbor id
        cand_owner = shard.nbr_owner[eidx]             # halo remap: owner()
        p_acc = policy.accept_prob_local(shard, st["prev"], cur_l, cand, eidx)
        accept_raw = has_nbrs & (u2 < p_acc)
        accept = occ & accept_raw & stepping
        dead_end = occ & ~has_nbrs & stepping
        mig = accept & (cand_owner != sid)
        stay = accept & ~mig

        prow = st["prow"]
        if fullpath:
            # Pre-append the accepted node at the origin (the message
            # carries the walk INCLUDING it) — one-hot select, no scatter.
            idxL = jnp.clip(st["info"].L.astype(jnp.int32), 0,
                            spec.max_len - 1)
            lpos = jnp.arange(spec.max_len, dtype=jnp.int32)[None, :]
            prow = jnp.where(accept[:, None] & (lpos == idxL[:, None]),
                             cand[:, None], prow)
            ship_sz = jnp.sum((prow >= 0).astype(jnp.int32), axis=1)

        # ---- packed sparse exchange ----------------------------------------
        info = st["info"]
        pay = {"i": jnp.stack([lane, cur, cand], axis=1),
               "f": jnp.stack([info.H, info.L, info.EH, info.EL, info.EHL,
                               info.EH2, info.EL2], axis=1)}
        if spec.reg_window:
            pay["ring"] = st["ring"]
        if fullpath:
            pay["path"] = prow
            pay["h"] = st["h"]
        shipped_fields = pay["i"].shape[1] + pay["f"].shape[1] + (
            pay["ring"].shape[1] if spec.reg_window else 0)

        n_mig = jnp.sum(mig.astype(jnp.int32))
        if fullpath:
            add_an = jnp.sum(jnp.where(
                mig, incom.fullpath_msg_bytes(info.L + 1.0), 0.0))
        else:
            add_an = jnp.float32(incom.MSG_BYTES
                                 + 8 * (spec.reg_window or 0)) * n_mig

        sp0 = dict(
            pending=mig, lane=lane, alive=st["alive"], term=st["term"],
            cur=cur, prev=st["prev"],
            info=info, ring=st["ring"], h=st["h"], prow=prow,
            proc=stay, pcand=cand,
            overflow=jnp.zeros((), jnp.int32),
            msg_count=jnp.zeros((), jnp.int32),
            msg_bytes=jnp.zeros((), jnp.float32),
        )

        def sp_round(c):
            if transport == "a2a":
                # Destination-bucketed point-to-point swap (mesh path):
                # every received record is addressed to this shard.
                arr, arr_valid, sent = packed_all_to_all(
                    pay, cand_owner, c["pending"], k, r_cap, AXIS)
                mine = arr_valid.reshape(n_rec)
            elif transport == "gather":
                # Packed broadcast: receivers filter records by the
                # candidate's owner, recomputed from the record.
                arr, arr_valid, sent = packed_all_gather(
                    pay, c["pending"], r_cap, AXIS)
                cand_flat = arr["i"].reshape(n_rec, 3)[:, 2]
                mine = arr_valid.reshape(n_rec) & (
                    owner[jnp.maximum(cand_flat, 0)] == sid)
            else:
                # Flat pool transport (stacked default): the P-wide lane
                # payload travels masked — one round ALWAYS delivers every
                # migrant, so the superstep stays straight-line code.
                sent = c["pending"]
                arr = jax.tree_util.tree_map(
                    lambda x: lax.all_gather(x, AXIS), pay)
                a_lane = arr["i"].reshape(n_rec, 3)[:, 0]
                a_cand = arr["i"].reshape(n_rec, 3)[:, 2]
                pend_all = lax.all_gather(c["pending"], AXIS
                                          ).reshape(n_rec)
                mine = pend_all & (
                    owner[jnp.maximum(a_cand, 0)] == sid) & (a_lane >= 0)
            a_i = arr["i"].reshape(n_rec, 3)
            a_f = arr["f"].reshape(n_rec, 7)

            if fullpath:
                # The walk left with its walker; the sender slot frees.
                lane1 = jnp.where(sent, -1, c["lane"])
                revived = jnp.zeros((p,), bool)
                rrec = jnp.zeros((p,), jnp.int32)
                rec_unrevived = mine
            else:
                # The sender slot becomes a fragment GHOST: the walker's
                # owner-local path rows stay (they never travel) so a
                # returning walker can resume its n(v) history; the rows
                # retire to the store at the next flush. A RETURNING
                # walker REVIVES its own ghost slot in place — no free
                # slot needed, which is what keeps per-shard occupancy
                # bounded by one slot per lane (so pool == B never
                # overflows) and the fragment row simply stays put.
                lane1 = c["lane"]
                ghost = (lane1 >= 0) & ~c["alive"] & ~c["term"]
                rl = a_i[:, 0]
                rm = (lane1[:, None] == rl[None, :]) \
                    & mine[None, :] & ghost[:, None]     # (P, n_rec)
                revived = jnp.any(rm, axis=1)
                rrec = jnp.argmax(rm, axis=1).astype(jnp.int32)
                rec_unrevived = mine & ~jnp.any(rm, axis=0)
            alive1 = c["alive"] & ~sent
            free = lane1 < 0
            # Gather-based placement for first-visit arrivals: the r-th
            # free slot (ascending index) takes the r-th unrevived record
            # addressed to me (ascending (source shard, record position)
            # order) — scatter-free and deterministic, so walks never
            # depend on the transport.
            free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
            mcum = jnp.cumsum(rec_unrevived.astype(jnp.int32))
            n_mine = mcum[-1]
            takes = free & (free_rank < n_mine)
            rec_idx = jnp.clip(rank_search(mcum, free_rank + 1),
                               0, n_rec - 1)
            place = takes | revived
            rec_sel = jnp.where(revived, rrec, rec_idx)
            t_i = a_i[rec_sel]                          # (P, 3)
            t_f = a_f[rec_sel]                          # (P, 7)
            a_info = incom.InfoState(
                H=t_f[:, 0], L=t_f[:, 1], EH=t_f[:, 2], EL=t_f[:, 3],
                EHL=t_f[:, 4], EH2=t_f[:, 5], EL2=t_f[:, 6])

            if fullpath:
                prow1 = jnp.where(
                    takes[:, None],
                    arr["path"].reshape(n_rec, spec.max_len)[rec_sel],
                    c["prow"])
            else:
                # First-visit (or post-flush return) fragment rows come
                # from the lane-indexed store; a revived slot's row is
                # already in place. Resolved PER SLOT (P-sized — the
                # record axis is k·cap wide and row ops there blow up k^2
                # under the stacked emulation).
                t_lane = jnp.where(takes, t_i[:, 0], 0)
                prow1 = jnp.where(takes[:, None], st["frag"][t_lane],
                                  c["prow"])

            out = dict(
                pending=c["pending"] & ~sent,
                lane=jnp.where(takes, t_i[:, 0], lane1),
                alive=alive1 | place,
                term=c["term"] & ~place,
                cur=jnp.where(place, t_i[:, 1], c["cur"]),
                prev=jnp.where(place, t_i[:, 1], c["prev"]),
                info=_info_select(place, a_info, c["info"]),
                ring=(jnp.where(place[:, None],
                                arr["ring"].reshape(n_rec, k_ring)[rec_sel],
                                c["ring"])
                      if spec.reg_window else c["ring"]),
                h=(jnp.where(place[:, None],
                             arr["h"].reshape(n_rec, h_len)[rec_sel],
                             c["h"])
                   if fullpath else c["h"]),
                prow=prow1,
                proc=c["proc"] | place,
                pcand=jnp.where(place, t_i[:, 2], c["pcand"]),
                overflow=c["overflow"]
                + jnp.maximum(n_mine - jnp.sum(free, dtype=jnp.int32), 0),
            )
            n_sent = jnp.sum(sent, dtype=jnp.int32)
            if fullpath:
                shipped = jnp.sum(jnp.where(sent, ship_sz, 0))
                add_meas = (8.0 * pay["i"].shape[1]) * n_sent + 8.0 * shipped
            else:
                add_meas = jnp.float32(8.0 * shipped_fields) * n_sent
            out["msg_count"] = c["msg_count"] + n_sent
            out["msg_bytes"] = c["msg_bytes"] + add_meas
            return out

        if flat:
            sp = sp_round(sp0)     # one round always delivers everything
        else:
            def sp_cond(c):
                n = jnp.sum(c["pending"], dtype=jnp.int32)
                return lax.psum(n, AXIS) > 0

            # Spill rounds: self-skips when no shard has a migrant, loops
            # while more than ``cap`` migrants queue at one sender.
            sp = lax.while_loop(sp_cond, sp_round, sp0)

        # ---- phase B on the compacted pool ---------------------------------
        lane_x, proc, pcand = sp["lane"], sp["proc"], sp["pcand"]
        occ_now = jnp.sum((lane_x >= 0).astype(jnp.int32))
        info2, path2, h2, ring2, done_now = wk.absorb(
            spec, sp["info"], sp["prow"], sp["h"], sp["ring"], pcand, proc)
        cur2 = jnp.where(proc, pcand, sp["cur"])
        prev2 = jnp.where(proc, sp["cur"], sp["prev"])
        done = (proc & done_now) | dead_end

        nxt = dict(st)
        nxt.update(
            lane=lane_x,
            # Terminated walkers tombstone: state freezes in the pool and
            # retires to the fin buffers at the block flush.
            alive=sp["alive"] & (lane_x >= 0) & ~done,
            term=sp["term"] | done,
            cur=cur2, prev=prev2, info=info2, ring=ring2, h=h2, prow=path2,
            t=st["t"] + stepping.astype(jnp.int32),
            accepts=st["accepts"] + jnp.sum(accept, dtype=jnp.int32),
            rejects=st["rejects"]
            + jnp.sum(occ & has_nbrs & ~accept_raw & stepping,
                      dtype=jnp.int32),
            msg_count=st["msg_count"] + sp["msg_count"],
            msg_bytes=st["msg_bytes"] + sp["msg_bytes"],
            msg_bytes_analytic=st["msg_bytes_analytic"] + add_an,
            overflow=st["overflow"] + sp["overflow"],
            peak_occ=jnp.maximum(st["peak_occ"], occ_now),
        )
        return nxt

    def cond(st):
        live = jnp.sum((st["lane"] >= 0) & st["alive"], dtype=jnp.int32)
        return (lax.psum(live, AXIS) > 0) & (st["t"] < step_cap)

    def body(st):
        # ``unroll`` straight-line supersteps, then ONE unconditional
        # flush/repack: no lax.cond in the loop (its operand threading
        # copied every buffer every superstep), and the block tail runs as
        # cheap no-op supersteps when the walk ends mid-block.
        for _ in range(unroll):
            st = superstep(st)
        return flush_and_repack(st)

    out = lax.while_loop(cond, body, st0)

    # ---- final flush: ghosts, tombstones AND still-live lanes --------------
    filled = out["lane"] >= 0
    out = flush_into(out, filled, out["alive"])
    out["occ_final"] = jnp.sum(filled.astype(jnp.int32))
    out.pop("alive")
    out.pop("term")
    out.pop("prow")
    return out


# ---------------------------------------------------------------------------
# Drivers: stacked emulation (vmap) and SPMD (shard_map), both engines
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("policy", "spec", "num_shards"))
def _run_stacked(graph, owner, sources, root_key, policy, spec, num_shards):
    def per_shard(_marker):
        return _shard_program_replicated(graph, owner, sources, root_key,
                                         policy, spec)

    return jax.vmap(per_shard, axis_name=AXIS)(jnp.arange(num_shards))


def _run_spmd(graph, owner, sources, root_key, policy, spec,
              num_shards: int, mesh: Mesh):
    from jax.experimental.shard_map import shard_map

    def per_shard(graph_, owner_, sources_, key_, _marker):
        out = _shard_program_replicated(graph_, owner_, sources_, key_,
                                        policy, spec)
        return jax.tree_util.tree_map(lambda x: x[None], out)

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(AXIS)),
        out_specs=P(AXIS),
        check_rep=False,
    )
    return fn(graph, owner, sources, root_key, jnp.arange(num_shards))


@functools.partial(jax.jit,
                   static_argnames=("policy", "spec", "num_shards", "pool",
                                    "cap", "compact_every", "transport"))
def _run_stacked_local(slices, local_of, owner, sources, root_key,
                       policy, spec, num_shards, pool, cap, compact_every,
                       transport):
    def per_shard(shard):
        return _shard_program_local(shard, local_of, owner, sources, root_key,
                                    policy, spec, num_shards, pool, cap,
                                    compact_every, transport)

    return jax.vmap(per_shard, axis_name=AXIS)(slices)


def _run_spmd_local(slices, local_of, owner, sources, root_key,
                    policy, spec, num_shards: int, mesh: Mesh,
                    pool: int, cap: int, compact_every: int, transport: str):
    from jax.experimental.shard_map import shard_map

    def per_shard(slices_, local_of_, owner_, sources_, key_):
        out = _shard_program_local(
            slices_.take_shard(), local_of_, owner_, sources_, key_,
            policy, spec, num_shards, pool, cap, compact_every, transport)
        return jax.tree_util.tree_map(lambda x: x[None], out)

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(AXIS), P(), P(), P(), P()),
        out_specs=P(AXIS),
        check_rep=False,
    )
    return fn(slices, local_of, owner, sources, root_key)


# ---------------------------------------------------------------------------
# Merges
# ---------------------------------------------------------------------------


def _merge(out, spec: wk.WalkSpec, root_key) -> wk.WalkerBatchState:
    """Combine the (k, ...) replicated-engine outputs into one state."""
    res = out["resident"]                                    # (k, B)
    pick = lambda x: jnp.sum(jnp.where(res, x, 0), axis=0)   # 1 resident/lane
    pickf = lambda x: jnp.sum(
        jnp.where(res[..., None], x, 0), axis=0)
    if spec.info_mode == "fullpath":
        # The walk travels whole; only the final resident copy is current.
        path = jnp.max(jnp.where(res[..., None], out["path"], -1), axis=0)
    else:
        # Fragment union: each position was written by exactly one owner.
        path = jnp.max(out["path"], axis=0)
    info = incom.InfoState(
        H=pick(out["info"].H), L=pick(out["info"].L),
        EH=pick(out["info"].EH), EL=pick(out["info"].EL),
        EHL=pick(out["info"].EHL), EH2=pick(out["info"].EH2),
        EL2=pick(out["info"].EL2))
    return wk.WalkerBatchState(
        cur=pick(out["cur"].astype(jnp.int32)),
        prev=pick(out["prev"].astype(jnp.int32)),
        path=path,
        info=info,
        h_series=pickf(out["h"]),
        hring=pickf(out["ring"]),
        active=jnp.any(out["resident"] & out["active"], axis=0),
        key=root_key,
        supersteps=jnp.max(out["t"]),        # max, not [0]: shard skew safe
        accepts=jnp.sum(out["accepts"]),
        rejects=jnp.sum(out["rejects"]),
        msg_count=jnp.sum(out["msg_count"]),
        msg_bytes=jnp.sum(out["msg_bytes"]),
        msg_bytes_analytic=jnp.sum(out["msg_bytes_analytic"]),
    )


def _merge_local(out, spec: wk.WalkSpec, root_key) -> wk.WalkerBatchState:
    """Combine the (k, ...) compacted-engine outputs into one state.

    Each lane retired (or was flushed) at EXACTLY one shard — the one whose
    ``fin_valid`` row is set — so the scalar merge is the same
    one-resident-per-lane sum the replicated merge uses; the path is the
    fragment union (incom) or the retiring copy (fullpath)."""
    fv = out["fin_valid"]                                    # (k, B)
    pick = lambda x: jnp.sum(jnp.where(fv, x, 0), axis=0)
    pickf = lambda x: jnp.sum(jnp.where(fv[..., None], x, 0), axis=0)
    if spec.info_mode == "fullpath":
        path = jnp.max(jnp.where(fv[..., None], out["fin_path"], -1), axis=0)
    else:
        path = jnp.max(out["frag"], axis=0)
    fi = out["fin_info"]
    info = incom.InfoState(
        H=pick(fi.H), L=pick(fi.L), EH=pick(fi.EH), EL=pick(fi.EL),
        EHL=pick(fi.EHL), EH2=pick(fi.EH2), EL2=pick(fi.EL2))
    return wk.WalkerBatchState(
        cur=pick(out["fin_cur"]),
        prev=pick(out["fin_prev"]),
        path=path,
        info=info,
        h_series=pickf(out["fin_h"]),
        hring=pickf(out["fin_ring"]),
        active=jnp.any(fv & out["fin_active"], axis=0),
        key=root_key,
        supersteps=jnp.max(out["t"]),        # max, not [0]: shard skew safe
        accepts=jnp.sum(out["accepts"]),
        rejects=jnp.sum(out["rejects"]),
        msg_count=jnp.sum(out["msg_count"]),
        msg_bytes=jnp.sum(out["msg_bytes"]),
        msg_bytes_analytic=jnp.sum(out["msg_bytes_analytic"]),
    )


def _shard_stats(out, pcsr: Optional[PartitionedCSR], pool: Optional[int],
                 cap: Optional[int], retries: int) -> Dict:
    """Per-shard balance/occupancy/traffic stats (benchmark surface)."""
    stats: Dict = {
        "supersteps": np.asarray(out["t"]).astype(int).tolist(),
        "msg_count": np.asarray(out["msg_count"]).astype(int).tolist(),
    }
    if "peak_occ" in out:
        stats["peak_lane_occupancy"] = (
            np.asarray(out["peak_occ"]).astype(int).tolist())
        stats["final_lane_occupancy"] = (
            np.asarray(out["occ_final"]).astype(int).tolist())
        stats["pool_slots"] = pool
        stats["exchange_cap"] = cap
        stats["pool_retries"] = retries
    if pcsr is not None:
        stats["owned_nodes"] = pcsr.num_owned.astype(int).tolist()
        stats["csr_bytes_per_shard"] = pcsr.shard_csr_nbytes().astype(
            int).tolist()
    # Everything above was already pulled to host for the stats dict;
    # exporting it to the registry adds no device syncs.
    if obs.enabled():
        obs.inc("walk.supersteps", float(np.sum(stats["supersteps"])))
        obs.inc("walk.msg_count", float(np.sum(stats["msg_count"])))
        if "peak_lane_occupancy" in stats:
            obs.set_gauges("walk.peak_occ", stats["peak_lane_occupancy"])
            obs.set_gauge("walk.pool_slots", stats["pool_slots"])
            obs.inc("walk.pool_retries", stats["pool_retries"])
        if "csr_bytes_per_shard" in stats:
            obs.set_gauges("walk.csr_bytes", stats["csr_bytes_per_shard"])
    return stats


# ---------------------------------------------------------------------------
# Partition-local store cache + public driver
# ---------------------------------------------------------------------------


_PCSR_CACHE: Dict = {}
_POOL_CACHE: Dict = {}


def partitioned_csr_for(graph: CSRGraph, assignment: np.ndarray,
                        num_shards: int,
                        key_obj: object = None) -> PartitionedCSR:
    """Memoized ``build_partitioned_csr`` — the slicing is host-side O(|E|)
    preprocessing and the engine is called once per walk batch per round.

    ``key_obj`` names the object whose identity keys the cache; pass the
    CALLER-HELD graph when ``graph`` is a derived copy (e.g. the result of
    ``with_edge_cm()``, which is a fresh object every call and would never
    hit). Entries hold the key object by WEAKREF so a dropped graph's
    device-resident slices free with it, and the key carries the slicing
    graph's edge_cm presence so a cm-less entry is never served to a
    policy that needs Cm. The key also carries the graph's MUTATION
    VERSION (``graph.delta.graph_version``): a graph mutated through the
    delta overlay bumps its version, so an in-place edit of a held object
    can never be served the pre-mutation slices (identity alone would
    silently alias them)."""
    import weakref
    from repro.graph.delta import graph_version
    key_obj = graph if key_obj is None else key_obj
    asn = np.asarray(assignment)
    key = (id(key_obj), graph_version(key_obj), num_shards,
           graph.edge_cm is not None, hash(asn.tobytes()))
    hit = _PCSR_CACHE.get(key)
    if hit is not None and hit[0]() is key_obj:
        return hit[1]
    pcsr = build_partitioned_csr(graph, asn, num_shards)
    if len(_PCSR_CACHE) >= 8:
        _PCSR_CACHE.clear()
    _PCSR_CACHE[key] = (weakref.ref(key_obj), pcsr)
    return pcsr


def run_walk_sharded(
    graph: CSRGraph,
    sources: jax.Array,
    key: jax.Array,
    policy: Policy,
    spec: wk.WalkSpec,
    assignment: jax.Array,
    num_shards: int,
    mesh: Optional[Mesh] = None,
    *,
    engine: str = "auto",
    pool_factor: float = 2.0,
    exchange_cap: Optional[int] = None,
    compact_every: int = 8,
    transport: Optional[str] = None,
    with_stats: bool = False,
):
    """Run one walk per source on ``num_shards`` partition shards.

    ``assignment`` maps node -> owning shard (MPGP output). With ``mesh``
    (k devices) the program runs SPMD under shard_map; otherwise the k
    shards run as a stacked vmap axis on the local device. Results are
    bit-identical across both executions and across shard counts.

    ``engine`` picks the realization: ``"local"`` (partition-local CSR
    slices + compacted lane pool + packed sparse exchange), ``"replicated"``
    (full-width reference), or ``"auto"`` — local whenever the policy can
    evaluate its transition from one shard's slice
    (``policy.supports_partition_local``). ``pool_factor`` is the gamma of
    the MPGP balance bound sizing the per-shard slot pool
    (pool = gamma·B/k, doubled and re-run on the rare occupancy overflow);
    ``exchange_cap`` bounds records per source per spill round (per
    (source, destination) bucket under the all_to_all transport).
    ``transport`` forces the exchange realization — ``"gather"``
    (all_gather broadcast, the stacked default) or ``"a2a"``
    (destination-bucketed ``lax.all_to_all``, the mesh default); walks are
    bit-identical under either. ``with_stats=True`` additionally returns
    the per-shard balance/occupancy/traffic dict.
    """
    sources = jnp.asarray(sources, jnp.int32)
    owner = jnp.asarray(assignment, jnp.int32)
    graph_key = graph          # caches key on the CALLER's (stable) object
    if getattr(policy, "needs_edge_cm", False) and graph.edge_cm is None:
        graph = graph.with_edge_cm()
    use_mesh = mesh is not None and int(mesh.shape[AXIS]) == num_shards
    if engine == "auto":
        # Partition-local is the memory-correct engine when shards map to
        # real devices (each holds only its |V|/k + |E|/k slice). Under the
        # single-device stacked emulation there is no memory to save and
        # the k per-shard programs serialize, so the replicated fast path
        # wins wall-clock; tests/benchmarks pass engine="local" explicitly.
        engine = ("local"
                  if use_mesh
                  and getattr(policy, "supports_partition_local", False)
                  else "replicated")

    if engine == "replicated":
        if use_mesh:
            out = _run_spmd(graph, owner, sources, key, policy, spec,
                            num_shards, mesh)
        else:
            out = _run_stacked(graph, owner, sources, key, policy, spec,
                               num_shards)
        state = _merge(out, spec, key)
        if with_stats:
            return state, _shard_stats(out, None, None, None, 0)
        return state
    if engine != "local":
        raise ValueError(f"unknown engine {engine!r}")
    if not getattr(policy, "supports_partition_local", False):
        raise ValueError(
            f"{type(policy).__name__} cannot run partition-local (it reads "
            "non-local CSR rows); use engine='replicated'")

    asn_np = np.asarray(assignment)
    pcsr = partitioned_csr_for(graph, asn_np, num_shards, key_obj=graph_key)
    b = int(sources.shape[0])
    init_occ = np.bincount(asn_np[np.asarray(sources)],
                           minlength=num_shards) if b else np.zeros(1)
    pool = min(b, max(int(np.ceil(pool_factor * b / max(num_shards, 1))),
                      int(init_occ.max()), 1))
    # Occupancy (live + ghosts + tombstones between flushes) is workload-
    # dependent; the overflow retry discovers the working pool size and
    # this cache remembers it, so steady-state callers (benchmark reps,
    # streaming rounds) run the engine exactly once per batch. Entries
    # weakly hold the keying graph so a recycled id() can never alias and
    # dead graphs don't pin memory.
    import weakref
    from repro.graph.delta import graph_version
    pool_key = (id(graph_key), graph_version(graph_key), num_shards, b,
                spec, float(pool_factor), hash(asn_np.tobytes()))
    hit = _POOL_CACHE.get(pool_key)
    if hit is not None and hit[0]() is graph_key:
        pool = max(pool, hit[1])
    cap = int(exchange_cap) if exchange_cap else max(8, pool // 8)
    if transport is None:
        # a2a = point-to-point buckets on a real mesh; the packed broadcast
        # is the stacked default — its spill loop constant-folds away when
        # a shard count makes migration impossible and self-skips on
        # migrant-free supersteps, unlike the flat "pool" transport which
        # pays its all_gather every superstep.
        transport = "a2a" if use_mesh else "gather"
    if transport not in ("pool", "gather", "a2a"):
        raise ValueError(f"unknown transport {transport!r}")

    retries = 0
    t0 = time.perf_counter() if obs.enabled() else 0.0
    while True:
        if use_mesh:
            out = _run_spmd_local(
                pcsr.slices, pcsr.local_of, owner, sources, key, policy,
                spec, num_shards, mesh, pool, cap, compact_every, transport)
        else:
            out = _run_stacked_local(
                pcsr.slices, pcsr.local_of, owner, sources, key, policy,
                spec, num_shards, pool, cap, compact_every, transport)
        if int(jnp.sum(out["overflow"])) == 0:
            break
        # MPGP balance bound violated at this pool size: walkers piled onto
        # one shard beyond gamma·B/k. Double the pool and re-run — at
        # pool == B overflow is impossible (arrivals + residents <= B).
        assert pool < b, "slot pool of size B cannot overflow"
        pool = min(b, pool * 2)
        retries += 1
    if retries:
        if len(_POOL_CACHE) >= 64:
            _POOL_CACHE.clear()
        _POOL_CACHE[pool_key] = (weakref.ref(graph_key), pool)
    if obs.enabled():
        # The overflow check above already synced the dispatch; the wall
        # measured here is real device time, not just enqueue latency.
        obs.observe("walk.batch_dispatch.s", time.perf_counter() - t0)
        obs.inc("walk.engine_batches")
        obs.inc("walk.spill_retries", retries)
        obs.set_gauge("walk.pool_slots", pool)
    state = _merge_local(out, spec, key)
    if with_stats:
        return state, _shard_stats(out, pcsr, pool, cap, retries)
    return state


def reconfigure_partitions(
    graph: CSRGraph,
    old_assignment: np.ndarray,
    new_assignment: np.ndarray,
    num_shards_new: int,
    *,
    old_of_new: np.ndarray,
    num_shards_old: Optional[int] = None,
    key_obj: object = None,
) -> Dict:
    """Swap the cached partition-local store to a new shard layout after
    an elastic reconfiguration (DESIGN.md §12) — a k → k-1 shard death
    (the default: ``num_shards_old`` falls back to ``num_shards_new + 1``)
    or a k → k+1 re-JOIN (pass ``num_shards_old`` explicitly, with a
    ``-1`` entry in ``old_of_new`` for the returned shard).

    Looks up the old ``PartitionedCSR`` in the cache; when found (the
    steady-state case — the walk engine built it on the previous round),
    the new store is assembled by ``reassign_partitioned_csr`` with the
    untouched shards' edge slices copied instead of re-scattered.
    Otherwise it falls back to a fresh ``build_partitioned_csr``. The new
    store is PRIMED into the cache under the new assignment's key so the
    next walk round hits, and every cache entry keyed on the replaced
    assignment — partition slices and learned slot-pool sizes — is
    evicted (the pool sizing of a k-way layout says nothing about k±1).

    Returns ``{"reused_shards", "rebuilt_shards", "wall_s"}``.
    """
    import time
    import weakref

    from repro.graph.csr import reassign_partitioned_csr
    from repro.graph.delta import graph_version

    t0 = time.perf_counter()
    key_obj = graph if key_obj is None else key_obj
    old_asn = np.asarray(old_assignment)
    new_asn = np.asarray(new_assignment)
    gv = graph_version(key_obj)
    k_old = (num_shards_new + 1 if num_shards_old is None
             else int(num_shards_old))
    h_old = hash(old_asn.tobytes())

    # Find a live old entry whose feature set (weights/cm presence) matches
    # the graph we are slicing — reuse needs like-for-like rows. The cm flag
    # in the key tracks the SLICING graph, which run_walk_sharded may have
    # cm-augmented, so match on the store itself rather than the flag.
    old_pcsr = None
    for key, (ref, pcsr) in list(_PCSR_CACHE.items()):
        if (key[0] == id(key_obj) and key[1] == gv and key[2] == k_old
                and key[4] == h_old and ref() is key_obj
                and (pcsr.slices.edge_cm is not None)
                == (graph.edge_cm is not None)
                and (pcsr.slices.weights is not None)
                == (graph.weights is not None)):
            old_pcsr = pcsr
            break

    if old_pcsr is not None:
        new_pcsr, reused = reassign_partitioned_csr(
            graph, new_asn, num_shards_new, old=old_pcsr,
            old_assignment=old_asn, old_of_new=np.asarray(old_of_new))
    else:
        new_pcsr, reused = build_partitioned_csr(
            graph, new_asn, num_shards_new), 0

    # Evict everything keyed on the dead layout, then prime the new one.
    for key in [k for k in _PCSR_CACHE
                if k[0] == id(key_obj) and k[4] == h_old]:
        del _PCSR_CACHE[key]
    for key in [k for k in _POOL_CACHE
                if k[0] == id(key_obj) and k[-1] == h_old]:
        del _POOL_CACHE[key]
    new_key = (id(key_obj), gv, num_shards_new, graph.edge_cm is not None,
               hash(new_asn.tobytes()))
    if len(_PCSR_CACHE) >= 8:
        _PCSR_CACHE.clear()
    _PCSR_CACHE[new_key] = (weakref.ref(key_obj), new_pcsr)

    return {
        "reused_shards": int(reused),
        "rebuilt_shards": int(num_shards_new - reused),
        "wall_s": float(time.perf_counter() - t0),
    }
