"""MPGP — multi-proximity-aware streaming parallel graph partitioning (§3.2).

An un-partitioned node v is assigned to

    argmax_i ( PS1(v, P_i) + PS2(v, P_i) ) * tau(P_i)          (Eq. 14)
    tau(P_i) = 1 - |P_i| / (gamma * (sum_j |P_j|) / m)          (Eq. 15)

PS1 = |N(v) ∩ P_i|  (first-order proximity: neighbors already in P_i)
PS2 = Σ_{u ∈ P_i ∩ N(v)} |N(v) ∩ N(u)|  (second-order: common neighbors,
      restricted — per the paper's second optimization — to u that are
      themselves neighbors of v, since a walker cannot jump elsewhere).

Weighted graphs multiply each term by w(v, u) (paper §3.2).

Streaming orders (paper's third optimization): random, bfs, dfs,
bfs+degree, dfs+degree (the recommended orders pick the highest-degree
unexplored neighbor first). Parallel MPGP (fourth optimization) splits the
stream into segments partitioned independently and merges.

Intersections use searchsorted-based galloping on the sorted CSR rows.
Partition membership is O(1) via an assignment array, so PS1 is a
vectorized membership-count — the streaming loop itself is host-side
(partitioning is preprocessing; DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.stats import edge_locality, partition_balance


@dataclasses.dataclass
class PartitionResult:
    assignment: np.ndarray       # (|V|,) int32 partition id per node
    num_parts: int
    gamma: float
    order: str
    seconds: float
    locality: float              # fraction of arcs kept intra-partition
    balance: float               # max/mean partition size

    def counts(self) -> np.ndarray:
        return np.bincount(self.assignment, minlength=self.num_parts)


def _intersect_count_sorted(a: np.ndarray, b: np.ndarray) -> int:
    """|a ∩ b| for sorted int arrays via galloping (binary) search of the
    smaller set into the larger — O(S1 log S2), the paper's Galloping use."""
    if a.size > b.size:
        a, b = b, a
    if a.size == 0 or b.size == 0:
        return 0
    pos = np.searchsorted(b, a)
    pos = np.minimum(pos, b.size - 1)
    return int(np.sum(b[pos] == a))


def stream_order(
    graph: CSRGraph, order: str, seed: int = 0
) -> np.ndarray:
    """Node visit order for the stream. BFS/DFS run over all components;
    '+degree' variants visit the highest-degree unexplored neighbor first."""
    g = graph.to_numpy()
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    n = len(indptr) - 1
    order = order.lower()
    if order == "random":
        return np.random.default_rng(seed).permutation(n).astype(np.int64)
    if order == "natural":
        return np.arange(n, dtype=np.int64)

    by_degree = order.endswith("+degree") or order.endswith("+deg")
    kind = order.split("+")[0]
    if kind not in ("bfs", "dfs"):
        raise ValueError(f"unknown stream order {order!r}")

    deg = indptr[1:] - indptr[:-1]
    visited = np.zeros(n, dtype=bool)
    out = np.empty(n, dtype=np.int64)
    k = 0
    # Seed traversals from highest-degree roots for determinism + quality.
    roots = np.argsort(-deg, kind="stable")
    from collections import deque

    for root in roots:
        if visited[root]:
            continue
        if kind == "bfs":
            dq = deque([root])
            visited[root] = True
            while dq:
                u = dq.popleft()
                out[k] = u
                k += 1
                nbrs = indices[indptr[u]:indptr[u + 1]]
                if by_degree:
                    nbrs = nbrs[np.argsort(-deg[nbrs], kind="stable")]
                for v in nbrs:
                    if not visited[v]:
                        visited[v] = True
                        dq.append(v)
        else:  # dfs
            stack = [root]
            visited[root] = True
            while stack:
                u = stack.pop()
                out[k] = u
                k += 1
                nbrs = indices[indptr[u]:indptr[u + 1]]
                if by_degree:
                    # push lowest-degree first so highest-degree pops first
                    nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                for v in nbrs:
                    if not visited[v]:
                        visited[v] = True
                        stack.append(v)
    assert k == n
    return out


def _assign_stream(
    graph_np: CSRGraph,
    nodes: np.ndarray,
    assignment: np.ndarray,
    counts: np.ndarray,
    num_parts: int,
    gamma: float,
    use_ps2: bool = True,
    tau_weight: str = "nodes",
    allowed: Optional[np.ndarray] = None,
) -> None:
    """Assign ``nodes`` (in order) in-place into ``assignment``/``counts``.

    ``assignment`` may already contain other segments' results (parallel
    MPGP merges into shared state); -1 marks unassigned. ``allowed``
    (bool (num_parts,)) restricts the argmax to a subset of partitions —
    the elastic-reconfiguration path streams a dead shard's orphans into
    the SURVIVORS only.

    ``tau_weight`` selects the LOAD each node contributes to the Eq. 15
    capacity term tau(P_i): ``"nodes"`` is the paper-literal node count;
    ``"degree"`` charges deg(v) + 1, so capacity tracks DEGREE MASS.
    Walker occupancy follows degree mass, not node count (a walker at v
    next occupies a neighbor drawn from N(v)), so on degree-skewed graphs
    the node-count tau lets one shard accumulate most of the edge mass
    and with it most of the walkers — BENCH_walk's peak_lane_occupancy
    measured 384/512 walkers piling onto one shard of a 4-way rmat
    partition. Degree-weighted tau makes the gamma*B/k slot-pool bound of
    the partition-local engine actually bind.
    """
    indptr = graph_np.indptr
    indices = graph_np.indices
    weights = graph_np.weights
    if tau_weight not in ("nodes", "degree"):
        raise ValueError(f"unknown tau_weight {tau_weight!r}")
    degree_tau = tau_weight == "degree"

    for v in nodes:
        lo, hi = indptr[v], indptr[v + 1]
        nbrs = indices[lo:hi]
        w = weights[lo:hi] if weights is not None else None
        parts = assignment[nbrs]
        placed = parts >= 0
        scores = np.zeros(num_parts, dtype=np.float64)
        if placed.any():
            pn = parts[placed]
            # PS1: (weighted) count of v's neighbors already in each P_i.
            if w is None:
                np.add.at(scores, pn, 1.0)
            else:
                np.add.at(scores, pn, w[placed].astype(np.float64))
            if use_ps2:
                # PS2 restricted to u ∈ N(v) (optimization 2): common
                # neighbors |N(v) ∩ N(u)| via galloping intersection.
                placed_nbrs = nbrs[placed]
                for j, u in enumerate(placed_nbrs):
                    cm = _intersect_count_sorted(
                        nbrs, indices[indptr[u]:indptr[u + 1]]
                    )
                    wt = 1.0 if w is None else float(w[placed][j])
                    scores[pn[j]] += cm * wt
        total = counts.sum()
        if total > 0:
            tau = 1.0 - counts / (gamma * total / num_parts)
        else:
            tau = np.ones(num_parts)
        # Nodes with no placed neighbors score 0 everywhere: tau breaks the
        # tie toward the least-loaded partition (keeps balance).
        obj = scores * tau if scores.any() else tau
        if allowed is not None:
            obj = np.where(allowed, obj, -np.inf)
        p = int(np.argmax(obj))
        assignment[v] = p
        counts[p] += (hi - lo + 1) if degree_tau else 1


def mpgp_partition(
    graph: CSRGraph,
    num_parts: int,
    *,
    gamma: float = 2.0,
    order: str = "dfs+degree",
    use_ps2: bool = True,
    seed: int = 0,
    tau_weight: str = "nodes",
) -> PartitionResult:
    """Sequential MPGP (paper-recommended order: DFS+degree).

    ``tau_weight="degree"`` switches Eq. 15's capacity term to degree
    mass so walker load balances across shards (see ``_assign_stream``).
    """
    t0 = time.perf_counter()
    g = graph.to_numpy()
    n = g.num_nodes
    nodes = stream_order(graph, order, seed)
    assignment = np.full(n, -1, dtype=np.int32)
    counts = np.zeros(num_parts, dtype=np.int64)
    _assign_stream(g, nodes, assignment, counts, num_parts, gamma, use_ps2,
                   tau_weight)
    dt = time.perf_counter() - t0
    return PartitionResult(
        assignment=assignment,
        num_parts=num_parts,
        gamma=gamma,
        order=order if tau_weight == "nodes" else f"{order}:tau={tau_weight}",
        seconds=dt,
        locality=edge_locality(graph, assignment),
        balance=partition_balance(assignment, num_parts),
    )


def reassign_dead_shard(
    graph: CSRGraph,
    assignment: np.ndarray,
    dead: int,
    *,
    num_parts: Optional[int] = None,
    gamma: float = 2.0,
    use_ps2: bool = True,
    tau_weight: str = "degree",
) -> np.ndarray:
    """Elastic reconfiguration (DESIGN.md §12): stream the orphans of a
    permanently-lost shard into the SURVIVORS via the same Eq. 14/15
    objective as the original partition.

    The survivors' existing placements are kept fixed — only the orphans
    re-stream, so PS1/PS2 see the full survivor context and the rebuilt
    partition reuses the survivor slices untouched. Orphans stream in
    descending-degree order (the high-degree nodes anchor the proximity
    scores for the rest, mirroring the '+degree' stream orders). Eq. 15's
    capacity counts are primed from the survivors' CURRENT load so the
    orphan mass spreads instead of piling onto one survivor;
    ``tau_weight="degree"`` (the walker-occupancy default, see
    ``_assign_stream``) charges degree mass. Returns a NEW assignment over
    the ORIGINAL partition ids with no node left on ``dead`` — compact the
    id space afterwards with ``compact_assignment``.
    """
    asn = np.asarray(assignment, dtype=np.int32)
    if num_parts is None:      # a shard may own zero nodes; callers that
        num_parts = int(asn.max()) + 1   # know k should pass it explicitly
    if not (0 <= dead < num_parts):
        raise ValueError(f"dead shard {dead} out of range for {num_parts}")
    if num_parts <= 1:
        raise ValueError("cannot reassign the only shard")
    g = graph.to_numpy()
    deg = (g.indptr[1:] - g.indptr[:-1]).astype(np.int64)

    new_asn = asn.copy()
    orphans = np.flatnonzero(new_asn == dead)
    new_asn[orphans] = -1
    order = orphans[np.argsort(-deg[orphans], kind="stable")]

    counts = np.zeros(num_parts, dtype=np.int64)
    placed = np.flatnonzero(new_asn >= 0)
    load = (deg[placed] + 1) if tau_weight == "degree" else \
        np.ones(placed.size, dtype=np.int64)
    np.add.at(counts, new_asn[placed], load)

    allowed = np.ones(num_parts, dtype=bool)
    allowed[dead] = False
    _assign_stream(g, order, new_asn, counts, num_parts, gamma, use_ps2,
                   tau_weight, allowed=allowed)
    assert not np.any(new_asn == dead) and not np.any(new_asn < 0)
    return new_asn


def compact_assignment(
    assignment: np.ndarray, dead: int, *, num_parts: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Compact the partition id space after ``reassign_dead_shard``: ids
    above ``dead`` shift down by one so the k-1 survivors are dense in
    [0, k-1). Returns ``(compacted, old_of_new)`` where ``old_of_new[i]``
    is survivor i's ORIGINAL id (the slice-reuse map for the partial
    PartitionedCSR rebuild)."""
    asn = np.asarray(assignment, dtype=np.int32)
    if np.any(asn == dead):
        raise ValueError(f"assignment still references dead shard {dead}")
    if num_parts is None:
        num_parts = max(int(asn.max()) + 1 if asn.size else 0, dead + 1)
    compacted = np.where(asn > dead, asn - 1, asn).astype(np.int32)
    old_of_new = np.array([p for p in range(num_parts) if p != dead],
                          dtype=np.int32)
    return compacted, old_of_new


def rejoin_shard(
    graph: CSRGraph,
    assignment: np.ndarray,
    *,
    num_parts: Optional[int] = None,
    gamma: float = 2.0,
    tau_weight: str = "degree",
) -> Tuple[np.ndarray, np.ndarray]:
    """Elastic re-JOIN (the inverse of ``reassign_dead_shard``): grow a
    k-way assignment to k+1 when a lost machine returns. The re-opened
    shard gets id ``num_parts`` (appended — survivor ids never move, so
    dispatch-keyed host state stays valid; ``compact_assignment`` is the
    death-direction counterpart of this id layout).

    Donor selection keeps the new shard LOCAL instead of a random
    skim: BFS out of the most-loaded survivor's highest-degree hub,
    donating nodes whose current shard still has surplus over the k+1-way
    degree-mass target, until the returned shard reaches target mass (the
    total surplus equals exactly one target share, so a connected graph
    fills it). The donors then re-enter ``_assign_stream`` restricted to
    the returned shard — the same Eq. 15 capacity bookkeeping as the
    death direction, with the ``allowed`` mask inverted (orphans → the
    survivors there, donors → the returned shard here; PS2 is skipped
    because a single allowed partition makes the proximity argmax
    degenerate).

    Returns ``(new_assignment, moved_mask)`` over k+1 ids.
    """
    from collections import deque

    asn = np.asarray(assignment, dtype=np.int32)
    if num_parts is None:
        num_parts = int(asn.max()) + 1
    if np.any(asn < 0) or np.any(asn >= num_parts):
        raise ValueError("assignment must be dense in [0, num_parts)")
    k_new = num_parts + 1
    g = graph.to_numpy()
    deg = (g.indptr[1:] - g.indptr[:-1]).astype(np.int64)
    load_of = (deg + 1) if tau_weight == "degree" else \
        np.ones(asn.size, dtype=np.int64)

    counts = np.zeros(k_new, dtype=np.int64)
    np.add.at(counts, asn, load_of)
    target = counts.sum() / k_new
    surplus = counts[:num_parts].astype(np.float64) - target

    heavy = int(np.argmax(counts[:num_parts]))
    members = np.flatnonzero(asn == heavy)
    seed = int(members[np.argmax(deg[members])])

    donors = []
    donated = 0.0
    visited = np.zeros(asn.size, dtype=bool)
    visited[seed] = True
    frontier = deque([seed])
    while frontier and donated < target:
        v = frontier.popleft()
        if surplus[asn[v]] > 0:
            donors.append(v)
            donated += float(load_of[v])
            surplus[asn[v]] -= float(load_of[v])
        for u in g.indices[g.indptr[v]:g.indptr[v + 1]]:
            if not visited[u]:
                visited[u] = True
                frontier.append(u)
    if not donors:
        donors = [seed]       # degenerate balance: never re-open empty

    new_asn = asn.copy()
    donor_ids = np.asarray(donors, dtype=np.int64)
    new_asn[donor_ids] = -1
    counts2 = np.zeros(k_new, dtype=np.int64)
    placed = np.flatnonzero(new_asn >= 0)
    np.add.at(counts2, new_asn[placed], load_of[placed])
    order = donor_ids[np.argsort(-deg[donor_ids], kind="stable")]
    allowed = np.zeros(k_new, dtype=bool)
    allowed[num_parts] = True
    _assign_stream(g, order, new_asn, counts2, k_new, gamma,
                   use_ps2=False, tau_weight=tau_weight, allowed=allowed)
    assert not np.any(new_asn < 0)
    assert np.any(new_asn == num_parts)
    return new_asn, new_asn != asn


def mpgp_partition_parallel(
    graph: CSRGraph,
    num_parts: int,
    *,
    gamma: float = 2.0,
    order: str = "bfs+degree",
    num_segments: int = 4,
    use_ps2: bool = True,
    seed: int = 0,
    tau_weight: str = "nodes",
) -> PartitionResult:
    """Parallel MPGP (paper optimization 4): the stream is cut into
    ``num_segments`` segments, each partitioned independently (as if alone),
    then the per-segment results are merged. The paper recommends
    BFS+degree here. (On this 1-core container segments run sequentially;
    the algorithm — independent state per segment — is the parallel one.)"""
    t0 = time.perf_counter()
    g = graph.to_numpy()
    n = g.num_nodes
    nodes = stream_order(graph, order, seed)
    bounds = np.linspace(0, n, num_segments + 1).astype(np.int64)
    assignment = np.full(n, -1, dtype=np.int32)
    seg_results = []
    for s in range(num_segments):
        seg_nodes = nodes[bounds[s]:bounds[s + 1]]
        seg_assign = np.full(n, -1, dtype=np.int32)
        seg_counts = np.zeros(num_parts, dtype=np.int64)
        _assign_stream(g, seg_nodes, seg_assign, seg_counts,
                       num_parts, gamma, use_ps2, tau_weight)
        seg_results.append((seg_nodes, seg_assign))
    # Merge: later segments overwrite nothing (disjoint node sets).
    for seg_nodes, seg_assign in seg_results:
        assignment[seg_nodes] = seg_assign[seg_nodes]
    dt = time.perf_counter() - t0
    return PartitionResult(
        assignment=assignment,
        num_parts=num_parts,
        gamma=gamma,
        order=f"parallel:{order}x{num_segments}",
        seconds=dt,
        locality=edge_locality(graph, assignment),
        balance=partition_balance(assignment, num_parts),
    )


def balanced_only_partition(
    graph: CSRGraph, num_parts: int, *, seed: int = 0
) -> PartitionResult:
    """KnightKing-style workload-balancing-only partition (§2.2): distribute
    nodes so the per-partition edge counts balance, ignoring locality.
    Implemented as a greedy bin-pack of nodes (heaviest-degree first) onto
    the least-loaded partition — the baseline MPGP beats in Fig. 10(c,d)."""
    t0 = time.perf_counter()
    deg = np.asarray(graph.degrees(), dtype=np.int64)
    n = graph.num_nodes
    order_idx = np.argsort(-deg, kind="stable")
    assignment = np.empty(n, dtype=np.int32)
    load = np.zeros(num_parts, dtype=np.int64)
    for v in order_idx:
        p = int(np.argmin(load))
        assignment[v] = p
        load[p] += deg[v] + 1
    dt = time.perf_counter() - t0
    return PartitionResult(
        assignment=assignment,
        num_parts=num_parts,
        gamma=1.0,
        order="balanced-only",
        seconds=dt,
        locality=edge_locality(graph, assignment),
        balance=partition_balance(assignment, num_parts),
    )


def hash_partition(graph: CSRGraph, num_parts: int) -> PartitionResult:
    """Trivial modulo partition — the weakest baseline."""
    t0 = time.perf_counter()
    n = graph.num_nodes
    assignment = (np.arange(n) % num_parts).astype(np.int32)
    dt = time.perf_counter() - t0
    return PartitionResult(
        assignment=assignment,
        num_parts=num_parts,
        gamma=1.0,
        order="hash",
        seconds=dt,
        locality=edge_locality(graph, assignment),
        balance=partition_balance(assignment, num_parts),
    )
