"""HuGE-D — the paper's distributed baseline (§2.3).

Same information-oriented walk as DistGER, but with the *full-path
computation mechanism*: H and R are recomputed from the whole path at every
step (O(L)/step => O(L^2) per walk) and cross-machine messages carry the
path (24 + 8L bytes). On our engine this is just the ``fullpath`` info mode;
this module pins the configuration so benchmarks and tests reference one
canonical baseline object.
"""

from __future__ import annotations

from repro.core.corpus import Corpus, generate_corpus
from repro.core.walker import WalkSpec


def huge_d_spec(
    max_len: int = 100, min_len: int = 20, mu: float = 0.995, reg_start: int = 16
) -> WalkSpec:
    return WalkSpec(max_len=max_len, min_len=min_len, mu=mu,
                    info_mode="fullpath", reg_start=reg_start)


def distger_spec(
    max_len: int = 100, min_len: int = 20, mu: float = 0.995, reg_start: int = 16
) -> WalkSpec:
    """Production spec: suffix regression from L0=16 reproduces HuGE's
    reported adaptive walk lengths (~63% shorter than the routine L=80);
    reg_start=1 recovers the paper-literal full series (DESIGN.md §8)."""
    return WalkSpec(max_len=max_len, min_len=min_len, mu=mu,
                    info_mode="incom", reg_start=reg_start)


def incremental_spec(
    max_len: int = 100, min_len: int = 20, mu: float = 0.995,
    reg_start: int = 16
) -> WalkSpec:
    """``distger_spec`` with VERTEX-KEYED walk RNG — the spec a
    refresh-capable deployment runs from day one. Walks become a pure
    function of (key, round, source vertex), so after edge churn the
    incremental driver (``repro.core.incremental``) can re-walk just the
    affected vertices and splice results that are bit-identical to a
    from-scratch round on the mutated graph; the ΔD gate then continues
    seeded from the prior rounds' D_r history instead of cold-starting.
    """
    return WalkSpec(max_len=max_len, min_len=min_len, mu=mu,
                    info_mode="incom", reg_start=reg_start,
                    rng_mode="vertex")


def routine_spec(fixed_len: int = 80) -> WalkSpec:
    """KnightKing-style routine configuration (L=80, r=10)."""
    return WalkSpec(max_len=fixed_len, info_mode="fixed", fixed_len=fixed_len)


def generate_corpus_huge_d(graph, **kwargs) -> Corpus:
    kwargs.setdefault("spec", huge_d_spec())
    return generate_corpus(graph, **kwargs)
