"""Corpus generation and frequency-ordered relabeling (paper §4.2-I).

``generate_corpus`` drives the walker engine round-by-round: each round runs
one information-oriented walk from every source node, then the Eq. 7
controller decides whether another round is needed. The result is a padded
(num_walks, max_len) array of node ids plus per-walk lengths and the node
occurrence counts ``ocn`` (needed by both Eq. 6 and the hotness machinery).

``FrequencyOrder`` relabels nodes in descending corpus frequency so the
embedding matrices can be laid out hot-rows-first (Improvement-I): row 0 of
the global matrices is the hottest node. This both keeps hot vectors in
fast memory and makes hotness-*block* boundaries contiguous index ranges.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.termination import WalkCountController
from repro.core.transition import Policy, make_policy
from repro.core.walker import WalkSpec, batch_stats, run_walk_batch, walks_to_numpy
from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class Corpus:
    walks: np.ndarray        # (num_walks, max_len) int32, -1 padded
    lengths: np.ndarray      # (num_walks,) int64
    ocn: np.ndarray          # (|V|,) int64 — occurrences per node
    rounds: int
    stats: Dict[str, float]

    @property
    def num_walks(self) -> int:
        return int(self.walks.shape[0])

    @property
    def total_tokens(self) -> int:
        return int(self.lengths.sum())

    def token_count(self) -> np.ndarray:
        return self.ocn


def count_occurrences(
    walks: np.ndarray, lengths: np.ndarray, num_nodes: int
) -> np.ndarray:
    mask = np.arange(walks.shape[1])[None, :] < lengths[:, None]
    flat = walks[mask]
    return np.bincount(flat, minlength=num_nodes).astype(np.int64)


def generate_corpus(
    graph: CSRGraph,
    *,
    policy: Policy | str = "huge",
    spec: Optional[WalkSpec] = None,
    delta: float = 1e-3,
    min_rounds: int = 2,
    max_rounds: int = 20,
    walker_batch: int = 4096,
    seed: int = 0,
    part: Optional[np.ndarray] = None,
    sources: Optional[np.ndarray] = None,
) -> Corpus:
    """End-to-end sampler: rounds of walks until Delta D_r <= delta."""
    if isinstance(policy, str):
        policy = make_policy(policy)
    spec = spec or WalkSpec()
    # The HuGE transition probability needs per-edge common-neighbor counts
    # regardless of the termination mode (fixed or info-centric).
    if getattr(policy, "needs_edge_cm", False) and graph.edge_cm is None:
        graph = graph.with_edge_cm()
    n = graph.num_nodes
    if sources is None:
        sources = np.arange(n, dtype=np.int32)
    degrees = np.asarray(graph.degrees(), dtype=np.int64)
    part_dev = None if part is None else jnp.asarray(part, jnp.int32)

    controller = WalkCountController(
        delta=delta, min_rounds=min_rounds, max_rounds=max_rounds
    )
    key = jax.random.PRNGKey(seed)
    all_walks: List[np.ndarray] = []
    all_lengths: List[np.ndarray] = []
    ocn = np.zeros(n, dtype=np.int64)
    agg = {"supersteps": 0, "accepts": 0, "rejects": 0,
           "msg_count": 0, "msg_bytes": 0.0}

    keep_walking = True
    while keep_walking:
        key, round_key = jax.random.split(key)
        for start in range(0, len(sources), walker_batch):
            chunk = sources[start : start + walker_batch]
            round_key, k = jax.random.split(round_key)
            st = run_walk_batch(
                graph, jnp.asarray(chunk, jnp.int32), k, policy, spec, part_dev
            )
            walks, lengths = walks_to_numpy(st)
            all_walks.append(walks)
            all_lengths.append(lengths)
            ocn += count_occurrences(walks, lengths, n)
            s = batch_stats(st)
            for field in ("supersteps", "accepts", "rejects", "msg_count"):
                agg[field] += s[field]
            agg["msg_bytes"] += s["msg_bytes"]
        keep_walking = controller.update(degrees, ocn)

    walks = np.concatenate(all_walks, axis=0)
    lengths = np.concatenate(all_lengths, axis=0)
    agg["mean_len"] = float(lengths.mean()) if len(lengths) else 0.0
    agg["d_history"] = list(controller.history)
    return Corpus(
        walks=walks, lengths=lengths, ocn=ocn,
        rounds=controller.rounds, stats=agg,
    )


@dataclasses.dataclass(frozen=True)
class FrequencyOrder:
    """Bijection node id <-> frequency rank (rank 0 = hottest).

    to_rank[v] = rank of node v; to_node[r] = node at rank r.
    """

    to_rank: np.ndarray
    to_node: np.ndarray
    sorted_ocn: np.ndarray   # occurrences in rank order (non-increasing)

    @classmethod
    def from_ocn(cls, ocn: np.ndarray) -> "FrequencyOrder":
        ocn = np.asarray(ocn, dtype=np.int64)
        to_node = np.argsort(-ocn, kind="stable").astype(np.int32)
        to_rank = np.empty_like(to_node)
        to_rank[to_node] = np.arange(len(to_node), dtype=np.int32)
        return cls(to_rank=to_rank, to_node=to_node, sorted_ocn=ocn[to_node])

    def relabel_walks(self, walks: np.ndarray) -> np.ndarray:
        """Map a -1-padded walk array into rank space."""
        out = np.where(walks >= 0, self.to_rank[np.maximum(walks, 0)], -1)
        return out.astype(np.int32)

    def hotness_blocks(self) -> Tuple[np.ndarray, np.ndarray]:
        """Block boundaries grouping equal-frequency ranks (paper §4.2-III:
        blocks B(i) share the same corpus frequency). Returns (starts, ends)
        index ranges in rank space, hottest block first."""
        occ = self.sorted_ocn
        if len(occ) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        change = np.nonzero(np.diff(occ))[0] + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [len(occ)]])
        return starts.astype(np.int64), ends.astype(np.int64)
