"""Corpus generation: device-resident ring + frequency relabeling (§4.2-I).

The sampler's native output is a ``CorpusRing`` — a device-resident buffer
that finished walk batches are appended into without ever leaving the
accelerator: paths land in ring slots via one scatter, per-node occurrence
counts (``ocn``, needed by Eq. 6/7 and the hotness machinery) accumulate by
a fused scatter-add. The streaming trainer
(``repro.runtime.trainer.StreamingEmbedPipeline``) consumes ring slots as
stacked shard chunks directly, so walk→train never round-trips through host
numpy; round r+1's append region is disjoint from round r's read region,
which is what makes the walk/train double-buffering safe.

``generate_corpus`` remains the compatibility shim: it drives the same
ring + sharded engine round-by-round (Eq. 7 ΔD controller) and materializes
a host-side ``Corpus`` at the API boundary for callers that want numpy
(tests, benchmarks, ``sample_corpus``).

``FrequencyOrder`` relabels nodes in descending corpus frequency so the
embedding matrices can be laid out hot-rows-first (Improvement-I): row 0 of
the global matrices is the hottest node. This both keeps hot vectors in
fast memory and makes hotness-*block* boundaries contiguous index ranges.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.termination import WalkCountController
from repro.core.transition import Policy, make_policy
from repro.core.walker import WalkSpec, batch_stats, run_walk_batch
from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class Corpus:
    walks: np.ndarray        # (num_walks, max_len) int32, -1 padded
    lengths: np.ndarray      # (num_walks,) int64
    ocn: np.ndarray          # (|V|,) int64 — occurrences per node
    rounds: int
    stats: Dict[str, float]

    @property
    def num_walks(self) -> int:
        return int(self.walks.shape[0])

    @property
    def total_tokens(self) -> int:
        return int(self.lengths.sum())

    def token_count(self) -> np.ndarray:
        return self.ocn


def count_occurrences(
    walks: np.ndarray, lengths: np.ndarray, num_nodes: int
) -> np.ndarray:
    mask = np.arange(walks.shape[1])[None, :] < lengths[:, None]
    flat = walks[mask]
    return np.bincount(flat, minlength=num_nodes).astype(np.int64)


# ---------------------------------------------------------------------------
# Device-resident corpus ring
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CorpusRing:
    """Finished walks, resident on device.

    ``walks[cursor:cursor+b]`` is where the next batch lands (wrapping);
    ``ocn`` tracks per-node occurrences of everything ever appended, and
    ``total`` the number of appended walks (may exceed capacity once the
    ring wraps and old rounds are retired).
    """

    walks: jax.Array      # (capacity, T) int32, -1 padded
    lengths: jax.Array    # (capacity,) int32
    ocn: jax.Array        # (|V|,) int32
    cursor: jax.Array     # () int32 — next write slot
    total: jax.Array      # () int32 — walks ever appended

    def tree_flatten(self):
        return (self.walks, self.lengths, self.ocn, self.cursor,
                self.total), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, capacity: int, max_len: int, num_nodes: int) -> "CorpusRing":
        # ocn is int32 (JAX default without x64): total occurrences are
        # bounded by capacity * max_len (one count per token slot), so
        # refuse configurations that could silently wrap a hot node's count.
        if capacity * max_len >= 2**31:
            raise ValueError(
                f"CorpusRing capacity {capacity} x max_len {max_len} can "
                "overflow int32 occurrence counts; shard the corpus or "
                "enable jax_enable_x64 and widen ocn")
        return cls(
            walks=jnp.full((capacity, max_len), -1, jnp.int32),
            lengths=jnp.zeros((capacity,), jnp.int32),
            ocn=jnp.zeros((num_nodes,), jnp.int32),
            cursor=jnp.zeros((), jnp.int32),
            total=jnp.zeros((), jnp.int32),
        )

    @property
    def capacity(self) -> int:
        return int(self.walks.shape[0])

    @property
    def num_filled(self) -> int:
        return int(min(int(self.total), self.capacity))


def _ring_append(ring: CorpusRing, paths: jax.Array,
                 lengths: jax.Array) -> CorpusRing:
    b = paths.shape[0]
    cap = ring.walks.shape[0]
    slots = jnp.mod(ring.cursor + jnp.arange(b, dtype=jnp.int32), cap)
    valid = paths >= 0
    ocn = ring.ocn.at[jnp.maximum(paths, 0).reshape(-1)].add(
        valid.reshape(-1).astype(jnp.int32))
    return CorpusRing(
        walks=ring.walks.at[slots].set(paths.astype(jnp.int32)),
        lengths=ring.lengths.at[slots].set(lengths.astype(jnp.int32)),
        ocn=ocn,
        cursor=jnp.mod(ring.cursor + b, cap),
        total=ring.total + b,
    )


def _ring_replace(ring: CorpusRing, slots: jax.Array, paths: jax.Array,
                  lengths: jax.Array) -> CorpusRing:
    """Overwrite specific ring slots in place (the incremental-refresh
    write path: a re-walked vertex's new walk replaces its stale walk at
    the SAME round-aligned slot, so every untouched slot — and therefore
    every walk rooted at an unaffected vertex — stays bit-identical).

    ``ocn`` is kept exact: the replaced slots' tokens are subtracted
    before the new walks' tokens are added, so Eq. 6/7's occurrence
    distribution reflects the refreshed corpus, not the union of stale
    and fresh walks. ``cursor``/``total`` do not move — replacement is
    not an append.
    """
    slots = slots.astype(jnp.int32)
    old = ring.walks[slots]
    ocn = ring.ocn.at[jnp.maximum(old, 0).reshape(-1)].add(
        -(old >= 0).reshape(-1).astype(jnp.int32))
    valid = paths >= 0
    ocn = ocn.at[jnp.maximum(paths, 0).reshape(-1)].add(
        valid.reshape(-1).astype(jnp.int32))
    return CorpusRing(
        walks=ring.walks.at[slots].set(paths.astype(jnp.int32)),
        lengths=ring.lengths.at[slots].set(lengths.astype(jnp.int32)),
        ocn=ocn,
        cursor=ring.cursor,
        total=ring.total,
    )


# Two jit wrappers over one implementation. Production callers (the
# streaming pipeline and generate_corpus) drop their old ring reference at
# the call site and use the donated form: XLA aliases the buffers when no
# queued consumer (e.g. a trainer gather over earlier rounds) still holds
# them and falls back to a defensive copy when one does, so donation is
# always value-safe and skips the O(capacity) copy in the steady state.
# The functional form is for callers that intentionally keep the
# pre-append version alive (tests, ad-hoc snapshots).
ring_append = jax.jit(_ring_append)
ring_append_donated = jax.jit(_ring_append, donate_argnums=(0,))
ring_replace = jax.jit(_ring_replace)
ring_replace_donated = jax.jit(_ring_replace, donate_argnums=(0,))


def ring_export(ring: CorpusRing) -> Dict[str, np.ndarray]:
    """Full ring state as host arrays — the snapshot surface. Unlike
    ``ring_to_numpy`` (which rotates and drops the write cursor for numpy
    consumers), this is a lossless dump: importing it reproduces the ring
    bit-for-bit including cursor/total, so slot-indexed host maps
    (slot→root, slot→round) stay aligned across a save/restore cycle."""
    return {
        "walks": np.asarray(ring.walks),
        "lengths": np.asarray(ring.lengths),
        "ocn": np.asarray(ring.ocn),
        "cursor": np.asarray(ring.cursor),
        "total": np.asarray(ring.total),
    }


def ring_import(state: Dict[str, np.ndarray]) -> CorpusRing:
    """Rebuild a device ring from ``ring_export`` output."""
    return CorpusRing(
        walks=jnp.asarray(state["walks"], jnp.int32),
        lengths=jnp.asarray(state["lengths"], jnp.int32),
        ocn=jnp.asarray(state["ocn"], jnp.int32),
        cursor=jnp.asarray(state["cursor"], jnp.int32),
        total=jnp.asarray(state["total"], jnp.int32),
    )


def ring_to_numpy(ring: CorpusRing) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize the filled slots (oldest -> newest) on host — the API
    boundary for numpy consumers; the hot path never calls this."""
    n = ring.num_filled
    walks = np.asarray(ring.walks)
    lengths = np.asarray(ring.lengths)
    if int(ring.total) > ring.capacity:               # wrapped: rotate
        c = int(ring.cursor)
        order = np.concatenate([np.arange(c, ring.capacity), np.arange(c)])
        walks, lengths = walks[order], lengths[order]
    return walks[:n], lengths[:n].astype(np.int64)


# ---------------------------------------------------------------------------
# Round-driven sampler (compatibility shim over ring + sharded engine)
# ---------------------------------------------------------------------------


def generate_corpus(
    graph: CSRGraph,
    *,
    policy: Policy | str = "huge",
    spec: Optional[WalkSpec] = None,
    delta: float = 1e-3,
    min_rounds: int = 2,
    max_rounds: int = 20,
    window: int = 1,
    walker_batch: int = 4096,
    seed: int = 0,
    part: Optional[np.ndarray] = None,
    sources: Optional[np.ndarray] = None,
) -> Corpus:
    """End-to-end sampler: rounds of walks until Delta D_r <= delta.

    Thin shim over the sharded engine + device ring: walks accumulate on
    device; the host sees only the (|V|,) ``ocn`` per round (controller
    input) and one final materialization into the numpy ``Corpus``.
    """
    if isinstance(policy, str):
        policy = make_policy(policy)
    spec = spec or WalkSpec()
    # The HuGE transition probability needs per-edge common-neighbor counts
    # regardless of the termination mode (fixed or info-centric).
    if getattr(policy, "needs_edge_cm", False) and graph.edge_cm is None:
        graph = graph.with_edge_cm()
    n = graph.num_nodes
    if sources is None:
        sources = np.arange(n, dtype=np.int32)
    degrees = np.asarray(graph.degrees(), dtype=np.int64)
    part_dev = None if part is None else jnp.asarray(part, jnp.int32)
    num_shards = 1 if part is None else int(np.max(np.asarray(part))) + 1

    controller = WalkCountController(
        delta=delta, min_rounds=min_rounds, max_rounds=max_rounds,
        window=window,
    )
    key = jax.random.PRNGKey(seed)
    # This shim materializes EVERY walk for its numpy Corpus, so the ring
    # must retain all rounds; when that exceeds the device-side int32/
    # memory budget, spill each round to host instead (the pre-ring
    # behavior — acceptable here because the output is host numpy anyway).
    capacity = max_rounds * len(sources)
    on_device = capacity * spec.max_len < 2**31
    if on_device:
        ring = CorpusRing.create(capacity, spec.max_len, n)
    else:
        host_walks, host_lengths = [], []
        ocn_host = np.zeros(n, dtype=np.int64)
    agg = {"supersteps": 0, "accepts": 0, "rejects": 0,
           "msg_count": 0, "msg_bytes": 0.0, "msg_bytes_analytic": 0.0}

    keep_walking = True
    while keep_walking:
        key, round_key = jax.random.split(key)
        for start in range(0, len(sources), walker_batch):
            chunk = sources[start : start + walker_batch]
            if spec.rng_mode == "vertex":
                k = round_key        # vertex ids disambiguate the lanes;
                # a shared round key keeps walks chunk-layout-invariant
            else:
                round_key, k = jax.random.split(round_key)
            st = run_walk_batch(
                graph, jnp.asarray(chunk, jnp.int32), k, policy, spec,
                part_dev, num_shards=num_shards if part is not None else None,
            )
            if on_device:
                ring = ring_append_donated(ring, st.path,
                                           st.info.L.astype(jnp.int32))
            else:
                w = np.asarray(st.path)
                l = np.asarray(st.info.L, dtype=np.int64)
                host_walks.append(w)
                host_lengths.append(l)
                ocn_host += count_occurrences(w, l, n)
            s = batch_stats(st)
            for field in ("supersteps", "accepts", "rejects", "msg_count"):
                agg[field] += s[field]
            agg["msg_bytes"] += s["msg_bytes"]
            agg["msg_bytes_analytic"] += s["msg_bytes_analytic"]
        ocn_now = np.asarray(ring.ocn) if on_device else ocn_host
        keep_walking = controller.update(degrees, ocn_now)

    if on_device:
        walks, lengths = ring_to_numpy(ring)
        ocn_out = np.asarray(ring.ocn, dtype=np.int64)
    else:
        walks = np.concatenate(host_walks, axis=0)
        lengths = np.concatenate(host_lengths, axis=0)
        ocn_out = ocn_host
    agg["mean_len"] = float(lengths.mean()) if len(lengths) else 0.0
    agg["d_history"] = list(controller.history)
    return Corpus(
        walks=walks, lengths=lengths, ocn=ocn_out,
        rounds=controller.rounds, stats=agg,
    )


@dataclasses.dataclass(frozen=True)
class FrequencyOrder:
    """Bijection node id <-> frequency rank (rank 0 = hottest).

    to_rank[v] = rank of node v; to_node[r] = node at rank r.
    """

    to_rank: np.ndarray
    to_node: np.ndarray
    sorted_ocn: np.ndarray   # occurrences in rank order (non-increasing)

    @classmethod
    def from_ocn(cls, ocn: np.ndarray) -> "FrequencyOrder":
        ocn = np.asarray(ocn, dtype=np.int64)
        to_node = np.argsort(-ocn, kind="stable").astype(np.int32)
        to_rank = np.empty_like(to_node)
        to_rank[to_node] = np.arange(len(to_node), dtype=np.int32)
        return cls(to_rank=to_rank, to_node=to_node, sorted_ocn=ocn[to_node])

    def relabel_walks(self, walks: np.ndarray) -> np.ndarray:
        """Map a -1-padded walk array into rank space."""
        out = np.where(walks >= 0, self.to_rank[np.maximum(walks, 0)], -1)
        return out.astype(np.int32)

    def hotness_blocks(self) -> Tuple[np.ndarray, np.ndarray]:
        """Block boundaries grouping equal-frequency ranks (paper §4.2-III:
        blocks B(i) share the same corpus frequency). Returns (starts, ends)
        index ranges in rank space, hottest block first."""
        occ = self.sorted_ocn
        if len(occ) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        change = np.nonzero(np.diff(occ))[0] + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [len(occ)]])
        return starts.astype(np.int64), ends.astype(np.int64)
