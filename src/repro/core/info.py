"""Full-path information measurements (paper Eq. 4–7) — the reference oracle.

Everything here recomputes from the complete walk path. It is the ground
truth that ``repro.core.incom`` (Theorem 1 incremental computing) must match
exactly, and it is also what the HuGE-D baseline executes at every step
(O(L) per step — the cost InCoM removes).

Logs are base 2 throughout (Theorem 1's proof manipulates 2^{-H·L}).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def walk_entropy(path: Sequence[int]) -> float:
    """H(W^L) = -sum_v n(v)/L log2 n(v)/L   (Eq. 4)."""
    path = np.asarray(path)
    if path.size == 0:
        return 0.0
    _, counts = np.unique(path, return_counts=True)
    p = counts / path.size
    return float(-np.sum(p * np.log2(p)))


def walk_entropy_series(path: Sequence[int]) -> np.ndarray:
    """H(W^1), H(W^2), ..., H(W^L) — entropy of every prefix."""
    path = np.asarray(path)
    return np.asarray([walk_entropy(path[: i + 1]) for i in range(path.size)])


def pearson_r(h_series: Sequence[float], l_series: Sequence[float]) -> float:
    """R(H, L) per Eq. 5 / Eq. 12 (plain Pearson correlation).

    Degenerate series (zero variance in either coordinate) return 0.0 — a
    flat entropy series means the walk has converged, and R -> 0 is exactly
    the paper's termination direction.
    """
    h = np.asarray(h_series, dtype=np.float64)
    l = np.asarray(l_series, dtype=np.float64)
    if h.size < 2:
        return 1.0  # too short to judge: keep walking
    eh, el = h.mean(), l.mean()
    cov = np.mean(h * l) - eh * el
    vh = np.mean(h * h) - eh * eh
    vl = np.mean(l * l) - el * el
    denom = np.sqrt(max(vh, 0.0) * max(vl, 0.0))
    if denom <= 1e-30:
        return 0.0
    return float(cov / denom)


def r_squared_of_path(path: Sequence[int]) -> float:
    """R^2(H, L) computed from scratch over a full path."""
    path = np.asarray(path)
    h = walk_entropy_series(path)
    l = np.arange(1, path.size + 1, dtype=np.float64)
    r = pearson_r(h, l)
    return float(r * r)


def huge_walk_should_stop(path: Sequence[int], mu: float, min_len: int) -> bool:
    """HuGE termination: R^2(H, L) < mu once the walk has min_len nodes."""
    if len(path) < min_len:
        return False
    return r_squared_of_path(path) < mu


def relative_entropy_dpq(degrees: np.ndarray, ocn: np.ndarray) -> float:
    """D(p || q) between degree and corpus-occurrence distributions (Eq. 6).

    Nodes with ocn == 0 are guarded with a small epsilon, mirroring an
    unconverged corpus (they push D up, demanding more walks).
    """
    deg = np.asarray(degrees, dtype=np.float64)
    occ = np.asarray(ocn, dtype=np.float64)
    sum_deg = deg.sum()
    sum_occ = occ.sum()
    if sum_deg == 0 or sum_occ == 0:
        return float("inf")
    p = deg / sum_deg
    q = occ / sum_occ
    mask = p > 0
    eps = 1e-12
    return float(np.sum(p[mask] * np.log2(p[mask] / (q[mask] + eps))))


def reference_huge_walk_length(
    path: Sequence[int], mu: float = 0.995, min_len: int = 5
) -> int:
    """Walk length HuGE would choose on this node sequence — scans prefixes
    until the termination condition fires (pure-python oracle for tests)."""
    path = np.asarray(path)
    for L in range(min_len, path.size + 1):
        if r_squared_of_path(path[:L]) < mu:
            return L
    return int(path.size)


def incremental_mean_update(e_prev: float, x_p: float, p: int) -> float:
    """E_p(X) = ((p-1)/p) E_{p-1}(X) + X_p / p   (Eq. 13, first line)."""
    return ((p - 1) / p) * e_prev + x_p / p


def incremental_cross_update(exy_prev: float, x_p: float, y_p: float, p: int) -> float:
    """E_p(XY) = ((p-1) E_{p-1}(XY) + X_p Y_p) / p.

    NOTE (paper erratum): the paper's printed Eq. 13 second line expands to
    E_p(X)·E_p(Y) rather than the running cross-moment — plugging it into
    Eq. 12 would make the covariance identically ~0 and terminate every walk
    at min_len. We verified numerically (X=Y=[1,2]: true E_2(XY)=2.5, the
    printed formula gives 2.25=E_2(X)E_2(Y)) and implement the correct
    running cross-moment, which makes incremental R match full-path R
    exactly (property-tested in tests/test_incom.py).
    """
    return ((p - 1) * exy_prev + x_p * y_p) / p


def r_from_stats(eh, el, ehl, eh2, el2) -> float:
    """Eq. 12: R from the five running expectations."""
    cov = ehl - eh * el
    vh = eh2 - eh * eh
    vl = el2 - el * el
    denom = np.sqrt(max(vh, 0.0) * max(vl, 0.0))
    if denom <= 1e-30:
        return 0.0
    return float(cov / denom)
