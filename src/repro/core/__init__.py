# The paper's primary contribution: InCoM incremental information-centric
# walks, MPGP streaming partitioning, and DSGL distributed Skip-Gram.
from repro.core import incom, info
from repro.core.api import EmbedConfig, embed_graph, sample_corpus
from repro.core.corpus import (
    Corpus,
    CorpusRing,
    FrequencyOrder,
    generate_corpus,
    ring_append,
)
from repro.core.huge_d import distger_spec, huge_d_spec, routine_spec
from repro.core.shard_engine import make_walk_mesh, run_walk_sharded
from repro.core.termination import WalkCountController
from repro.core.transition import (
    DeepwalkPolicy,
    HugePolicy,
    Node2vecPolicy,
    make_policy,
)
from repro.core.walker import WalkSpec, run_walk_batch

__all__ = [
    "incom",
    "info",
    "EmbedConfig",
    "embed_graph",
    "sample_corpus",
    "Corpus",
    "CorpusRing",
    "FrequencyOrder",
    "generate_corpus",
    "ring_append",
    "make_walk_mesh",
    "run_walk_sharded",
    "distger_spec",
    "huge_d_spec",
    "routine_spec",
    "WalkCountController",
    "DeepwalkPolicy",
    "HugePolicy",
    "Node2vecPolicy",
    "make_policy",
    "WalkSpec",
    "run_walk_batch",
]
