"""Batched information-oriented random-walk engine (paper §3.1, Alg. 1).

TPU-native realization of the walker-centric model: every walker is a lane
of a batched tensor program; one ``lax.while_loop`` iteration is one BSP
superstep. Rejected lanes (walking-backtracking) keep their current node and
redraw next superstep — the identical Markov chain, with no lane divergence.

Three information modes:

* ``incom``    — DistGER: Theorem 1 / Eq. 13 O(1) incremental updates.
* ``fullpath`` — HuGE-D baseline: recompute H from the path and R over the
                 stored H-series at every step (O(L) work/step, O(L) msgs).
* ``fixed``    — KnightKing-style routine walks (L fixed, e.g. 80).

Cross-partition message accounting (counts + bytes) is carried in-loop when
a partition assignment is provided, reproducing Fig. 10(c) / Example 1
measurements exactly (80 B constant vs 24+8L B full-path messages).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incom
from repro.core.transition import Policy, node_degrees
from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class WalkSpec:
    max_len: int = 100          # path buffer capacity (hard cap)
    min_len: int = 8            # don't test termination before this length
    mu: float = 0.995           # Eq. 5 termination threshold (R^2 < mu)
    info_mode: str = "incom"    # "incom" | "fullpath" | "fixed"
    fixed_len: int = 80         # routine walk length (info_mode == "fixed")
    reg_start: int = 1          # L0: start of the regression series. 1 =
                                # paper-literal; 16 reproduces HuGE's
                                # reported adaptive lengths (DESIGN.md §8)
    reg_window: int = 0         # optional ring-buffer variant: R^2 over the
                                # last K points (incom.windowed_r_squared)
    max_supersteps: int = 0     # 0 => 8 * max_len safety cap

    def supersteps_cap(self) -> int:
        return self.max_supersteps or 8 * self.max_len


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WalkerBatchState:
    """Loop carry for one batch of walkers."""

    cur: jax.Array            # (B,) int32 current node
    prev: jax.Array           # (B,) int32 previous node (== cur at start)
    path: jax.Array           # (B, max_len) int32, -1 padded
    info: incom.InfoState     # (B,) scalars
    h_series: jax.Array       # (B, max_len) f32 (fullpath mode only; else 0-size)
    hring: jax.Array          # (B, K) f32 ring of recent H (reg_window mode)
    active: jax.Array         # (B,) bool
    key: jax.Array            # PRNG key
    supersteps: jax.Array     # () int32
    accepts: jax.Array        # () int32
    rejects: jax.Array        # () int32
    msg_count: jax.Array      # () int32   cross-partition hand-offs
    msg_bytes: jax.Array      # () float32 bytes for those hand-offs

    def tree_flatten(self):
        return (
            self.cur, self.prev, self.path, self.info, self.h_series,
            self.hring, self.active, self.key, self.supersteps, self.accepts,
            self.rejects, self.msg_count, self.msg_bytes,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_batch(sources: jax.Array, key: jax.Array, spec: WalkSpec) -> WalkerBatchState:
    b = sources.shape[0]
    path = jnp.full((b, spec.max_len), -1, jnp.int32)
    path = path.at[:, 0].set(sources)
    h_len = spec.max_len if spec.info_mode == "fullpath" else 1
    k = max(spec.reg_window, 1)
    return WalkerBatchState(
        cur=sources.astype(jnp.int32),
        prev=sources.astype(jnp.int32),
        path=path,
        info=incom.InfoState.init(b),
        h_series=jnp.zeros((b, h_len), jnp.float32),
        hring=jnp.zeros((b, k), jnp.float32),
        active=jnp.ones((b,), bool),
        key=key,
        supersteps=jnp.zeros((), jnp.int32),
        accepts=jnp.zeros((), jnp.int32),
        rejects=jnp.zeros((), jnp.int32),
        msg_count=jnp.zeros((), jnp.int32),
        msg_bytes=jnp.zeros((), jnp.float32),
    )


def _fullpath_entropy(path: jax.Array, length: jax.Array) -> jax.Array:
    """H(W^L) recomputed from scratch: O(max_len^2) lane-work per call.

    Uses the positional identity  H = -(1/L) * sum_{i<L} log2(n(path_i)/L)
    (each node v contributes n(v) positions)."""
    b, max_len = path.shape
    pos = jnp.arange(max_len, dtype=jnp.int32)
    mask = pos[None, :] < length[:, None]                       # (B, max_len)
    eq = path[:, :, None] == path[:, None, :]                   # (B, i, j)
    eq = eq & mask[:, None, :] & mask[:, :, None]
    n_i = jnp.sum(eq, axis=-1).astype(jnp.float32)              # (B, max_len)
    lf = jnp.maximum(length.astype(jnp.float32), 1.0)[:, None]
    term = jnp.where(mask, jnp.log2(jnp.maximum(n_i, 1.0) / lf), 0.0)
    return -jnp.sum(term, axis=-1) / lf[:, 0]


def _fullpath_r2(
    h_series: jax.Array, length: jax.Array, window: int = 0, start: int = 1
) -> jax.Array:
    """Pearson R^2 over the stored prefix-entropy series (O(L)/step).
    ``window`` > 0 restricts to the last ``window`` points; ``start`` = L0
    drops points with L < L0 (suffix regression)."""
    b, max_len = h_series.shape
    pos = jnp.arange(max_len, dtype=jnp.float32)
    l_series = pos[None, :] + 1.0
    in_prefix = pos[None, :] < length[:, None]
    if window:
        in_prefix = in_prefix & (pos[None, :] >= length[:, None] - window)
    if start > 1:
        in_prefix = in_prefix & (l_series >= jnp.float32(start))
    mask = in_prefix.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(mask, -1), 1.0)
    eh = jnp.sum(h_series * mask, -1) / cnt
    el = jnp.sum(l_series * mask, -1) / cnt
    ehl = jnp.sum(h_series * l_series * mask, -1) / cnt
    eh2 = jnp.sum(h_series * h_series * mask, -1) / cnt
    el2 = jnp.sum(l_series * l_series * mask, -1) / cnt
    cov = ehl - eh * el
    vh = jnp.maximum(eh2 - eh * eh, 0.0)
    vl = jnp.maximum(el2 - el * el, 0.0)
    denom = vh * vl
    return jnp.where(denom > 1e-12, cov * cov / jnp.maximum(denom, 1e-12), 0.0)


def _superstep(
    graph: CSRGraph,
    policy: Policy,
    spec: WalkSpec,
    part: Optional[jax.Array],
    st: WalkerBatchState,
) -> WalkerBatchState:
    b = st.cur.shape[0]
    key, k_cand, k_acc = jax.random.split(st.key, 3)

    deg = node_degrees(graph, st.cur)                       # (B,) f32
    has_nbrs = deg > 0
    u1 = jax.random.uniform(k_cand, (b,))
    j = jnp.minimum((u1 * deg).astype(jnp.int32),
                    jnp.maximum(deg.astype(jnp.int32) - 1, 0))
    eidx = graph.indptr[st.cur].astype(jnp.int32) + j
    eidx = jnp.clip(eidx, 0, graph.indices.shape[0] - 1)
    cand = graph.indices[eidx]

    p_acc = policy.accept_prob(graph, st.prev, st.cur, cand, eidx)
    u2 = jax.random.uniform(k_acc, (b,))
    accept = st.active & has_nbrs & (u2 < p_acc)
    # Lanes whose node has no neighbors terminate immediately.
    dead_end = st.active & ~has_nbrs

    # --- information update on accepted lanes --------------------------------
    info_acc, path_acc = incom.accept_update(st.info, st.path, cand, spec.reg_start)
    new_info = jax.tree_util.tree_map(
        lambda new, old: jnp.where(accept, new, old), info_acc, st.info
    )
    new_path = jnp.where(accept[:, None], path_acc, st.path)

    l_new = new_info.L  # (B,) f32 — post-accept length

    if spec.info_mode == "fullpath":
        # Recompute H from scratch (O(L^2) lanes) and R over the H-series.
        h_full = _fullpath_entropy(new_path, l_new.astype(jnp.int32))
        idx = jnp.clip(l_new.astype(jnp.int32) - 1, 0, spec.max_len - 1)
        h_series = jnp.where(
            accept[:, None],
            st.h_series.at[jnp.arange(b), idx].set(h_full),
            st.h_series,
        )
        r2 = _fullpath_r2(h_series, l_new.astype(jnp.int32),
                          spec.reg_window, spec.reg_start)
        # Overwrite incremental H with recomputed (identical values) to keep
        # downstream uniform; the *cost* difference is what we benchmark.
        new_info = dataclasses.replace(new_info, H=jnp.where(accept, h_full, new_info.H))
        hring = st.hring
    else:
        h_series = st.h_series
        if spec.reg_window:
            k = st.hring.shape[1]
            slot = jnp.mod(l_new.astype(jnp.int32) - 1, k)
            hring = jnp.where(
                accept[:, None],
                st.hring.at[jnp.arange(b), slot].set(new_info.H),
                st.hring,
            )
            r2 = incom.windowed_r_squared(hring, l_new, spec.reg_window)
        else:
            hring = st.hring
            r2 = incom.r_squared(new_info)

    # --- termination ----------------------------------------------------------
    if spec.info_mode == "fixed":
        done_now = accept & (l_new >= jnp.float32(spec.fixed_len))
    else:
        long_enough = l_new >= jnp.float32(spec.min_len)
        done_now = accept & long_enough & (r2 < jnp.float32(spec.mu))
    done_now = done_now | (accept & (l_new >= jnp.float32(spec.max_len)))
    done_now = done_now | dead_end

    # --- cross-partition message accounting -----------------------------------
    if part is not None:
        crossed = accept & (part[st.cur] != part[cand])
        n_crossed = jnp.sum(crossed).astype(jnp.int32)
        if spec.info_mode == "fullpath":
            per_msg = incom.fullpath_msg_bytes(l_new).astype(jnp.float32)
        else:
            # Constant-size InCoM message; the windowed variant additionally
            # carries the K-entry H ring (still constant w.r.t. L).
            size = incom.MSG_BYTES + 8 * spec.reg_window
            per_msg = jnp.full((b,), float(size), jnp.float32)
        add_bytes = jnp.sum(jnp.where(crossed, per_msg, 0.0))
    else:
        n_crossed = jnp.zeros((), jnp.int32)
        add_bytes = jnp.zeros((), jnp.float32)

    return WalkerBatchState(
        cur=jnp.where(accept, cand, st.cur),
        prev=jnp.where(accept, st.cur, st.prev),
        path=new_path,
        info=new_info,
        h_series=h_series,
        hring=hring,
        active=st.active & ~done_now,
        key=key,
        supersteps=st.supersteps + 1,
        accepts=st.accepts + jnp.sum(accept).astype(jnp.int32),
        rejects=st.rejects
        + jnp.sum(st.active & has_nbrs & ~accept).astype(jnp.int32),
        msg_count=st.msg_count + n_crossed,
        msg_bytes=st.msg_bytes + add_bytes,
    )


@functools.partial(jax.jit, static_argnames=("policy", "spec"))
def run_walk_batch(
    graph: CSRGraph,
    sources: jax.Array,
    key: jax.Array,
    policy: Policy,
    spec: WalkSpec,
    part: Optional[jax.Array] = None,
) -> WalkerBatchState:
    """Run one walk per source until every lane terminates (or cap)."""
    st = init_batch(sources, key, spec)
    cap = spec.supersteps_cap()

    def cond(s: WalkerBatchState):
        return jnp.any(s.active) & (s.supersteps < cap)

    def body(s: WalkerBatchState):
        return _superstep(graph, policy, spec, part, s)

    return jax.lax.while_loop(cond, body, st)


def walks_to_numpy(st: WalkerBatchState) -> Tuple[np.ndarray, np.ndarray]:
    """Extract (paths, lengths) as numpy from a finished batch."""
    paths = np.asarray(st.path)
    lengths = np.asarray(st.info.L, dtype=np.int64)
    return paths, lengths


def batch_stats(st: WalkerBatchState) -> Dict[str, float]:
    return {
        "supersteps": int(st.supersteps),
        "accepts": int(st.accepts),
        "rejects": int(st.rejects),
        "msg_count": int(st.msg_count),
        "msg_bytes": float(st.msg_bytes),
        "mean_len": float(np.mean(np.asarray(st.info.L))),
    }
