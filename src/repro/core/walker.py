"""Batched information-oriented random-walk engine (paper §3.1, Alg. 1).

TPU-native realization of the walker-centric model: every walker is a lane
of a batched tensor program; one ``lax.while_loop`` iteration is one BSP
superstep. Rejected lanes (walking-backtracking) keep their current node and
redraw next superstep — the identical Markov chain, with no lane divergence.

Three information modes:

* ``incom``    — DistGER: Theorem 1 / Eq. 13 O(1) incremental updates.
* ``fullpath`` — HuGE-D baseline: recompute H from the path and R over the
                 stored H-series at every step (O(L) work/step, O(L) msgs).
* ``fixed``    — KnightKing-style routine walks (L fixed, e.g. 80).

The superstep is split into two phase functions shared with the
partition-sharded BSP engine (``repro.core.shard_engine``):

* ``propose``  — phase A, executed where the walker currently resides:
  candidate draw + acceptance test (walking-backtracking).
* ``absorb``   — phase B, executed where the ACCEPTED node lives: n(v)
  count against the locally held path buffer, Theorem 1 / Eq. 13 info
  update, path append, Eq. 5 termination.

RNG is per-lane and stateless: lane w's draws at superstep t depend only on
(root_key, t, w) via ``step_uniforms``, never on batch layout — which is
what makes walks bit-identical whether the batch runs on 1 shard or k
shards (DESIGN.md §9).

When a partition ``part`` is given, ``run_walk_batch`` routes through the
sharded engine so ``msg_count``/``msg_bytes`` are MEASURED from the packed
message tensors actually exchanged between shard programs (80 B constant
InCoM messages vs 24+8L full-path messages, Example 1), not from an in-loop
analytic counter. The analytic value is still carried alongside
(``msg_bytes_analytic``) so benchmarks can assert measured == analytic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incom
from repro.core.transition import Policy, node_degrees
from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class WalkSpec:
    max_len: int = 100          # path buffer capacity (hard cap)
    min_len: int = 8            # don't test termination before this length
    mu: float = 0.995           # Eq. 5 termination threshold (R^2 < mu)
    info_mode: str = "incom"    # "incom" | "fullpath" | "fixed"
    fixed_len: int = 80         # routine walk length (info_mode == "fixed")
    reg_start: int = 1          # L0: start of the regression series. 1 =
                                # paper-literal; 16 reproduces HuGE's
                                # reported adaptive lengths (DESIGN.md §8)
    reg_window: int = 0         # optional ring-buffer variant: R^2 over the
                                # last K points (incom.windowed_r_squared)
    max_supersteps: int = 0     # 0 => 8 * max_len safety cap
    rng_mode: str = "lane"      # "lane": draws keyed by batch position
                                # (the historical stream); "vertex": keyed
                                # by SOURCE VERTEX id, so a walk's draws do
                                # not depend on which lanes ride along —
                                # the property incremental subset re-walks
                                # need (repro.core.incremental)

    def supersteps_cap(self) -> int:
        return self.max_supersteps or 8 * self.max_len

    def min_test_len(self) -> int:
        """First length at which the R^2 termination test may fire.

        The regression series starts at L0 = ``reg_start`` (re-seeded while
        L <= L0, see ``incom.stats_step``), so before L0 + ~3 points exist
        the Pearson R^2 is degenerate (0 from a 1-point series, 1.0 from a
        2-point series) and ``r2 < mu`` would terminate every walk at
        exactly ``min_len`` — fixed-length walks, not adaptive ones (this
        was the seed's link-prediction regression; DESIGN.md §8). The test
        is therefore gated until the series holds >= 4 points.
        """
        if self.info_mode == "fixed":
            return self.min_len
        if self.reg_window:
            return max(self.min_len, 4)
        return max(self.min_len, self.reg_start + 3)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WalkerBatchState:
    """Loop carry for one batch of walkers."""

    cur: jax.Array            # (B,) int32 current node
    prev: jax.Array           # (B,) int32 previous node (== cur at start)
    path: jax.Array           # (B, max_len) int32, -1 padded
    info: incom.InfoState     # (B,) scalars
    h_series: jax.Array       # (B, max_len) f32 (fullpath mode only; else 0-size)
    hring: jax.Array          # (B, K) f32 ring of recent H (reg_window mode)
    active: jax.Array         # (B,) bool
    key: jax.Array            # ROOT PRNG key (constant; per-lane keys are
                              # derived from (key, supersteps, lane))
    supersteps: jax.Array     # () int32
    accepts: jax.Array        # () int32
    rejects: jax.Array        # () int32
    msg_count: jax.Array      # () int32   cross-partition hand-offs
    msg_bytes: jax.Array      # () float32 measured bytes for those hand-offs
    msg_bytes_analytic: jax.Array  # () float32 Example-1 analytic bytes

    def tree_flatten(self):
        return (
            self.cur, self.prev, self.path, self.info, self.h_series,
            self.hring, self.active, self.key, self.supersteps, self.accepts,
            self.rejects, self.msg_count, self.msg_bytes,
            self.msg_bytes_analytic,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_batch(sources: jax.Array, key: jax.Array, spec: WalkSpec) -> WalkerBatchState:
    b = sources.shape[0]
    path = jnp.full((b, spec.max_len), -1, jnp.int32)
    path = path.at[:, 0].set(sources)
    h_len = spec.max_len if spec.info_mode == "fullpath" else 1
    k = max(spec.reg_window, 1)
    return WalkerBatchState(
        cur=sources.astype(jnp.int32),
        prev=sources.astype(jnp.int32),
        path=path,
        info=incom.InfoState.init(b),
        h_series=jnp.zeros((b, h_len), jnp.float32),
        hring=jnp.zeros((b, k), jnp.float32),
        active=jnp.ones((b,), bool),
        key=key,
        supersteps=jnp.zeros((), jnp.int32),
        accepts=jnp.zeros((), jnp.int32),
        rejects=jnp.zeros((), jnp.int32),
        msg_count=jnp.zeros((), jnp.int32),
        msg_bytes=jnp.zeros((), jnp.float32),
        msg_bytes_analytic=jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Per-lane RNG
# ---------------------------------------------------------------------------


def step_uniforms(root_key: jax.Array, superstep: jax.Array,
                  b: int) -> Tuple[jax.Array, jax.Array]:
    """(u_cand, u_accept), each (B,): lane i's draws are a pure function of
    (root, superstep, i) — the counter-based generator indexes elements by
    position, so every shard evaluating the full-width batch materializes
    identical values for lane i. Layout-independence (and therefore
    shard-count invariance) costs one fold_in + one split per superstep."""
    step_key = jax.random.fold_in(root_key, superstep)
    k1, k2 = jax.random.split(step_key)
    return jax.random.uniform(k1, (b,)), jax.random.uniform(k2, (b,))


def make_uniform_fn(spec: WalkSpec, sources: jax.Array):
    """Per-lane uniform source for one walk batch: ``fn(root_key, t)`` ->
    ``(u1, u2)``, each (B,).

    ``rng_mode == "lane"`` keeps the historical position-indexed stream.
    ``rng_mode == "vertex"`` FOLDS each lane's SOURCE VERTEX id into the
    per-step key (vmapped fold_in + scalar uniform): lane i's draws
    become a pure function of (root, t, source[i]) — independent of
    batch composition — so re-walking any subset of sources under the
    same key reproduces the full-batch walks bit-for-bit (the
    incremental-refresh contract). Cost is O(B) threefry work per
    superstep regardless of |V| — a (|V|,)-wide counter row gathered by
    source id would pay O(|V|) per DISPATCH CHUNK per superstep, a
    ~|V|/B overdraw exactly in the chunked/subset cases vertex keying
    exists for.
    """
    if spec.rng_mode == "vertex":
        src = sources.astype(jnp.int32)

        def fn(root_key, t):
            step_key = jax.random.fold_in(root_key, t)
            k1, k2 = jax.random.split(step_key)
            u1 = jax.vmap(
                lambda v: jax.random.uniform(jax.random.fold_in(k1, v)))(src)
            u2 = jax.vmap(
                lambda v: jax.random.uniform(jax.random.fold_in(k2, v)))(src)
            return u1, u2

        return fn
    if spec.rng_mode != "lane":
        raise ValueError(f"unknown rng_mode {spec.rng_mode!r}")
    b = int(sources.shape[0])
    return lambda root_key, t: step_uniforms(root_key, t, b)


# ---------------------------------------------------------------------------
# Phase A — propose (runs where the walker resides)
# ---------------------------------------------------------------------------


def propose(
    graph: CSRGraph,
    policy: Policy,
    cur: jax.Array,
    prev: jax.Array,
    u1: jax.Array,
    u2: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Candidate draw + walking-backtracking acceptance, per lane.

    Returns (cand, eidx, accept_raw, has_nbrs); the caller masks with its
    residence/activity lanes. ``accept_raw`` already includes ``has_nbrs``.
    """
    deg = node_degrees(graph, cur)                    # (B,) f32
    has_nbrs = deg > 0
    j = jnp.minimum((u1 * deg).astype(jnp.int32),
                    jnp.maximum(deg.astype(jnp.int32) - 1, 0))
    eidx = graph.indptr[cur].astype(jnp.int32) + j
    eidx = jnp.clip(eidx, 0, graph.indices.shape[0] - 1)
    cand = graph.indices[eidx]

    p_acc = policy.accept_prob(graph, prev, cur, cand, eidx)
    accept_raw = has_nbrs & (u2 < p_acc)
    return cand, eidx, accept_raw, has_nbrs


# ---------------------------------------------------------------------------
# Phase B — absorb (runs where the ACCEPTED node lives)
# ---------------------------------------------------------------------------


def _fullpath_entropy(path: jax.Array, length: jax.Array) -> jax.Array:
    """H(W^L) recomputed from scratch: O(max_len^2) lane-work per call.

    Uses the positional identity  H = -(1/L) * sum_{i<L} log2(n(path_i)/L)
    (each node v contributes n(v) positions)."""
    b, max_len = path.shape
    pos = jnp.arange(max_len, dtype=jnp.int32)
    mask = pos[None, :] < length[:, None]                       # (B, max_len)
    eq = path[:, :, None] == path[:, None, :]                   # (B, i, j)
    eq = eq & mask[:, None, :] & mask[:, :, None]
    n_i = jnp.sum(eq, axis=-1).astype(jnp.float32)              # (B, max_len)
    lf = jnp.maximum(length.astype(jnp.float32), 1.0)[:, None]
    term = jnp.where(mask, jnp.log2(jnp.maximum(n_i, 1.0) / lf), 0.0)
    return -jnp.sum(term, axis=-1) / lf[:, 0]


def _fullpath_r2(
    h_series: jax.Array, length: jax.Array, window: int = 0, start: int = 1
) -> jax.Array:
    """Pearson R^2 over the stored prefix-entropy series (O(L)/step).
    ``window`` > 0 restricts to the last ``window`` points; ``start`` = L0
    drops points with L < L0 (suffix regression)."""
    b, max_len = h_series.shape
    pos = jnp.arange(max_len, dtype=jnp.float32)
    l_series = pos[None, :] + 1.0
    in_prefix = pos[None, :] < length[:, None]
    if window:
        in_prefix = in_prefix & (pos[None, :] >= length[:, None] - window)
    if start > 1:
        in_prefix = in_prefix & (l_series >= jnp.float32(start))
    mask = in_prefix.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(mask, -1), 1.0)
    eh = jnp.sum(h_series * mask, -1) / cnt
    el = jnp.sum(l_series * mask, -1) / cnt
    ehl = jnp.sum(h_series * l_series * mask, -1) / cnt
    eh2 = jnp.sum(h_series * h_series * mask, -1) / cnt
    el2 = jnp.sum(l_series * l_series * mask, -1) / cnt
    cov = ehl - eh * el
    vh = jnp.maximum(eh2 - eh * eh, 0.0)
    vl = jnp.maximum(el2 - el * el, 0.0)
    denom = vh * vl
    return jnp.where(denom > 1e-12, cov * cov / jnp.maximum(denom, 1e-12), 0.0)


def absorb(
    spec: WalkSpec,
    info: incom.InfoState,
    path: jax.Array,
    h_series: jax.Array,
    hring: jax.Array,
    cand: jax.Array,
    proc: jax.Array,
) -> Tuple[incom.InfoState, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Apply one accepted step on ``proc`` lanes against the LOCAL buffers.

    ``path`` is the full walk on a single shard and the owner's fragment in
    the sharded engine — n(v) over either is identical because every visit
    to v is appended where v lives (DESIGN.md §9). The append at position
    L_old is idempotent, so fullpath-mode callers that pre-appended before
    packing the migrating message can reuse this unchanged.

    Returns (info', path', h_series', hring', done_now).
    """
    info_acc, new_path = incom.accept_update(info, path, cand,
                                             spec.reg_start, mask=proc)
    new_info = jax.tree_util.tree_map(
        lambda new, old: jnp.where(proc, new, old), info_acc, info
    )
    l_new = new_info.L  # (B,) f32 — post-accept length

    if spec.info_mode == "fullpath":
        # Recompute H from scratch (O(L^2) lanes) and R over the H-series.
        h_full = _fullpath_entropy(new_path, l_new.astype(jnp.int32))
        idx = jnp.clip(l_new.astype(jnp.int32) - 1, 0, spec.max_len - 1)
        # One-hot select, not scatter (batched scatters serialize on CPU).
        hpos = jnp.arange(h_series.shape[1], dtype=jnp.int32)[None, :]
        h_series = jnp.where(
            proc[:, None] & (hpos == idx[:, None]),
            h_full[:, None], h_series)
        r2 = _fullpath_r2(h_series, l_new.astype(jnp.int32),
                          spec.reg_window, spec.reg_start)
        # Overwrite incremental H with recomputed (identical values) to keep
        # downstream uniform; the *cost* difference is what we benchmark.
        new_info = dataclasses.replace(
            new_info, H=jnp.where(proc, h_full, new_info.H))
    elif spec.reg_window:
        k = hring.shape[1]
        slot = jnp.mod(l_new.astype(jnp.int32) - 1, k)
        rpos = jnp.arange(k, dtype=jnp.int32)[None, :]
        hring = jnp.where(
            proc[:, None] & (rpos == slot[:, None]),
            new_info.H[:, None], hring)
        r2 = incom.windowed_r_squared(hring, l_new, spec.reg_window)
    else:
        r2 = incom.r_squared(new_info)

    # --- termination ----------------------------------------------------------
    if spec.info_mode == "fixed":
        done_now = proc & (l_new >= jnp.float32(spec.fixed_len))
    else:
        long_enough = l_new >= jnp.float32(spec.min_test_len())
        done_now = proc & long_enough & (r2 < jnp.float32(spec.mu))
    done_now = done_now | (proc & (l_new >= jnp.float32(spec.max_len)))
    return new_info, new_path, h_series, hring, done_now


# ---------------------------------------------------------------------------
# Single-shard driver (the k=1 instantiation of the BSP engine)
# ---------------------------------------------------------------------------


def _superstep(
    graph: CSRGraph,
    policy: Policy,
    spec: WalkSpec,
    st: WalkerBatchState,
    ufn=None,
) -> WalkerBatchState:
    b = st.cur.shape[0]
    if ufn is None:
        u1, u2 = step_uniforms(st.key, st.supersteps, b)
    else:
        u1, u2 = ufn(st.key, st.supersteps)
    cand, _, accept_raw, has_nbrs = propose(graph, policy, st.cur, st.prev,
                                            u1, u2)
    accept = st.active & accept_raw
    # Lanes whose node has no neighbors terminate immediately.
    dead_end = st.active & ~has_nbrs

    new_info, new_path, h_series, hring, done_now = absorb(
        spec, st.info, st.path, st.h_series, st.hring, cand, accept)
    done_now = done_now | dead_end

    return WalkerBatchState(
        cur=jnp.where(accept, cand, st.cur),
        prev=jnp.where(accept, st.cur, st.prev),
        path=new_path,
        info=new_info,
        h_series=h_series,
        hring=hring,
        active=st.active & ~done_now,
        key=st.key,
        supersteps=st.supersteps + 1,
        accepts=st.accepts + jnp.sum(accept).astype(jnp.int32),
        rejects=st.rejects
        + jnp.sum(st.active & has_nbrs & ~accept_raw).astype(jnp.int32),
        msg_count=st.msg_count,
        msg_bytes=st.msg_bytes,
        msg_bytes_analytic=st.msg_bytes_analytic,
    )


@functools.partial(jax.jit, static_argnames=("policy", "spec"))
def _run_walk_batch_single(
    graph: CSRGraph,
    sources: jax.Array,
    key: jax.Array,
    policy: Policy,
    spec: WalkSpec,
) -> WalkerBatchState:
    st = init_batch(sources, key, spec)
    cap = spec.supersteps_cap()
    ufn = make_uniform_fn(spec, sources)

    def cond(s: WalkerBatchState):
        return jnp.any(s.active) & (s.supersteps < cap)

    def body(s: WalkerBatchState):
        return _superstep(graph, policy, spec, s, ufn)

    return jax.lax.while_loop(cond, body, st)


def run_walk_batch(
    graph: CSRGraph,
    sources: jax.Array,
    key: jax.Array,
    policy: Policy,
    spec: WalkSpec,
    part: Optional[jax.Array] = None,
    num_shards: Optional[int] = None,
    **shard_kwargs,
) -> WalkerBatchState:
    """Run one walk per source until every lane terminates (or cap).

    Without ``part`` this is the dense single-shard engine. With ``part``
    the batch runs on the partition-sharded BSP engine (one logical shard
    per partition): walkers live on the shard owning their current node and
    every cross-partition hand-off is a real packed-message exchange, so
    the returned ``msg_count``/``msg_bytes`` are measured collective
    traffic. Walks are bit-identical either way (per-lane RNG). Extra
    keyword arguments (``engine``, ``pool_factor``, ``exchange_cap``, ...)
    pass through to ``shard_engine.run_walk_sharded``.
    """
    sources = jnp.asarray(sources, jnp.int32)
    if part is None:
        return _run_walk_batch_single(graph, sources, key, policy, spec)
    from repro.core.shard_engine import run_walk_sharded
    part = jnp.asarray(part, jnp.int32)
    if num_shards is None:
        num_shards = int(jnp.max(part)) + 1
    return run_walk_sharded(graph, sources, key, policy, spec, part,
                            num_shards, **shard_kwargs)


def walks_to_numpy(st: WalkerBatchState) -> Tuple[np.ndarray, np.ndarray]:
    """Extract (paths, lengths) as numpy from a finished batch."""
    paths = np.asarray(st.path)
    lengths = np.asarray(st.info.L, dtype=np.int64)
    return paths, lengths


def batch_stats(st: WalkerBatchState) -> Dict[str, float]:
    return {
        "supersteps": int(st.supersteps),
        "accepts": int(st.accepts),
        "rejects": int(st.rejects),
        "msg_count": int(st.msg_count),
        "msg_bytes": float(st.msg_bytes),
        "msg_bytes_analytic": float(st.msg_bytes_analytic),
        "mean_len": float(np.mean(np.asarray(st.info.L))),
    }
