"""Hotness-block synchronization (paper §4.2 Improvement-III).

The global matrices are frequency-sorted (Improvement-I), so nodes with the
same corpus occurrence count form contiguous rank ranges — the hotness
blocks B(i). One synchronization period samples ONE row per block and
averages exactly those rows across all shard replicas:

* a node in B(i) is sampled with probability 1/|B(i)| — hot nodes (tiny
  blocks, often singletons) sync nearly every period, the long cold tail
  (huge blocks) rarely — matching update frequency to sync frequency;
* cost per period is O(ocn_max · d · m) instead of O(|V| · d · m)
  (ocn_max = number of blocks <= max corpus occurrence count).

``full_sync`` is the baseline the paper compares against. Both return the
byte volume they moved so benchmarks can reproduce the §4.2-III claim.

This module holds the *logical* forms: the replica-list API used by
benchmarks and tests, and ``hotness_sync_stacked`` — the same exchange over
a stacked (S, N, d) replica axis, pure jnp and jit-safe, which is what
``core.dsgl.train_chunk`` fuses into the training dispatch.
``repro.dist.collectives.hotness_sync_spmd`` provides the shard_map/psum
form of the same exchange for the SPMD dry-run.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Replica = Tuple[jax.Array, jax.Array]  # (phi_in, phi_out)


def sample_hotness_rows(
    starts: np.ndarray, ends: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """One uniformly-sampled rank per hotness block."""
    if len(starts) == 0:
        return np.zeros(0, dtype=np.int64)
    u = rng.random(len(starts))
    rows = starts + np.floor(u * (ends - starts)).astype(np.int64)
    return rows


def hotness_block_sync(
    replicas: List[Replica],
    starts: np.ndarray,
    ends: np.ndarray,
    rng: np.random.Generator,
) -> Tuple[List[Replica], float]:
    """Average the sampled hotness rows across replicas. Returns the new
    replica list and the bytes moved (rows * d * 4 B * m replicas * 2
    matrices)."""
    m = len(replicas)
    if m <= 1:
        return replicas, 0.0
    rows = sample_hotness_rows(starts, ends, rng)
    if rows.size == 0:
        return replicas, 0.0
    rows_j = jnp.asarray(rows)
    mean_in = jnp.mean(jnp.stack([r[0][rows_j] for r in replicas]), axis=0)
    mean_out = jnp.mean(jnp.stack([r[1][rows_j] for r in replicas]), axis=0)
    new_replicas = [
        (r[0].at[rows_j].set(mean_in), r[1].at[rows_j].set(mean_out))
        for r in replicas
    ]
    dim = int(replicas[0][0].shape[1])
    nbytes = float(rows.size * dim * 4 * m * 2)
    return new_replicas, nbytes


def hotness_sync_stacked(
    phi_in: jax.Array,     # (S, N, d) stacked replica matrices
    phi_out: jax.Array,    # (S, N, d)
    rows: jax.Array,       # (R,) int32 sampled hotness rows
) -> Tuple[jax.Array, jax.Array]:
    """Average the sampled rows across the leading replica axis and write
    them back into every replica — the jit-fusable form of
    ``hotness_block_sync`` (called from inside ``dsgl.train_chunk``)."""
    def exchange(phi):
        mean_rows = jnp.mean(phi[:, rows], axis=0)         # (R, d)
        return phi.at[:, rows].set(
            jnp.broadcast_to(mean_rows, (phi.shape[0],) + mean_rows.shape))
    return exchange(phi_in), exchange(phi_out)


def full_sync(replicas: List[Replica]) -> Tuple[List[Replica], float]:
    """Baseline: average EVERY row across replicas — O(|V| d m) bytes."""
    m = len(replicas)
    if m <= 1:
        return replicas, 0.0
    mean_in = jnp.mean(jnp.stack([r[0] for r in replicas]), axis=0)
    mean_out = jnp.mean(jnp.stack([r[1] for r in replicas]), axis=0)
    n, d = replicas[0][0].shape
    nbytes = float(n * d * 4 * m * 2)
    return [(mean_in, mean_out) for _ in range(m)], nbytes


def sync_cost_model(
    num_nodes: int, dim: int, m: int, num_blocks: int
) -> Tuple[float, float]:
    """(hotness_bytes, full_bytes) per synchronization period — the paper's
    O(ocn_max d m) vs O(|V| d m) comparison, in concrete bytes."""
    hot = float(num_blocks * dim * 4 * m * 2)
    full = float(num_nodes * dim * 4 * m * 2)
    return hot, full
