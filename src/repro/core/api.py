"""General embedding API (paper §6.6 "Generality of DistGER").

DistGER's engine is method-agnostic: DeepWalk / node2vec / HuGE(+) all run
through the same sampler, and each can use either its routine configuration
(fixed L, r) or DistGER's information-centric termination (R^2 < mu walk
length + Delta D <= delta walk count). ``embed_graph`` is the one-call
user-facing entry point: partition -> sample -> learn -> embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.corpus import Corpus, FrequencyOrder, generate_corpus
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec


@dataclasses.dataclass(frozen=True)
class EmbedConfig:
    method: str = "huge"           # huge | deepwalk | node2vec | huge_plus
    info_termination: bool = True  # DistGER info-centric L and r
    fixed_len: int = 80            # routine config (when info_termination=False)
    fixed_rounds: int = 10
    max_len: int = 100
    min_len: int = 20
    mu: float = 0.995
    reg_start: int = 16
    delta: float = 1e-3
    d_window: int = 3              # Eq. 7 gate: windowed-mean ΔD (1 = raw)
    dim: int = 128
    window: int = 10
    negatives: int = 5
    epochs: int = 1
    lr: float = 0.025
    multi_windows: int = 2
    seed: int = 0
    p: float = 1.0                 # node2vec return parameter
    q: float = 1.0                 # node2vec in-out parameter


def make_walk_plan(cfg: EmbedConfig) -> Tuple[object, WalkSpec, Dict]:
    """Resolve (policy, spec, round kwargs) for a method + termination mode."""
    name = "huge" if cfg.method in ("huge", "huge_plus") else cfg.method
    policy = make_policy(name, p=cfg.p, q=cfg.q)
    if cfg.info_termination:
        spec = WalkSpec(max_len=cfg.max_len, min_len=cfg.min_len,
                        mu=cfg.mu, info_mode="incom", reg_start=cfg.reg_start)
        rounds = dict(delta=cfg.delta, min_rounds=2, max_rounds=20,
                      window=cfg.d_window)
    else:
        spec = WalkSpec(max_len=cfg.fixed_len, info_mode="fixed",
                        fixed_len=cfg.fixed_len)
        rounds = dict(delta=-1.0, min_rounds=cfg.fixed_rounds,
                      max_rounds=cfg.fixed_rounds)
    return policy, spec, rounds


def sample_corpus(graph, cfg: EmbedConfig, part: Optional[np.ndarray] = None) -> Corpus:
    policy, spec, rounds = make_walk_plan(cfg)
    return generate_corpus(
        graph, policy=policy, spec=spec, seed=cfg.seed, part=part, **rounds
    )


def embed_graph(
    graph,
    cfg: EmbedConfig = EmbedConfig(),
    *,
    num_shards: int = 1,
    return_corpus: bool = False,
    streaming: bool = True,
):
    """partition -> sharded info-oriented walks -> streamed DSGL -> embeddings.

    The default path is the fused pipeline (``StreamingEmbedPipeline``):
    walks run on the partition-sharded BSP engine, finished rounds append
    into a device-resident corpus ring, and DSGL training consumes ring
    slots directly — round r trains while round r+1 walks, and nothing
    round-trips through host numpy between sampling and learning.
    ``streaming=False`` keeps the legacy two-phase path (sample the whole
    corpus, then ``train_dsgl`` in frequency-rank space).

    Returns (phi_in, phi_out) in ORIGINAL node-id space, plus optional
    corpus. Imports are deferred so this module stays import-light.
    """
    from repro.core.mpgp import mpgp_partition
    from repro.core.dsgl import DSGLConfig

    part = None
    if num_shards > 1:
        part = mpgp_partition(graph, num_shards).assignment
    dsgl_cfg = DSGLConfig(
        dim=cfg.dim, window=cfg.window, negatives=cfg.negatives,
        epochs=cfg.epochs, lr=cfg.lr, multi_windows=cfg.multi_windows,
        seed=cfg.seed,
    )

    if streaming:
        from repro.runtime.trainer import StreamingEmbedPipeline

        policy, spec, rounds = make_walk_plan(cfg)
        pipe = StreamingEmbedPipeline(
            graph, policy, spec, rounds, dsgl_cfg,
            assignment=part, num_shards=num_shards)
        out = pipe.run()
        phi_in = np.asarray(out["phi_in"])     # node space already
        phi_out = np.asarray(out["phi_out"])
        if return_corpus:
            return phi_in, phi_out, pipe.corpus()
        return phi_in, phi_out

    from repro.core.dsgl import train_dsgl

    corpus = sample_corpus(graph, cfg, part=part)
    order = FrequencyOrder.from_ocn(corpus.ocn)
    phi_in_rank, phi_out_rank = train_dsgl(corpus, order, dsgl_cfg,
                                           num_shards=num_shards)
    # Back to original node-id space.
    phi_in = np.asarray(phi_in_rank)[order.to_rank]
    phi_out = np.asarray(phi_out_rank)[order.to_rank]
    if return_corpus:
        return phi_in, phi_out, corpus
    return phi_in, phi_out
