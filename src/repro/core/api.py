"""General embedding API (paper §6.6 "Generality of DistGER").

DistGER's engine is method-agnostic: DeepWalk / node2vec / HuGE(+) all run
through the same sampler, and each can use either its routine configuration
(fixed L, r) or DistGER's information-centric termination (R^2 < mu walk
length + Delta D <= delta walk count). ``embed_graph`` is the one-call
user-facing entry point: partition -> sample -> learn -> embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.corpus import Corpus, FrequencyOrder, generate_corpus
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec


@dataclasses.dataclass(frozen=True)
class EmbedConfig:
    method: str = "huge"           # huge | deepwalk | node2vec | huge_plus
    info_termination: bool = True  # DistGER info-centric L and r
    fixed_len: int = 80            # routine config (when info_termination=False)
    fixed_rounds: int = 10
    max_len: int = 100
    min_len: int = 20
    mu: float = 0.995
    reg_start: int = 16
    delta: float = 1e-3
    d_window: int = 3              # Eq. 7 gate: windowed-mean ΔD (1 = raw)
    dim: int = 128
    window: int = 10
    negatives: int = 5
    epochs: int = 1
    lr: float = 0.025
    multi_windows: int = 2
    seed: int = 0
    p: float = 1.0                 # node2vec return parameter
    q: float = 1.0                 # node2vec in-out parameter
    rng_mode: str = "lane"         # walk RNG keying; "vertex" makes walks
                                   # independent of batch composition (the
                                   # incremental-refresh contract; forced
                                   # on by return_state/updates)


def make_walk_plan(cfg: EmbedConfig) -> Tuple[object, WalkSpec, Dict]:
    """Resolve (policy, spec, round kwargs) for a method + termination mode."""
    name = "huge" if cfg.method in ("huge", "huge_plus") else cfg.method
    policy = make_policy(name, p=cfg.p, q=cfg.q)
    if cfg.info_termination:
        spec = WalkSpec(max_len=cfg.max_len, min_len=cfg.min_len,
                        mu=cfg.mu, info_mode="incom", reg_start=cfg.reg_start,
                        rng_mode=cfg.rng_mode)
        rounds = dict(delta=cfg.delta, min_rounds=2, max_rounds=20,
                      window=cfg.d_window)
    else:
        spec = WalkSpec(max_len=cfg.fixed_len, info_mode="fixed",
                        fixed_len=cfg.fixed_len, rng_mode=cfg.rng_mode)
        rounds = dict(delta=-1.0, min_rounds=cfg.fixed_rounds,
                      max_rounds=cfg.fixed_rounds)
    return policy, spec, rounds


def sample_corpus(graph, cfg: EmbedConfig, part: Optional[np.ndarray] = None) -> Corpus:
    policy, spec, rounds = make_walk_plan(cfg)
    return generate_corpus(
        graph, policy=policy, spec=spec, seed=cfg.seed, part=part, **rounds
    )


@dataclasses.dataclass
class EmbedState:
    """Handle onto a live embedding: the streaming pipeline plus the
    delta-overlay/refresh driver around it. ``refresh_embedding`` keeps
    this handle current across edge-churn batches."""

    refresher: object           # core.incremental.IncrementalRefresh
    cfg: EmbedConfig
    num_shards: int

    @property
    def graph(self):
        return self.refresher.pipeline.graph

    def embeddings(self):
        return self.refresher.embeddings()


def embed_graph(
    graph,
    cfg: EmbedConfig = EmbedConfig(),
    *,
    num_shards: int = 1,
    return_corpus: bool = False,
    streaming: bool = True,
    updates=None,
    return_state: bool = False,
):
    """partition -> sharded info-oriented walks -> streamed DSGL -> embeddings.

    The default path is the fused pipeline (``StreamingEmbedPipeline``):
    walks run on the partition-sharded BSP engine, finished rounds append
    into a device-resident corpus ring, and DSGL training consumes ring
    slots directly — round r trains while round r+1 walks, and nothing
    round-trips through host numpy between sampling and learning.
    ``streaming=False`` keeps the legacy two-phase path (sample the whole
    corpus, then ``train_dsgl`` in frequency-rank space).

    Dynamic graphs: ``return_state=True`` additionally returns an
    ``EmbedState`` that ``refresh_embedding`` can absorb edge churn into
    incrementally (walk RNG is forced to vertex keying so subset re-walks
    stay bit-identical); ``updates=EdgeBatch(...)`` embeds the base graph
    and immediately refreshes it with the batch.

    Returns (phi_in, phi_out) in ORIGINAL node-id space, plus optional
    corpus and/or state. Imports are deferred so this module stays
    import-light.
    """
    from repro.core.mpgp import mpgp_partition
    from repro.core.dsgl import DSGLConfig

    incremental = updates is not None or return_state
    if incremental and not streaming:
        raise ValueError(
            "updates=/return_state= need the streaming pipeline "
            "(streaming=True); the two-phase path has no resident state "
            "to refresh")
    if incremental and cfg.rng_mode != "vertex":
        cfg = dataclasses.replace(cfg, rng_mode="vertex")

    part = None
    if num_shards > 1:
        part = mpgp_partition(graph, num_shards).assignment
    dsgl_cfg = DSGLConfig(
        dim=cfg.dim, window=cfg.window, negatives=cfg.negatives,
        epochs=cfg.epochs, lr=cfg.lr, multi_windows=cfg.multi_windows,
        seed=cfg.seed,
    )

    if streaming:
        from repro.runtime.trainer import StreamingEmbedPipeline

        policy, spec, rounds = make_walk_plan(cfg)
        pipe = StreamingEmbedPipeline(
            graph, policy, spec, rounds, dsgl_cfg,
            assignment=part, num_shards=num_shards)
        pipe.run()
        state = None
        if incremental:
            from repro.core.incremental import IncrementalRefresh

            state = EmbedState(refresher=IncrementalRefresh(pipe),
                               cfg=cfg, num_shards=num_shards)
            if updates is not None:
                state.refresher.apply_updates(updates)
                state.refresher.refresh()
        phi_in, phi_out = pipe.embeddings()
        out = (phi_in, phi_out)
        if return_corpus:
            out = out + (pipe.corpus(),)
        if return_state:
            out = out + (state,)
        return out

    from repro.core.dsgl import train_dsgl

    corpus = sample_corpus(graph, cfg, part=part)
    order = FrequencyOrder.from_ocn(corpus.ocn)
    phi_in_rank, phi_out_rank = train_dsgl(corpus, order, dsgl_cfg,
                                           num_shards=num_shards)
    # Back to original node-id space.
    phi_in = np.asarray(phi_in_rank)[order.to_rank]
    phi_out = np.asarray(phi_out_rank)[order.to_rank]
    if return_corpus:
        return phi_in, phi_out, corpus
    return phi_in, phi_out


def refresh_embedding(
    state: EmbedState,
    updates,
    *,
    detect: Optional[str] = None,
    **refresh_kwargs,
):
    """Absorb an ``EdgeBatch`` into a live embedding incrementally.

    mutate -> detect (from the corpus) -> re-walk ONLY affected vertices
    -> fine-tune DSGL in place. Returns (phi_in, phi_out, stats) where
    ``stats`` is a ``core.incremental.RefreshStats`` (affected fraction,
    re-walk supersteps, wall clock — the cost columns of
    BENCH_incremental.json). Keyword arguments (``fine_tune_frac``,
    ``max_extra_rounds``, ...) pass through to the pipeline refresh.
    ``detect`` overrides the refresher's configured detection mode FOR
    THIS CALL only ("traversal" | "paranoid").
    """
    prev_detect = state.refresher.detect
    if detect is not None:
        state.refresher.detect = detect
    try:
        state.refresher.apply_updates(updates)
        stats = state.refresher.refresh(**refresh_kwargs)
    finally:
        state.refresher.detect = prev_detect
    phi_in, phi_out = state.refresher.embeddings()
    return phi_in, phi_out, stats
