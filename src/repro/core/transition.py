"""Walk transition policies (paper Eq. 3 + §2.1/§2.2 baselines).

All policies expose one vectorized function:

    accept_prob(graph, prev, cur, cand, cand_edge_idx) -> (B,) float32

used inside the rejection/backtracking loop of the walker engine
(HuGE's walking-backtracking == KnightKing's rejection sampling; a rejected
lane keeps ``cur`` and redraws next superstep).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph


def node_degrees(graph: CSRGraph, nodes: jax.Array) -> jax.Array:
    return (graph.indptr[nodes + 1] - graph.indptr[nodes]).astype(jnp.float32)


def row_contains(graph: CSRGraph, rows: jax.Array, values: jax.Array) -> jax.Array:
    """Vectorized membership test: values[i] in sorted N(rows[i]).

    Fixed-iteration binary search over each CSR row (32 steps cover any
    |E| < 2^32) — SIMD-friendly, no data-dependent trip counts.
    """
    lo = graph.indptr[rows].astype(jnp.int32)
    hi0 = graph.indptr[rows + 1].astype(jnp.int32)

    def body(_, carry):
        lo, hi = carry
        searching = lo < hi
        mid = (lo + hi) // 2
        mid_val = graph.indices[jnp.clip(mid, 0, graph.indices.shape[0] - 1)]
        less = mid_val < values
        lo = jnp.where(searching & less, mid + 1, lo)
        hi = jnp.where(searching & ~less, mid, hi)
        return lo, hi

    lo_f, _ = jax.lax.fori_loop(0, 32, body, (lo, hi0))
    pos = jnp.clip(lo_f, 0, graph.indices.shape[0] - 1)
    found = (lo_f < hi0) & (graph.indices[pos] == values)
    return found


def common_neighbors_onthefly(
    graph: CSRGraph, u: jax.Array, v: jax.Array, max_deg: int
) -> jax.Array:
    """Reference on-the-fly Cm(u, v): for each neighbor of u, test membership
    in N(v). O(deg * log deg) per pair — used only for validating the
    precomputed ``edge_cm`` (DESIGN.md §2)."""
    b = u.shape[0]
    base = graph.indptr[u].astype(jnp.int32)
    deg = (graph.indptr[u + 1] - graph.indptr[u]).astype(jnp.int32)
    offs = jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    valid = offs < deg[:, None]
    nbr_idx = jnp.clip(base[:, None] + offs, 0, graph.indices.shape[0] - 1)
    nbrs = graph.indices[nbr_idx]
    flat_rows = jnp.repeat(v, max_deg)
    flat_vals = nbrs.reshape(-1)
    member = row_contains(graph, flat_rows, flat_vals).reshape(b, max_deg)
    return jnp.sum(member & valid, axis=-1).astype(jnp.int32)


class Policy:
    """Base class — subclasses are stateless, graph-closed callables."""

    needs_prev: bool = False
    needs_edge_cm: bool = False     # HuGE transition needs Cm(u,v) precompute
    # Whether accept_prob can be evaluated from one shard's partition-local
    # CSR slice alone (local indptr row + edge-aligned halo metadata).
    # Second-order policies that read N(prev) — a row that may live on
    # another shard — cannot, and route through the replicated engine.
    supports_partition_local: bool = False

    def accept_prob(
        self,
        graph: CSRGraph,
        prev: jax.Array,
        cur: jax.Array,
        cand: jax.Array,
        cand_edge_idx: jax.Array,
    ) -> jax.Array:
        raise NotImplementedError

    def accept_prob_local(
        self,
        shard,                 # graph.csr.ShardCSR (one shard's slice)
        prev: jax.Array,       # (P,) global ids
        cur_local: jax.Array,  # (P,) LOCAL row ids in this shard's slice
        cand: jax.Array,       # (P,) global ids
        cand_edge_idx: jax.Array,  # (P,) LOCAL edge ids in this slice
    ) -> jax.Array:
        """Partition-local form of ``accept_prob``: identical arithmetic on
        the shard's slice (bit-identical outputs), no global CSR reads."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class HugePolicy(Policy):
    """HuGE information-oriented transition (Eq. 3):

        alpha(u,v) = 1/(deg(u) - Cm(u,v)) * max(deg(u)/deg(v), deg(v)/deg(u))
        P(u,v)     = Z(alpha * w(u,v)),  Z(x) = tanh(x)

    Cm comes from the CSR-aligned precompute (graph.edge_cm); since v is a
    neighbor of u and graphs are loop-free, deg(u) - Cm(u,v) >= 1 always.
    """

    needs_prev = False
    needs_edge_cm = True
    supports_partition_local = True

    def accept_prob(self, graph, prev, cur, cand, cand_edge_idx):
        deg_u = node_degrees(graph, cur)
        deg_v = node_degrees(graph, cand)
        if graph.edge_cm is None:
            raise ValueError("HugePolicy requires graph.with_edge_cm()")
        cm = graph.edge_cm[cand_edge_idx].astype(jnp.float32)
        ratio = jnp.maximum(deg_u / jnp.maximum(deg_v, 1.0),
                            deg_v / jnp.maximum(deg_u, 1.0))
        alpha = ratio / jnp.maximum(deg_u - cm, 1.0)
        if graph.weights is not None:
            alpha = alpha * graph.weights[cand_edge_idx]
        return jnp.tanh(alpha)

    def accept_prob_local(self, shard, prev, cur_local, cand, cand_edge_idx):
        # Same f32 expression as accept_prob, fed from the slice: deg(u)
        # from the local row, deg(v)/Cm/w from the edge-aligned halo arrays.
        deg_u = (shard.indptr[cur_local + 1]
                 - shard.indptr[cur_local]).astype(jnp.float32)
        deg_v = shard.nbr_deg[cand_edge_idx].astype(jnp.float32)
        if shard.edge_cm is None:
            raise ValueError("HugePolicy requires graph.with_edge_cm()")
        cm = shard.edge_cm[cand_edge_idx].astype(jnp.float32)
        ratio = jnp.maximum(deg_u / jnp.maximum(deg_v, 1.0),
                            deg_v / jnp.maximum(deg_u, 1.0))
        alpha = ratio / jnp.maximum(deg_u - cm, 1.0)
        if shard.weights is not None:
            alpha = alpha * shard.weights[cand_edge_idx]
        return jnp.tanh(alpha)


@dataclasses.dataclass(frozen=True)
class Node2vecPolicy(Policy):
    """node2vec second-order walk via rejection sampling (KnightKing §2.2).

    pi(u,v) = 1/p if v == prev; 1 if v in N(prev); 1/q otherwise.
    Envelope Q = max(1/p, 1, 1/q); acceptance = pi / Q.
    """

    p: float = 1.0
    q: float = 1.0
    needs_prev = True

    def accept_prob(self, graph, prev, cur, cand, cand_edge_idx):
        inv_p = jnp.float32(1.0 / self.p)
        inv_q = jnp.float32(1.0 / self.q)
        envelope = jnp.maximum(jnp.maximum(inv_p, 1.0), inv_q)
        is_return = cand == prev
        is_common = row_contains(graph, prev, cand)
        pi = jnp.where(is_return, inv_p, jnp.where(is_common, 1.0, inv_q))
        # First step of a walk has prev == cur: uniform first hop.
        first = prev == cur
        pi = jnp.where(first, envelope, pi)
        return pi / envelope


@dataclasses.dataclass(frozen=True)
class DeepwalkPolicy(Policy):
    """Uniform first-order walk — every candidate accepted."""

    needs_prev = False
    supports_partition_local = True

    def accept_prob(self, graph, prev, cur, cand, cand_edge_idx):
        return jnp.ones_like(cand, dtype=jnp.float32)

    def accept_prob_local(self, shard, prev, cur_local, cand, cand_edge_idx):
        return jnp.ones_like(cand, dtype=jnp.float32)


def make_policy(name: str, **kwargs) -> Policy:
    name = name.lower()
    if name == "huge":
        return HugePolicy()
    if name == "node2vec":
        return Node2vecPolicy(p=kwargs.get("p", 1.0), q=kwargs.get("q", 1.0))
    if name == "deepwalk":
        return DeepwalkPolicy()
    raise ValueError(f"unknown policy {name!r}")
