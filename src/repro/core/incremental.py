"""Incremental embedding refresh after edge churn (dynamic-graph driver).

DistGER computes walk information *incrementally*; this module extends the
same posture to the GRAPH: when a batch of edges changes, the system must
not re-walk and retrain the world. The lifecycle is

    mutate  — churn batches accumulate in a ``graph.delta.DeltaCSR``
              overlay (O(|Δ| log |E|) per batch, periodic vectorized
              compaction back into CSR);
    detect  — the affected-vertex set is RECOVERED FROM THE CORPUS
              (``incom.paths_traverse_edges`` / ``paths_visit_nodes``):
              endpoints of changed edges plus roots of recorded walks that
              traverse a changed arc — no walk is re-simulated to find out
              whether it is stale;
    re-walk — only affected roots go back through the sharded walk engine,
              one subset batch per retained round under the SAME round
              keys; vertex-keyed per-lane RNG (``WalkSpec.rng_mode ==
              "vertex"``) makes the subset walks bit-identical to what a
              full-batch walk on the mutated graph would produce, and
              ``corpus.ring_replace`` swaps them into their original
              round-aligned ring slots (untouched slots stay bit-identical
              by construction);
    gate    — the Eq. 7 ΔD controller continues SEEDED from the prior
              run's D_r history (no cold-start burn-in): if churn moved
              the degree/occurrence divergence beyond delta, extra
              subset rounds append until it re-converges;
    tune    — DSGL fine-tunes in place over the refreshed ring through the
              existing ``StreamingEmbedPipeline`` training path (decayed
              mini-schedule, node-space alias table rebuilt from the
              exact refreshed ocn).

Detection modes
---------------
``"traversal"`` (default, the paper-spirit detector): a stored walk is
stale iff it traverses a changed arc; plus all churn endpoints. Walks that
merely pass nearby keep slightly stale *sampling distributions* (quality
is guarded by the refresh AUC benchmarks), but every kept slot is
bit-identical to its pre-update contents.

``"paranoid"``: additionally re-walks every root whose walk visits the
closed neighborhood of the churn. Kept walks are then PROVABLY identical
to a from-scratch walk of the mutated graph (no visited node's candidate
row, degree, or Cm inputs changed) — the detector to use when exact
distributional freshness matters more than re-walk volume.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import incom
from repro.graph.csr import CSRGraph
from repro.graph.delta import DeltaCSR, EdgeBatch


def changed_arc_codes(edges: np.ndarray, num_nodes: int) -> np.ndarray:
    """Sorted row-major arc codes for both directions of ``edges``."""
    if len(edges) == 0:
        return np.zeros(0, np.int64)
    e = np.asarray(edges, np.int64)
    arcs = np.concatenate([e, e[:, ::-1]], axis=0)
    codes = arcs[:, 0] * np.int64(num_nodes) + arcs[:, 1]
    return np.unique(codes)


def closed_neighborhood(graph: CSRGraph, nodes: np.ndarray) -> np.ndarray:
    """(|V|,) bool mask of ``nodes`` plus all their neighbors."""
    g = graph.to_numpy()
    indptr = np.asarray(g.indptr, np.int64)
    indices = np.asarray(g.indices, np.int64)
    n = len(indptr) - 1
    mark = np.zeros(n, bool)
    nodes = np.asarray(nodes, np.int64)
    nodes = nodes[nodes < n]
    mark[nodes] = True
    for v in nodes:
        mark[indices[indptr[v]:indptr[v + 1]]] = True
    return mark


def affected_roots(
    walks: np.ndarray,
    roots: np.ndarray,
    changed_edges: np.ndarray,
    touched: np.ndarray,
    num_nodes: int,
    *,
    mode: str = "traversal",
    old_graph: Optional[CSRGraph] = None,
    new_graph: Optional[CSRGraph] = None,
) -> np.ndarray:
    """(num_nodes,) bool — which vertices' walks must be re-simulated.

    ``walks`` are the recorded (-1 padded) corpus buffers, ``roots`` the
    per-row source vertex. Everything is recovered from the corpus —
    detection never steps the walk engine.
    """
    affected = np.zeros(num_nodes, bool)
    touched = np.asarray(touched, np.int64)
    affected[touched[touched < num_nodes]] = True
    if len(walks) == 0:
        return affected

    roots = np.asarray(roots, np.int64)
    if num_nodes * num_nodes < 2**31:
        codes = changed_arc_codes(changed_edges, num_nodes)
        hit = np.asarray(incom.paths_traverse_edges(
            jnp.asarray(walks, jnp.int32),
            jnp.asarray(codes, jnp.int32), num_nodes))
    else:
        # Host int64 fallback for graphs whose pair codes overflow int32.
        codes = changed_arc_codes(changed_edges, num_nodes)
        a, b = walks[:, :-1].astype(np.int64), walks[:, 1:].astype(np.int64)
        valid = (a >= 0) & (b >= 0)
        pair = np.maximum(a, 0) * np.int64(num_nodes) + np.maximum(b, 0)
        hit = (np.isin(pair, codes) & valid).any(axis=1)
    affected[roots[hit]] = True

    if mode == "paranoid":
        mark = closed_neighborhood(old_graph, touched)
        if new_graph is not None:
            mark |= closed_neighborhood(new_graph, touched)[:num_nodes]
        visit = np.asarray(incom.paths_visit_nodes(
            jnp.asarray(walks, jnp.int32), jnp.asarray(mark)))
        affected[roots[visit]] = True
    elif mode != "traversal":
        raise ValueError(f"unknown detection mode {mode!r}")
    return affected


@dataclasses.dataclass
class RefreshStats:
    """Cost/quality record of one refresh (also the BENCH_incremental row)."""

    changed_edges: int
    churn_frac: float              # changed edges / |E_und| pre-churn
    affected: int
    affected_frac: float           # affected roots / |V|
    retained_rounds: int
    extra_rounds: int
    rewalk_walks: int              # walks re-simulated (roots x rounds)
    rewalk_supersteps: int
    fine_tune_steps: int
    wall_s: float
    mode: str = "full"             # degrade ladder rung (DESIGN.md §12)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class IncrementalRefresh:
    """Owns the mutate → detect → re-walk → fine-tune lifecycle around one
    ``StreamingEmbedPipeline`` and one ``DeltaCSR`` overlay.

    The pipeline must have been built with ``WalkSpec.rng_mode ==
    "vertex"`` (the subset-re-walk bit-identity contract);
    ``core.api.embed_graph(..., return_state=True)`` arranges this.
    """

    def __init__(self, pipeline, delta: Optional[DeltaCSR] = None,
                 *, detect: str = "traversal"):
        if pipeline.spec.rng_mode != "vertex":
            raise ValueError(
                "incremental refresh needs vertex-keyed walk RNG "
                "(WalkSpec.rng_mode='vertex'); re-embed with "
                "embed_graph(..., return_state=True)")
        self.pipeline = pipeline
        self.delta = delta if delta is not None else DeltaCSR(pipeline.graph)
        self.detect = detect
        self.last_stats: Optional[RefreshStats] = None
        self.last_affected_mask: Optional[np.ndarray] = None

    def apply_updates(self, batch: EdgeBatch) -> "IncrementalRefresh":
        """Stage one churn batch in the overlay (cheap; no refresh yet)."""
        self.delta.apply_batch(batch)
        return self

    def refresh(self, *, mode: str = "full",
                extra_affected: Optional[np.ndarray] = None,
                **kwargs) -> RefreshStats:
        """Absorb all staged churn: compact the overlay, detect affected
        vertices from the corpus, re-walk them, fine-tune DSGL in place.

        ``mode`` is the SLO degrade ladder rung (DESIGN.md §12):
        ``"full"`` the complete lifecycle; ``"no_finetune"`` skips the
        DSGL fine-tune and the ΔD top-up rounds (walks stay exact, phi
        lags); ``"detect_only"`` runs detection and graph adoption only —
        the ring keeps its stale walks and the caller must carry
        ``last_affected_mask`` forward as debt. ``extra_affected`` is that
        debt: a (|V|,) bool mask OR-ed into this refresh's detected set so
        a deferred re-walk happens under the CURRENT graph/keys."""
        if mode not in ("full", "no_finetune", "detect_only"):
            raise ValueError(f"unknown refresh mode {mode!r}")
        t0 = time.perf_counter()
        old_graph = self.pipeline.graph
        n_old = old_graph.num_nodes
        if self.delta.num_nodes != n_old:
            # Validate BEFORE draining the churn log / compacting: a
            # failed refresh must leave the refresher consistent (the
            # overlay supports |V| growth, the pipeline does not yet).
            raise ValueError(
                f"staged churn grows the vertex set "
                f"({self.delta.num_nodes} != {n_old}), which "
                "refresh_embedding cannot absorb yet; rebuild with "
                "embed_graph on the mutated graph")
        arcs_und = old_graph.num_edges / 2.0
        ins, dele = self.delta.take_changes()
        changed = np.concatenate([ins, dele], axis=0)
        touched = (np.unique(changed.reshape(-1))
                   if len(changed) else np.zeros(0, np.int64))
        new_graph = self.delta.compact()

        walks, roots, valid = self.pipeline.corpus_slots()
        affected_mask = affected_roots(
            walks[valid], roots[valid], changed, touched, n_old,
            mode=self.detect, old_graph=old_graph, new_graph=new_graph)
        if extra_affected is not None:
            affected_mask = affected_mask | np.asarray(extra_affected, bool)
        self.last_affected_mask = affected_mask.copy()

        if mode == "detect_only":
            self.pipeline.adopt_graph(new_graph)
            body = {
                "affected": int(affected_mask.sum()),
                "affected_frac": float(affected_mask.mean()),
                "retained_rounds": 0, "extra_rounds": 0,
                "rewalk_walks": 0, "rewalk_supersteps": 0,
                "fine_tune_steps": 0,
                "wall_s": float(time.perf_counter() - t0),
            }
        else:
            if mode == "no_finetune":
                kwargs = {**kwargs, "fine_tune_steps": 0,
                          "max_extra_rounds": 0}
            body = self.pipeline.refresh(new_graph, affected_mask, **kwargs)
        stats = RefreshStats(
            changed_edges=int(len(changed)),
            churn_frac=float(len(changed) / max(arcs_und, 1.0)),
            mode=mode,
            **body)
        self.last_stats = stats
        return stats

    def embeddings(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.pipeline.embeddings()
