"""DSGL — distributed Skip-Gram learning (paper §4).

Improvement-I  (global matrices + local buffers): the embedding matrices are
laid out in descending corpus frequency (``FrequencyOrder``); each training
*lifetime* gathers the rows it will touch into local buffers, performs every
update there, and writes the deltas back once at the end. On TPU the buffers
live in VMEM (see ``repro.kernels.sgns``); this module is the pure-JAX
reference with identical semantics.

Improvement-II (multi-window shared negatives): ``multi_windows`` walks are
trained together per lane; their context windows share one negative-sample
set per position, and each walk's target acts as an extra negative for the
other walks — turning K+1 dot products into one (W·2w) x (W+K) level-3
matmul per position (MXU-shaped).

Improvement-III (hotness-block synchronization) lives in
``repro.core.sync`` and is fused into ``train_chunk``; the shard_map/psum
form is ``repro.dist.collectives.hotness_sync_spmd``.

Device residency: the whole training hot path runs inside ONE jit per
chunk of ``sync_period`` lifetimes — negatives are drawn on-device from a
precomputed Vose alias table (``AliasTable``), the shard replicas are a
leading array axis processed together (no Python loop over replicas), the
chunk is a ``lax.scan`` over lifetimes with the embedding matrices donated,
and the write-back scatter-averages straight into the donated matrices
without materializing any dense (N, d) temporary.

Race semantics: as in the paper (Hogwild heritage), duplicate rows inside a
lifetime and across shards are updated without locks; duplicate buffer rows
of one batch are AVERAGED on write-back (summing would multiply a hub
node's step by its duplicate count and diverge — see ``_scatter_average``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.corpus import Corpus, FrequencyOrder


@dataclasses.dataclass(frozen=True)
class DSGLConfig:
    dim: int = 128
    window: int = 10            # w — context half-width
    negatives: int = 5          # K — shared negative samples per position
    multi_windows: int = 2      # W — walks trained together per lane
    batch_groups: int = 64      # G — lanes per jit step
    epochs: int = 1
    lr: float = 0.025
    min_lr: float = 1e-4
    neg_power: float = 0.75     # unigram^0.75 negative-sampling distribution
    sync_period: int = 50       # lifetimes between hotness syncs (also the
                                # lax.scan chunk fused into one dispatch)
    seed: int = 0
    use_kernel: bool = False    # route the inner update through Pallas sgns


def init_embeddings(
    num_nodes: int, dim: int, key: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """word2vec convention: phi_in ~ U(-0.5/d, 0.5/d), phi_out = 0."""
    phi_in = (jax.random.uniform(key, (num_nodes, dim), jnp.float32) - 0.5) / dim
    phi_out = jnp.zeros((num_nodes, dim), jnp.float32)
    return phi_in, phi_out


# ---------------------------------------------------------------------------
# Negative sampling
# ---------------------------------------------------------------------------


def negative_table(ocn_sorted: np.ndarray, power: float) -> np.ndarray:
    """Cumulative unigram^power distribution over frequency ranks (the
    host-side CDF form — kept as the distribution oracle the on-device
    alias table is tested against)."""
    w = np.asarray(ocn_sorted, dtype=np.float64) ** power
    if w.sum() == 0:
        w = np.ones_like(w)
    cdf = np.cumsum(w / w.sum())
    cdf[-1] = 1.0
    return cdf


def sample_negatives(
    cdf: np.ndarray, shape: Tuple[int, ...], rng: np.random.Generator
) -> np.ndarray:
    """Host-side CDF inversion (numpy searchsorted) — oracle/baseline only;
    the training hot path samples on-device via ``sample_alias``."""
    u = rng.random(shape)
    return np.searchsorted(cdf, u).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class AliasTable:
    """Vose alias table over frequency ranks: O(1) on-device draws.

    ``prob[i]`` is the acceptance probability of slot i, ``alias[i]`` the
    fallback rank — one uniform slot + one uniform accept/reject per draw,
    all inside jit (vs the host searchsorted + re-upload per step of the
    CDF path)."""

    prob: jax.Array    # (n,) f32
    alias: jax.Array   # (n,) i32

    def tree_flatten(self):
        return (self.prob, self.alias), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AliasTable,
    lambda t: t.tree_flatten(),
    AliasTable.tree_unflatten,
)


def build_alias_table(ocn_sorted: np.ndarray, power: float) -> AliasTable:
    """Vose's algorithm over the unigram^power weights (host, build-once)."""
    w = np.asarray(ocn_sorted, dtype=np.float64) ** power
    if w.sum() == 0:
        w = np.ones_like(w)
    n = len(w)
    scaled = w / w.sum() * n
    prob = np.ones(n, dtype=np.float64)
    alias = np.arange(n, dtype=np.int64)
    small = [i for i in range(n) if scaled[i] < 1.0]
    large = [i for i in range(n) if scaled[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        prob[s] = scaled[s]
        alias[s] = l
        scaled[l] = (scaled[l] + scaled[s]) - 1.0
        (small if scaled[l] < 1.0 else large).append(l)
    for i in small + large:   # numerical leftovers: accept always
        prob[i] = 1.0
    return AliasTable(prob=jnp.asarray(prob, jnp.float32),
                      alias=jnp.asarray(alias, jnp.int32))


def sample_alias(
    table: AliasTable, key: jax.Array, shape: Tuple[int, ...]
) -> jax.Array:
    """Draw int32 ranks ~ unigram^power, fully on-device / jit-safe."""
    n = table.prob.shape[0]
    k_slot, k_acc = jax.random.split(key)
    slot = jax.random.randint(k_slot, shape, 0, n, dtype=jnp.int32)
    u = jax.random.uniform(k_acc, shape, jnp.float32)
    return jnp.where(u < table.prob[slot], slot, table.alias[slot])


# ---------------------------------------------------------------------------
# One lifetime: W walks x T positions, local-buffer semantics.
# The math lives in repro.kernels.sgns: ref.py is the pure-jnp oracle and
# kernel.py the fused Pallas version; both share one source of truth.
# ---------------------------------------------------------------------------


def _lifetime_math(ctx0, out0, neg0, valid, lr, window: int, use_kernel: bool):
    """Run the fused per-lifetime update on gathered (G, ...) buffers."""
    if use_kernel:
        from repro.kernels.sgns import ops as sgns_ops
        return sgns_ops.sgns_lifetime_batch(ctx0, out0, neg0, valid, lr, window)
    from repro.kernels.sgns import ref as sgns_ref
    return sgns_ref.sgns_lifetime_batch_ref(ctx0, out0, neg0, valid, lr, window)


def _scatter_average(base, ids, deltas, mask):
    """base.at[ids].add of duplicate-averaged deltas, allocation-free.

    Duplicate buffer rows of the same embedding row (hub nodes appear in
    many walks of one batch — power-law!) are AVERAGED, not summed: each
    occurrence contributes delta / count(row). Equivalent to the dense
    scatter-mean (sum then divide) but touches only the scattered rows of
    the donated ``base`` — no (N, d) zero temporary, no dense divide."""
    n_rows = base.shape[0]
    ones = jnp.where(mask, 1.0, 0.0)
    cnt = jnp.zeros((n_rows,), jnp.float32).at[ids].add(ones)
    inv = jnp.where(mask, 1.0 / jnp.maximum(cnt[ids], 1.0), 0.0)
    return base.at[ids].add(deltas * inv[:, None])


def _write_back(phi_in, phi_out, safe_walks, negs, valid,
                ctx_buf, ctx0, out_buf, out0, neg_buf, neg0):
    """Scatter the buffer deltas of one replica back into its matrices."""
    flat_ids = safe_walks.reshape(-1)
    d_in = (ctx_buf - ctx0).reshape(flat_ids.shape[0], -1)
    d_out = (out_buf - out0).reshape(flat_ids.shape[0], -1)
    mask = valid.reshape(-1)
    neg_ids = negs.reshape(-1)
    d_neg = (neg_buf - neg0).reshape(neg_ids.shape[0], -1)

    phi_in = _scatter_average(phi_in, flat_ids, d_in, mask)
    # phi_out receives deltas from both walk-token rows and negative rows;
    # average across the union so a hot node's total step stays bounded.
    out_ids = jnp.concatenate([flat_ids, neg_ids])
    out_deltas = jnp.concatenate([d_out, d_neg], axis=0)
    out_mask = jnp.concatenate([mask, jnp.ones_like(neg_ids, bool)])
    phi_out = _scatter_average(phi_out, out_ids, out_deltas, out_mask)
    return phi_in, phi_out


@functools.partial(jax.jit, static_argnames=("window", "use_kernel"),
                   donate_argnums=(0, 1))
def lifetime_step(
    phi_in: jax.Array,        # (N, d)
    phi_out: jax.Array,       # (N, d)
    walks: jax.Array,         # (G, W, T) int32 rank ids, -1 padded
    negs: jax.Array,          # (G, T, K) int32 rank ids
    lr: jax.Array,            # () f32
    window: int,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Process G lifetimes: gather buffers -> scan -> write back deltas."""
    safe_walks = jnp.maximum(walks, 0)
    valid = walks >= 0

    ctx0 = phi_in[safe_walks]                          # (G, W, T, d)
    out0 = phi_out[safe_walks]                         # (G, W, T, d)
    neg0 = phi_out[negs]                               # (G, T, K, d)

    ctx_buf, out_buf, neg_buf, loss = _lifetime_math(
        ctx0, out0, neg0, valid, lr, window, use_kernel)
    phi_in, phi_out = _write_back(
        phi_in, phi_out, safe_walks, negs, valid,
        ctx_buf, ctx0, out_buf, out0, neg_buf, neg0)
    return phi_in, phi_out, jnp.sum(loss)


# ---------------------------------------------------------------------------
# Fused multi-lifetime chunk over stacked shard replicas
# ---------------------------------------------------------------------------


def _replica_step(phi_in, phi_out, walks, negs, lr, window: int,
                  use_kernel: bool):
    """One lifetime batch over STACKED replicas: phi (S, N, d),
    walks (S, G, W, T), negs (S, G, T, K). The shard axis is merged into
    the group axis for the math (one kernel launch for all replicas) and
    vmapped for the per-replica gathers / write-backs."""
    s_cnt, g_cnt, w_cnt, t_len = walks.shape
    safe_walks = jnp.maximum(walks, 0)
    valid = walks >= 0

    gather = jax.vmap(lambda table, ids: table[ids])
    ctx0 = gather(phi_in, safe_walks)                  # (S, G, W, T, d)
    out0 = gather(phi_out, safe_walks)
    neg0 = gather(phi_out, negs)                       # (S, G, T, K, d)

    dim = ctx0.shape[-1]
    k_neg = neg0.shape[-2]
    merge = lambda a, *tail: a.reshape(s_cnt * g_cnt, *tail)
    ctx_buf, out_buf, neg_buf, loss = _lifetime_math(
        merge(ctx0, w_cnt, t_len, dim), merge(out0, w_cnt, t_len, dim),
        merge(neg0, t_len, k_neg, dim), merge(valid, w_cnt, t_len),
        lr, window, use_kernel)
    unmerge = lambda a: a.reshape(s_cnt, g_cnt, *a.shape[1:])

    phi_in, phi_out = jax.vmap(_write_back)(
        phi_in, phi_out, safe_walks, negs, valid,
        unmerge(ctx_buf), ctx0, unmerge(out_buf), out0,
        unmerge(neg_buf), neg0)
    return phi_in, phi_out, loss.reshape(s_cnt, g_cnt).sum(axis=1)


def _chunk_scan(phi_in, phi_out, walks, neg_table, sync_rows, key, lrs,
                window: int, negatives: int, use_kernel: bool, sync: bool):
    """The shared chunk body: scan C lifetimes, optional hotness sync."""
    s_cnt = phi_in.shape[0]
    _, _, g_cnt, _, t_len = walks.shape

    def step(carry, inp):
        pi, po, k = carry
        wb, lr = inp
        k, sub = jax.random.split(k)
        negs = sample_alias(neg_table, sub, (s_cnt, g_cnt, t_len, negatives))
        pi, po, loss = _replica_step(pi, po, wb, negs, lr, window, use_kernel)
        return (pi, po, k), loss

    (phi_in, phi_out, _), losses = jax.lax.scan(
        step, (phi_in, phi_out, key), (walks, lrs))

    if sync and s_cnt > 1:
        from repro.core.sync import hotness_sync_stacked
        phi_in, phi_out = hotness_sync_stacked(phi_in, phi_out, sync_rows)
    return phi_in, phi_out, losses


@functools.partial(
    jax.jit,
    static_argnames=("window", "negatives", "use_kernel", "sync"),
    donate_argnums=(0, 1))
def train_chunk(
    phi_in: jax.Array,        # (S, N, d) stacked replica matrices (donated)
    phi_out: jax.Array,       # (S, N, d) (donated)
    walks: jax.Array,         # (C, S, G, W, T) int32 — C lifetimes fused
    neg_table: AliasTable,    # on-device alias table
    sync_rows: jax.Array,     # (R,) int32 sampled hotness rows
    key: jax.Array,           # PRNG key for the chunk's negative draws
    lrs: jax.Array,           # (C,) f32 per-lifetime learning rates
    window: int,
    negatives: int,
    use_kernel: bool = False,
    sync: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The device-resident hot path: scan C lifetimes in ONE dispatch.

    Negatives are drawn on-device inside the scan (no per-step host
    sampling or H2D), the shard-replica axis is processed by one merged
    kernel launch per step, and when ``sync`` is set the chunk ends with
    the Improvement-III hotness-row exchange across the replica axis.
    Returns (phi_in', phi_out', losses (C, S))."""
    return _chunk_scan(phi_in, phi_out, walks, neg_table, sync_rows, key,
                       lrs, window, negatives, use_kernel, sync)


@functools.partial(
    jax.jit,
    static_argnames=("window", "negatives", "use_kernel", "sync"))
def train_chunk_checked(
    phi_in: jax.Array,        # (S, N, d) — NOT donated (update norm needs
    phi_out: jax.Array,       # (S, N, d)   the pre-chunk matrices)
    walks: jax.Array,         # (C, S, G, W, T)
    neg_table: AliasTable,
    sync_rows: jax.Array,
    key: jax.Array,
    lrs: jax.Array,
    window: int,
    negatives: int,
    use_kernel: bool = False,
    sync: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, dict]:
    """``train_chunk`` plus the watchdog's health reductions, in the SAME
    dispatch: the chunk math is bit-identical (``_chunk_scan`` is shared),
    and four cheap scalar reductions ride along — non-finite counts over
    the new matrices and the chunk losses, the Frobenius norm of the phi
    update (the optimizer-step magnitude a blow-up spikes first), and the
    new phi norm. The inputs are not donated so the update delta can be
    formed against the pre-chunk matrices; the extra live copy is why the
    pipeline only routes every ``HealthConfig.check_every``-th window of
    steps through this variant. Returns (phi_in', phi_out', losses,
    {nonfinite, loss_nonfinite, loss_sum, update_norm, phi_norm})."""
    new_in, new_out, losses = _chunk_scan(
        phi_in, phi_out, walks, neg_table, sync_rows, key, lrs,
        window, negatives, use_kernel, sync)
    health = {
        "nonfinite": (jnp.sum(~jnp.isfinite(new_in))
                      + jnp.sum(~jnp.isfinite(new_out))),
        "loss_nonfinite": jnp.sum(~jnp.isfinite(losses)),
        "loss_sum": jnp.sum(jnp.where(jnp.isfinite(losses), losses, 0.0)),
        "update_norm": jnp.sqrt(jnp.sum((new_in - phi_in) ** 2)
                                + jnp.sum((new_out - phi_out) ** 2)),
        "phi_norm": jnp.sqrt(jnp.sum(new_in ** 2)),
    }
    return new_in, new_out, losses, health


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _group_walks(
    walks: np.ndarray, w_cnt: int, g_cnt: int, rng: np.random.Generator
) -> np.ndarray:
    """Shuffle walks and pack into (num_steps, G, W, T) batches (drop tail)."""
    order = rng.permutation(walks.shape[0])
    per_step = g_cnt * w_cnt
    n_steps = len(order) // per_step
    if n_steps == 0:  # small corpora: pad by repetition
        reps = -(-per_step // max(len(order), 1))
        order = np.tile(order, reps)[:per_step]
        n_steps = 1
    order = order[: n_steps * per_step]
    return walks[order].reshape(n_steps, g_cnt, w_cnt, walks.shape[1])


def train_dsgl(
    corpus: Corpus,
    order: FrequencyOrder,
    cfg: DSGLConfig,
    *,
    num_shards: int = 1,
    collect_metrics: bool = False,
):
    """Train Skip-Gram embeddings over the corpus (rank space).

    ``num_shards`` > 1 runs the paper's distributed regime: the corpus is
    split across shard replicas — a leading axis of the stacked embedding
    matrices, trained together inside the jitted chunk — and replicas
    exchange hotness-block synchronizations every ``cfg.sync_period``
    lifetimes (Improvement-III). Returns (phi_in, phi_out) in RANK space
    (row 0 = hottest node); use ``order.to_rank`` to map ids.
    """
    from repro.core import sync as sync_mod

    n = len(order.to_rank)
    walks_rank = order.relabel_walks(corpus.walks)
    neg_table = build_alias_table(order.sorted_ocn, cfg.neg_power)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    keys = jax.random.split(key, num_shards + 1)
    key = keys[0]
    replicas = [init_embeddings(n, cfg.dim, k) for k in keys[1:]]
    phi_in = jnp.stack([r[0] for r in replicas])       # (S, N, d)
    phi_out = jnp.stack([r[1] for r in replicas])

    shard_walks = [walks_rank[s::num_shards] for s in range(num_shards)]
    starts, ends = order.hotness_blocks()
    metrics = {"loss": [], "sync_bytes": 0.0, "steps": 0}
    do_sync = num_shards > 1
    chunk = max(cfg.sync_period, 1)

    for epoch in range(cfg.epochs):
        batches = [
            _group_walks(sw, cfg.multi_windows, cfg.batch_groups, rng)
            for sw in shard_walks
        ]
        n_steps = min(b.shape[0] for b in batches)
        stacked = np.stack([b[:n_steps] for b in batches], axis=1)
        total = max(cfg.epochs * n_steps, 1)
        for c0 in range(0, n_steps, chunk):
            c1 = min(c0 + chunk, n_steps)
            fracs = (epoch * n_steps + np.arange(c0, c1)) / total
            lrs = jnp.asarray(
                np.maximum(cfg.lr * (1.0 - fracs), cfg.min_lr), jnp.float32)
            wb = jnp.asarray(stacked[c0:c1])           # ONE H2D per chunk
            rows = (jnp.asarray(
                sync_mod.sample_hotness_rows(starts, ends, rng), jnp.int32)
                if do_sync else jnp.zeros(0, jnp.int32))
            key, sub = jax.random.split(key)
            phi_in, phi_out, losses = train_chunk(
                phi_in, phi_out, wb, neg_table, rows, sub, lrs,
                cfg.window, cfg.negatives, cfg.use_kernel, do_sync)
            metrics["steps"] += c1 - c0
            if do_sync:
                metrics["sync_bytes"] += float(
                    rows.size * cfg.dim * 4 * num_shards * 2)
            if collect_metrics:
                metrics["loss"].extend(
                    float(v) for v in np.asarray(losses).reshape(-1))

    if num_shards > 1:
        phi_in, phi_out = jnp.mean(phi_in, axis=0), jnp.mean(phi_out, axis=0)
    else:
        phi_in, phi_out = phi_in[0], phi_out[0]

    if collect_metrics:
        return phi_in, phi_out, metrics
    return phi_in, phi_out
