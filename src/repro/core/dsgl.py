"""DSGL — distributed Skip-Gram learning (paper §4).

Improvement-I  (global matrices + local buffers): the embedding matrices are
laid out in descending corpus frequency (``FrequencyOrder``); each training
*lifetime* gathers the rows it will touch into local buffers, performs every
update there, and writes the deltas back once at the end. On TPU the buffers
live in VMEM (see ``repro.kernels.sgns``); this module is the pure-JAX
reference with identical semantics.

Improvement-II (multi-window shared negatives): ``multi_windows`` walks are
trained together per lane; their context windows share one negative-sample
set per position, and each walk's target acts as an extra negative for the
other walks — turning K+1 dot products into one (W·2w) x (W+K) level-3
matmul per position (MXU-shaped).

Improvement-III (hotness-block synchronization) lives in
``repro.core.sync`` and is driven from ``train_dsgl``.

Race semantics: as in the paper (Hogwild heritage), duplicate rows inside a
lifetime and across shards are updated without locks; deltas are
scatter-added on write-back.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.corpus import Corpus, FrequencyOrder


@dataclasses.dataclass(frozen=True)
class DSGLConfig:
    dim: int = 128
    window: int = 10            # w — context half-width
    negatives: int = 5          # K — shared negative samples per position
    multi_windows: int = 2      # W — walks trained together per lane
    batch_groups: int = 64      # G — lanes per jit step
    epochs: int = 1
    lr: float = 0.025
    min_lr: float = 1e-4
    neg_power: float = 0.75     # unigram^0.75 negative-sampling distribution
    sync_period: int = 50       # lifetimes between hotness syncs
    seed: int = 0
    use_kernel: bool = False    # route the inner update through Pallas sgns


def init_embeddings(
    num_nodes: int, dim: int, key: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """word2vec convention: phi_in ~ U(-0.5/d, 0.5/d), phi_out = 0."""
    phi_in = (jax.random.uniform(key, (num_nodes, dim), jnp.float32) - 0.5) / dim
    phi_out = jnp.zeros((num_nodes, dim), jnp.float32)
    return phi_in, phi_out


def negative_table(ocn_sorted: np.ndarray, power: float) -> np.ndarray:
    """Cumulative unigram^power distribution over frequency ranks."""
    w = np.asarray(ocn_sorted, dtype=np.float64) ** power
    if w.sum() == 0:
        w = np.ones_like(w)
    cdf = np.cumsum(w / w.sum())
    cdf[-1] = 1.0
    return cdf


def sample_negatives(
    cdf: np.ndarray, shape: Tuple[int, ...], rng: np.random.Generator
) -> np.ndarray:
    u = rng.random(shape)
    return np.searchsorted(cdf, u).astype(np.int32)


# ---------------------------------------------------------------------------
# One lifetime: W walks x T positions, local-buffer semantics.
# The math lives in repro.kernels.sgns: ref.py is the pure-jnp oracle and
# kernel.py the fused Pallas version; both share one source of truth.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("window", "use_kernel"),
                   donate_argnums=(0, 1))
def lifetime_step(
    phi_in: jax.Array,        # (N, d)
    phi_out: jax.Array,       # (N, d)
    walks: jax.Array,         # (G, W, T) int32 rank ids, -1 padded
    negs: jax.Array,          # (G, T, K) int32 rank ids
    lr: jax.Array,            # () f32
    window: int,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Process G lifetimes: gather buffers -> scan -> write back deltas."""
    g_cnt, w_cnt, t_len = walks.shape
    safe_walks = jnp.maximum(walks, 0)
    valid = walks >= 0

    ctx_buf0 = phi_in[safe_walks]                          # (G, W, T, d)
    out_buf0 = phi_out[safe_walks]                         # (G, W, T, d)
    neg_buf0 = phi_out[negs]                               # (G, T, K, d)

    if use_kernel:
        from repro.kernels.sgns import ops as sgns_ops
        ctx_buf, out_buf, neg_buf, loss = sgns_ops.sgns_lifetime_batch(
            ctx_buf0, out_buf0, neg_buf0, valid, lr, window
        )
    else:
        from repro.kernels.sgns import ref as sgns_ref
        ctx_buf, out_buf, neg_buf, loss = sgns_ref.sgns_lifetime_batch_ref(
            ctx_buf0, out_buf0, neg_buf0, valid, lr, window
        )

    # Write-back: duplicate buffer rows of the same embedding row (hub nodes
    # appear in many walks of one batch — power-law!) are AVERAGED, not
    # summed. Summing multiplies a hot row's step by its duplicate count and
    # diverges exponentially; averaging is the parallel-SGD semantics of the
    # paper's racy cross-thread write-back, and is stable.
    n_rows = phi_in.shape[0]
    flat_ids = safe_walks.reshape(-1)
    d_in = (ctx_buf - ctx_buf0).reshape(flat_ids.shape[0], -1)
    d_out = (out_buf - out_buf0).reshape(flat_ids.shape[0], -1)
    mask = valid.reshape(-1)
    neg_ids = negs.reshape(-1)
    d_neg = (neg_buf - neg_buf0).reshape(neg_ids.shape[0], -1)

    def scatter_mean(base, ids, deltas, m):
        ones = jnp.where(m, 1.0, 0.0)
        cnt = jnp.zeros((n_rows,), jnp.float32).at[ids].add(ones)
        summed = jnp.zeros_like(base).at[ids].add(
            jnp.where(m[:, None], deltas, 0.0)
        )
        return base + summed / jnp.maximum(cnt, 1.0)[:, None]

    phi_in = scatter_mean(phi_in, flat_ids, d_in, mask)
    # phi_out receives deltas from both walk-token rows and negative rows;
    # average across the union so a hot node's total step stays bounded.
    out_ids = jnp.concatenate([flat_ids, neg_ids])
    out_deltas = jnp.concatenate([d_out, d_neg], axis=0)
    out_mask = jnp.concatenate([mask, jnp.ones_like(neg_ids, bool)])
    phi_out = scatter_mean(phi_out, out_ids, out_deltas, out_mask)
    return phi_in, phi_out, jnp.sum(loss)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _group_walks(
    walks: np.ndarray, w_cnt: int, g_cnt: int, rng: np.random.Generator
) -> np.ndarray:
    """Shuffle walks and pack into (num_steps, G, W, T) batches (drop tail)."""
    order = rng.permutation(walks.shape[0])
    per_step = g_cnt * w_cnt
    n_steps = len(order) // per_step
    if n_steps == 0:  # small corpora: pad by repetition
        reps = -(-per_step // max(len(order), 1))
        order = np.tile(order, reps)[:per_step]
        n_steps = 1
    order = order[: n_steps * per_step]
    return walks[order].reshape(n_steps, g_cnt, w_cnt, walks.shape[1])


def train_dsgl(
    corpus: Corpus,
    order: FrequencyOrder,
    cfg: DSGLConfig,
    *,
    num_shards: int = 1,
    collect_metrics: bool = False,
):
    """Train Skip-Gram embeddings over the corpus (rank space).

    ``num_shards`` > 1 runs the paper's distributed regime: the corpus is
    split across shard replicas, each trains locally, and replicas exchange
    hotness-block synchronizations every ``cfg.sync_period`` lifetimes
    (Improvement-III, ``repro.core.sync``). Returns (phi_in, phi_out) in
    RANK space (row 0 = hottest node); use ``order.to_rank`` to map ids.
    """
    from repro.core import sync as sync_mod

    n = len(order.to_rank)
    walks_rank = order.relabel_walks(corpus.walks)
    cdf = negative_table(order.sorted_ocn, cfg.neg_power)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    # Per-shard replicas (num_shards == 1 -> plain single training).
    replicas = []
    for s in range(num_shards):
        key, k = jax.random.split(key)
        replicas.append(init_embeddings(n, cfg.dim, k))

    shard_walks = [walks_rank[s::num_shards] for s in range(num_shards)]
    starts, ends = order.hotness_blocks()
    metrics = {"loss": [], "sync_bytes": 0.0, "steps": 0}

    t_len = walks_rank.shape[1]
    for epoch in range(cfg.epochs):
        batches = [
            _group_walks(sw, cfg.multi_windows, cfg.batch_groups, rng)
            for sw in shard_walks
        ]
        n_steps = min(b.shape[0] for b in batches)
        total = max(cfg.epochs * n_steps, 1)
        for step in range(n_steps):
            frac = (epoch * n_steps + step) / total
            lr = jnp.float32(max(cfg.lr * (1 - frac), cfg.min_lr))
            for s in range(num_shards):
                phi_in, phi_out = replicas[s]
                wb = jnp.asarray(batches[s][step])
                neg = jnp.asarray(
                    sample_negatives(cdf, (cfg.batch_groups, t_len, cfg.negatives), rng)
                )
                phi_in, phi_out, loss = lifetime_step(
                    phi_in, phi_out, wb, neg, lr, cfg.window, cfg.use_kernel
                )
                replicas[s] = (phi_in, phi_out)
                if collect_metrics:
                    metrics["loss"].append(float(loss))
            metrics["steps"] += 1
            if num_shards > 1 and (step + 1) % cfg.sync_period == 0:
                replicas, nbytes = sync_mod.hotness_block_sync(
                    replicas, starts, ends, rng
                )
                metrics["sync_bytes"] += nbytes

    if num_shards > 1:
        replicas, nbytes = sync_mod.hotness_block_sync(replicas, starts, ends, rng)
        metrics["sync_bytes"] += nbytes
        phi_in = jnp.mean(jnp.stack([r[0] for r in replicas]), axis=0)
        phi_out = jnp.mean(jnp.stack([r[1] for r in replicas]), axis=0)
    else:
        phi_in, phi_out = replicas[0]

    if collect_metrics:
        return phi_in, phi_out, metrics
    return phi_in, phi_out
