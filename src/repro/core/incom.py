"""InCoM — incremental information-centric computing (paper §3.1).

The walker's information state is ten scalars (exactly the constant-size
message of Example 1): ``[walker_id, steps, node_id, H, L, E(H), E(L),
E(HL), E(H^2), E(L^2)]``. This module implements, fully vectorized over a
batch of walkers:

* Theorem 1 / Eq. 8 — O(1) incremental entropy update,
* Eq. 13 — O(1) incremental running means / cross-moment
  (with the cross-moment erratum fix documented in ``repro.core.info``),
* Eq. 12 — R(H, L) from the running expectations.

``n(v)`` (occurrences of the accepted node in the ongoing walk) is obtained
by a masked-lane count over the walker's fixed-length path buffer — the
TPU-native replacement for the paper's machine-local frequency list (see
DESIGN.md §2): one VPU op, no divergent hashing.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

LOG2 = jnp.log(jnp.float32(2.0))

# Message layout (floats) for the constant-size InCoM cross-shard message.
MSG_FIELDS = (
    "walker_id", "steps", "node_id", "H", "L",
    "EH", "EL", "EHL", "EH2", "EL2",
)
MSG_WIDTH = len(MSG_FIELDS)          # 10 fields
MSG_BYTES = 8 * MSG_WIDTH            # 80 bytes (Example 1)


def fullpath_msg_bytes(walk_len: jax.Array) -> jax.Array:
    """HuGE-D message size: 24 + 8L bytes (Example 1)."""
    return 24 + 8 * walk_len


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class InfoState:
    """Per-walker incremental information state (all shape (B,), float32).

    ``L`` is the current walk length (number of nodes, source included).
    The running expectations are over the series {(L_i, H_i)}_{i=1..L},
    seeded with the initial point (L=1, H=0).
    """

    H: jax.Array
    L: jax.Array
    EH: jax.Array
    EL: jax.Array
    EHL: jax.Array
    EH2: jax.Array
    EL2: jax.Array

    def tree_flatten(self):
        return (self.H, self.L, self.EH, self.EL, self.EHL, self.EH2, self.EL2), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def init(cls, batch: int) -> "InfoState":
        z = jnp.zeros((batch,), jnp.float32)
        one = jnp.ones((batch,), jnp.float32)
        # Seed with the first series point (L=1, H=0).
        return cls(H=z, L=one, EH=z, EL=one, EHL=z, EH2=z, EL2=one)


def _xlogx(x: jax.Array) -> jax.Array:
    """x * log2(x) with the 0*log(0) = 0 convention."""
    safe = jnp.where(x > 0, x, 1.0)
    return jnp.where(x > 0, x * jnp.log2(safe), 0.0)


def entropy_step(H: jax.Array, L: jax.Array, n_v: jax.Array) -> jax.Array:
    """Theorem 1: H(W^{L+1}) from H(W^L), L, and n(v) of the accepted node.

        H^{L+1} = (H^L * L - log2 T) / (L + 1)
        log2 T  = L log2 L - (L+1) log2 (L+1) + (n+1) log2 (n+1) - n log2 n

    (The two cases of Theorem 1 collapse into one formula since n=0 gives
    the v-not-in-walk branch with 0*log 0 = 0.)
    """
    n = n_v.astype(jnp.float32)
    log_t = _xlogx(L) - _xlogx(L + 1.0) + _xlogx(n + 1.0) - _xlogx(n)
    return (H * L - log_t) / (L + 1.0)


def stats_step(
    s: InfoState, h_new: jax.Array, l_new: jax.Array, reg_start: int = 1
) -> InfoState:
    """Eq. 13 running updates with the new series point (l_new, h_new).

    ``reg_start`` = L0 >= 1 starts the regression series at length L0,
    skipping the universal early log-transient of the entropy curve (see
    DESIGN.md §8): p = l_new - L0 + 1 points so far. reg_start=1 is the
    paper-literal full series (p = l_new). While l_new <= L0 the stats are
    re-seeded with the current point (weight-0 history) — still O(1)/step
    and still exactly the paper's 10-field constant-size message.
    """
    p = jnp.maximum(l_new - jnp.float32(reg_start) + 1.0, 1.0)
    w_prev = (p - 1.0) / p
    return InfoState(
        H=h_new,
        L=l_new,
        EH=w_prev * s.EH + h_new / p,
        EL=w_prev * s.EL + l_new / p,
        # Correct running cross/raw second moments (see info.py erratum note).
        EHL=(w_prev * s.EHL) + (h_new * l_new) / p,
        EH2=(w_prev * s.EH2) + (h_new * h_new) / p,
        EL2=(w_prev * s.EL2) + (l_new * l_new) / p,
    )


def r_squared(s: InfoState, eps: float = 1e-12) -> jax.Array:
    """Eq. 12: R^2(H, L) from the running expectations (vectorized)."""
    cov = s.EHL - s.EH * s.EL
    vh = jnp.maximum(s.EH2 - s.EH * s.EH, 0.0)
    vl = jnp.maximum(s.EL2 - s.EL * s.EL, 0.0)
    denom = vh * vl
    r2 = jnp.where(denom > eps, (cov * cov) / jnp.maximum(denom, eps), 0.0)
    return r2


def windowed_r_squared(
    hring: jax.Array, L: jax.Array, window: int, eps: float = 1e-12
) -> jax.Array:
    """R^2(H, L) over the LAST ``window`` series points, from a ring buffer.

    ``hring`` is (B, K): slot (s-1) mod K holds H(W^s). The windowed variant
    measures *recent* H-vs-L linearity, i.e. actual convergence of the
    entropy series. See DESIGN.md §8: the paper-literal full-series Pearson
    from L=1 is dominated by the early log-shaped segment (r^2 <= ~0.93 for
    any walk by L=8), so mu = 0.995 degenerates to fixed min-length walks;
    the windowed form reproduces HuGE's reported adaptive lengths while
    keeping O(1)/step updates and constant-size messages (80 B + 4K B ring).
    """
    b, k = hring.shape
    offs = jnp.arange(k, dtype=jnp.float32)[None, :]          # 0..K-1
    l_pts = L[:, None] - offs                                  # L, L-1, ...
    valid = (l_pts >= 1.0) & (offs < jnp.float32(window))
    slot = jnp.mod(l_pts.astype(jnp.int32) - 1, k)
    h_pts = jnp.take_along_axis(hring, jnp.clip(slot, 0, k - 1), axis=1)
    w = valid.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(w, -1), 1.0)
    eh = jnp.sum(h_pts * w, -1) / cnt
    el = jnp.sum(l_pts * w, -1) / cnt
    ehl = jnp.sum(h_pts * l_pts * w, -1) / cnt
    eh2 = jnp.sum(h_pts * h_pts * w, -1) / cnt
    el2 = jnp.sum(l_pts * l_pts * w, -1) / cnt
    cov = ehl - eh * el
    vh = jnp.maximum(eh2 - eh * eh, 0.0)
    vl = jnp.maximum(el2 - el * el, 0.0)
    denom = vh * vl
    return jnp.where(denom > eps, cov * cov / jnp.maximum(denom, eps), 0.0)


def count_in_path(path: jax.Array, length: jax.Array, v: jax.Array) -> jax.Array:
    """n(v): occurrences of v among the first ``length`` entries of ``path``.

    path: (B, max_len) int32, padded with -1; length: (B,); v: (B,).
    One masked compare+sum over lanes — the local-frequency-list analogue.
    """
    max_len = path.shape[-1]
    pos = jnp.arange(max_len, dtype=jnp.int32)[None, :]
    mask = pos < length[:, None]
    hit = (path == v[:, None]) & mask
    return jnp.sum(hit, axis=-1).astype(jnp.int32)


def accept_update(
    s: InfoState,
    path: jax.Array,
    v: jax.Array,
    reg_start: int = 1,
    mask: jax.Array = None,
) -> Tuple[InfoState, jax.Array]:
    """Apply one accepted step: compute n(v), H^{L+1}, running stats, and the
    appended path. Returns (new_state, new_path). ``mask`` (B,) restricts
    the path append to those lanes — callers that would otherwise re-select
    the whole (B, max_len) buffer afterwards fold their lane mask into the
    append's one-hot instead (one wide op, not two)."""
    n_v = count_in_path(path, s.L.astype(jnp.int32), v)
    h_new = entropy_step(s.H, s.L, n_v)
    l_new = s.L + 1.0
    s_new = stats_step(s, h_new, l_new, reg_start)
    idx = s.L.astype(jnp.int32)  # append position == old length
    # One-hot select instead of a scatter: a batched scatter lowers to a
    # serial per-entry while-loop on XLA CPU (~0.3 us/lane/step inside the
    # walk engines); the (B, max_len) select vectorizes. Appends past the
    # buffer (idx == max_len) write nothing, matching the scatter's
    # out-of-bounds drop.
    pos = jnp.arange(path.shape[1], dtype=jnp.int32)[None, :]
    hit = pos == idx[:, None]
    if mask is not None:
        hit = hit & mask[:, None]
    path_new = jnp.where(hit, v[:, None], path)
    return s_new, path_new


def paths_traverse_edges(
    paths: jax.Array, edge_codes: jax.Array, num_nodes: int
) -> jax.Array:
    """Which recorded walks traverse any of a set of (changed) arcs.

    paths:      (B, max_len) int32, -1 padded walk buffers (the corpus).
    edge_codes: (m,) SORTED row-major arc codes u * num_nodes + v
                (callers encode both directions of an undirected edge).

    Returns (B,) bool. This is the corpus half of the paper's incremental
    InCoM computation: whether a stored walk is invalidated by edge churn
    is recovered from the recorded path buffers with one vectorized
    consecutive-pair membership test — no walk is re-simulated to find
    out. Requires num_nodes^2 < 2^31 (int32 codes; the driver in
    ``repro.core.incremental`` falls back to a host int64 path beyond).
    """
    a, b_ = paths[:, :-1], paths[:, 1:]
    valid = (a >= 0) & (b_ >= 0)
    code = (jnp.maximum(a, 0) * jnp.int32(num_nodes)
            + jnp.maximum(b_, 0)).astype(jnp.int32)
    m = edge_codes.shape[0]
    if m == 0:
        return jnp.zeros(paths.shape[0], bool)
    pos = jnp.searchsorted(edge_codes, code.reshape(-1))
    hit = edge_codes[jnp.clip(pos, 0, m - 1)] == code.reshape(-1)
    hit = hit.reshape(code.shape) & valid
    return jnp.any(hit, axis=1)


def paths_visit_nodes(paths: jax.Array, node_mask: jax.Array) -> jax.Array:
    """Which recorded walks visit any marked node. node_mask: (|V|,) bool.

    The conservative ("paranoid") affected-walk criterion: a walk whose
    every visited node lies outside the closed neighborhood of the churn
    is PROVABLY bit-identical on the mutated graph (its candidate draws
    and acceptance inputs are all untouched), so marking visits to that
    neighborhood gives exact kept-walk invariance at the cost of a larger
    re-walk set.
    """
    hit = node_mask[jnp.maximum(paths, 0)] & (paths >= 0)
    return jnp.any(hit, axis=1)


def pack_message(walker_id: jax.Array, node_id: jax.Array, s: InfoState) -> jax.Array:
    """Constant-size (B, 10) float32 message — the Example 1 payload."""
    return jnp.stack(
        [
            walker_id.astype(jnp.float32),
            s.L,  # steps
            node_id.astype(jnp.float32),
            s.H, s.L, s.EH, s.EL, s.EHL, s.EH2, s.EL2,
        ],
        axis=-1,
    )


def unpack_message(msg: jax.Array) -> Tuple[jax.Array, jax.Array, InfoState]:
    walker_id = msg[..., 0].astype(jnp.int32)
    node_id = msg[..., 2].astype(jnp.int32)
    s = InfoState(
        H=msg[..., 3], L=msg[..., 4], EH=msg[..., 5], EL=msg[..., 6],
        EHL=msg[..., 7], EH2=msg[..., 8], EL2=msg[..., 9],
    )
    return walker_id, node_id, s
