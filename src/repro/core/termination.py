"""Walk-count controller (paper Eq. 6–7): how many walks per node.

After each round r (one walk from every source node), HuGE compares the
node-degree distribution p(v) against the corpus-occurrence distribution
q(v) via relative entropy D_r(p||q) and stops when
|D_r - D_{r-1}| <= delta (delta = 0.001 in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.info import relative_entropy_dpq


@dataclasses.dataclass
class WalkCountController:
    delta: float = 1e-3
    min_rounds: int = 2
    max_rounds: int = 20

    def __post_init__(self):
        self.history: List[float] = []

    def update(self, degrees: np.ndarray, ocn: np.ndarray) -> bool:
        """Record D_r for the corpus so far; return True if walking should
        CONTINUE (i.e. |Delta D_r| > delta or not enough rounds yet)."""
        return self.update_d(relative_entropy_dpq(degrees, ocn))

    def update_d(self, d_r: float) -> bool:
        """Decision half of ``update`` for callers that compute D_r
        themselves (e.g. the streaming pipeline, whose ocn lives on device
        and is pulled once per round for the alias/hotness rebuild anyway)."""
        self.history.append(float(d_r))
        r = len(self.history)
        if r < self.min_rounds:
            return True
        if r >= self.max_rounds:
            return False
        delta_d = abs(self.history[-1] - self.history[-2])
        return bool(delta_d > self.delta)

    @property
    def rounds(self) -> int:
        return len(self.history)
