"""Walk-count controller (paper Eq. 6–7): how many walks per node.

After each round r (one walk from every source node), HuGE compares the
node-degree distribution p(v) against the corpus-occurrence distribution
q(v) via relative entropy D_r(p||q) and stops when
|D_r - D_{r-1}| <= delta (delta = 0.001 in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.info import relative_entropy_dpq


@dataclasses.dataclass
class WalkCountController:
    """``window`` > 1 gates on the change of a WINDOWED MEAN of the D_r
    series instead of the raw round-to-round delta. At tight deltas
    (1e-4) on small graphs, the raw |D_r - D_{r-1}| sits inside the
    round-to-round sampling noise of the occurrence counts — one RNG
    stream converges in 8 rounds where another rides the noise to
    ``max_rounds``. Averaging the last ``window`` D values attenuates
    that noise ~``window``-fold (the smoothed delta is
    |D_r - D_{r-w}| / w for a flat-noise series) while leaving the
    macroscopic convergence trend untouched; ``window=1`` is the exact
    paper-literal Eq. 7 gate.

    ``seed_history`` warm-starts the gate from a PRIOR run's D_r series
    (the incremental-refresh posture: after edge churn, the refreshed
    corpus's D is judged against the converged pre-churn trajectory
    instead of cold-starting through ``min_rounds`` burn-in rounds —
    "seeded from prior-round InCoM state"). The windowed smoothing is
    replayed over the seed so the first post-churn delta compares like
    with like."""

    delta: float = 1e-3
    min_rounds: int = 2
    max_rounds: int = 20
    window: int = 1
    seed_history: Optional[List[float]] = None

    def __post_init__(self):
        self.history: List[float] = []
        self._smooth: List[float] = []
        if self.seed_history:
            w = max(self.window, 1)
            for d in self.seed_history:
                self.history.append(float(d))
                self._smooth.append(float(np.mean(self.history[-w:])))

    def update(self, degrees: np.ndarray, ocn: np.ndarray) -> bool:
        """Record D_r for the corpus so far; return True if walking should
        CONTINUE (i.e. |Delta D_r| > delta or not enough rounds yet)."""
        return self.update_d(relative_entropy_dpq(degrees, ocn))

    def update_d(self, d_r: float) -> bool:
        """Decision half of ``update`` for callers that compute D_r
        themselves (e.g. the streaming pipeline, whose ocn lives on device
        and is pulled once per round for the alias/hotness rebuild anyway)."""
        self.history.append(float(d_r))
        w = max(self.window, 1)
        self._smooth.append(float(np.mean(self.history[-w:])))
        r = len(self.history)
        if r < self.min_rounds:
            return True
        if r >= self.max_rounds:
            return False
        delta_d = abs(self._smooth[-1] - self._smooth[-2])
        return bool(delta_d > self.delta)

    @property
    def rounds(self) -> int:
        return len(self.history)

    # --- crash-consistent snapshot surface --------------------------------
    def to_state(self) -> dict:
        """JSON-serializable gate state for pipeline snapshots: config plus
        the full D_r history (the windowed smoothing is a pure function of
        the history, so it is replayed on restore rather than stored)."""
        return {
            "delta": float(self.delta),
            "min_rounds": int(self.min_rounds),
            "max_rounds": int(self.max_rounds),
            "window": int(self.window),
            "history": [float(d) for d in self.history],
        }

    @classmethod
    def from_state(cls, state: dict) -> "WalkCountController":
        """Rebuild a gate mid-trajectory. ``seed_history`` replay computes
        exactly the same ``_smooth`` series the live gate accumulated (the
        same windowed mean over the same history), so the first post-restore
        ``update_d`` decision is bit-identical to the uninterrupted run's."""
        return cls(
            delta=state["delta"], min_rounds=state["min_rounds"],
            max_rounds=state["max_rounds"], window=state["window"],
            seed_history=list(state["history"]))
