"""xlstm-350m — sLSTM + mLSTM recurrent LM [arXiv:2405.04517].

24 blocks  d_model=1024  4 heads  vocab=50304, d_ff=0 (xLSTM blocks carry
their own up/down projection; there is no separate FFN). Block cycle is the
paper's xLSTM[7:1] ratio: seven mLSTM ("x") then one sLSTM ("s").

Recurrent state decode => runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_cycle=("x", "x", "x", "x", "x", "x", "x", "s"),
    ssm_heads=4,
    ssm_expand=2,
    ssm_chunk=512,             # large matrix memory (512x513/head): fewer,
                               # bigger chunks cut inter-chunk state stash 4x
    dtype="bfloat16",
    remat="full",
    long_context="state",
    tie_embeddings=True,
    act_seq_shard=False,       # all-scan arch: SP resharding costs, no gain
                               # (EXPERIMENTS.md §Perf xlstm iteration 2)
)
