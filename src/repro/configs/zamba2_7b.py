"""zamba2-7b — hybrid Mamba2 + periodic attention blocks [arXiv:2411.15242].

81 blocks  d_model=3584  attn 32H (kv=32)  d_ff=14336  vocab=32000,
ssm_state=64. Block cycle: five Mamba2 mixers then one attention+MLP block
(13 attention positions over 81 blocks — Zamba2's ~1:6 ratio).

Adaptation note (DESIGN.md §5): Zamba2 re-USES one shared attention block's
weights at every attention position; we instantiate per-position attention
weights instead (the scan-over-cycles layout keeps HLO size identical; the
difference is parameter count only, ~0.6B, and is recorded here).

Sub-quadratic decode state => runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    block_cycle=("m", "m", "m", "m", "m", "a"),
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,       # d_in = 7168 -> 112 SSD heads
    ssm_conv=4,
    rope_theta=1.0e4,
    dtype="bfloat16",
    remat="full",
    long_context="state",
    act_seq_shard=False,   # 68/81 blocks are scans: SP resharding costs
                           # 4.5 TB/device, no benefit (§Perf zamba2 iter 2:
                           # 11.40 -> 8.40 s bound, frac 0.090 -> 0.122)
)
