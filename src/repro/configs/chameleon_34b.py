"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818].

48L  d_model=8192  64H (GQA kv=8)  d_ff=22016  vocab=65536 (text + VQ image
codes in ONE vocabulary — early fusion means images are just tokens).
Chameleon's training-stability recipe includes qk-norm, kept here.

Frontend stub: the VQ-GAN tokenizer is out of scope; ``vq_token_stream``
(repro.models.frontend) emits interleaved text+image-code ids for smoke
tests, and the dry-run inputs are ordinary (B, S) token ids.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    frontend="vision",
    rope_theta=1.0e4,
    dtype="bfloat16",
    remat="full",
    fsdp=True,
    grad_accum=4,
)
