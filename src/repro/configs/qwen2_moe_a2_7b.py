"""qwen2-moe-a2.7b — fine-grained MoE, 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L  d_model=2048  16H (kv=16)  vocab=151936.  moe_d_ff=1408 per routed
expert; the shared expert is ONE MLP of width 4x1408=5632
(HF shared_expert_intermediate_size), running on every token.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,              # dense width (unused: all layers are MoE)
    vocab_size=151936,
    moe=True,
    n_routed_experts=60,
    n_shared_experts=4,     # -> one shared MLP of width 4 * moe_d_ff
    top_k=4,
    moe_d_ff=1408,
    first_dense_layers=0,
    rope_theta=1.0e6,
    dtype="bfloat16",
    remat="full",
    fsdp=True,                  # 14.3B total params: shard opt state (ZeRO)
)
