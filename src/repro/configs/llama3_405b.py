"""llama3-405b — frontier-scale dense GQA LM [arXiv:2407.21783].

126L  d_model=16384  128H (GQA kv=8)  d_ff=53248  vocab=128256,
head_dim=128, rope_theta=5e5.

Distribution posture (DESIGN.md §4): FSDP over "data" on top of TP over
"model" (ZeRO-3 x tensor parallel), full activation remat, bf16 optimizer
moments — the 405B-class memory recipe. The pipeline-parallel alternative
(repro.dist.pipeline) is exercised by tests; TP+FSDP is the dry-run default.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5.0e5,
    dtype="bfloat16",
    remat="full",
    fsdp=True,
    opt_state_dtype="bfloat16",
    grad_accum=8,              # §Perf llama3 iteration: per-microbatch f32
                               # weight-grad all-reduces dominate (13 GB x
                               # layers x microbatches); 8 halves them vs 16
                               # and the residual stash still fits 16 GB HBM
                               # (analytic 14.8 GB/device; accum=4 would need
                               # a 24 GB-HBM part for another 1.9x)
    grad_accum_dtype="bfloat16",
)
