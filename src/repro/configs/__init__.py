"""Architecture registry: one module per assigned arch + the paper's own
DistGER workload. ``get_config(name)`` returns the full published config;
``get_reduced(name)`` the CPU-smoke version (same family, tiny dims)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig, shape_applicable

ARCH_IDS: List[str] = [
    "yi_6b",
    "qwen3_1_7b",
    "minicpm3_4b",
    "llama3_405b",
    "zamba2_7b",
    "qwen2_moe_a2_7b",
    "deepseek_v2_lite_16b",
    "seamless_m4t_large_v2",
    "chameleon_34b",
    "xlstm_350m",
]

# canonical external ids (grid spelling) -> module names
ALIASES: Dict[str, str] = {
    "yi-6b": "yi_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "minicpm3-4b": "minicpm3_4b",
    "llama3-405b": "llama3_405b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "chameleon-34b": "chameleon_34b",
    "xlstm-350m": "xlstm_350m",
}


def normalize(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    from repro.models.zoo import reduce_config
    return reduce_config(get_config(name))


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def grid_cells():
    """Every (arch, shape) cell, with applicability resolved."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch, sname, ok, why))
    return cells
