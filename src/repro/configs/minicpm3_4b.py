"""minicpm3-4b — dense LM with Multi-head Latent Attention
[hf:openbmb/MiniCPM3-4B].

62L  d_model=2560  40H  d_ff=6400  vocab=73448.  MLA dims from the HF
config: q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
qk_rope_head_dim=32, v_head_dim=64 (the grid line's "kv=40" denotes MLA:
every head derives K/V from the shared 256-d latent, so there is no
separate KV-head count).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73456,          # 73448 padded to a multiple of 16 for TP
    vocab_size_unpadded=73448,
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=1.0e4,
    dtype="bfloat16",
    remat="full",
    tie_embeddings=True,
)
