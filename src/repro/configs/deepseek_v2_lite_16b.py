"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434;
hf:deepseek-ai/DeepSeek-V2-Lite].

27L  d_model=2048  16H  vocab=102400.  MLA: kv_lora_rank=512,
qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128, NO q
compression (q_lora_rank=0 in the Lite model). MoE: 64 routed + 2 shared
experts, top-6, moe_d_ff=1408, first layer dense (d_ff=10944).

The grid line says "160 routed top-6" in prose but "64e" in its own tag;
we follow the HF config (64 routed), as recorded in DESIGN.md §5.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,             # the single leading dense layer's width
    vocab_size=102400,
    use_mla=True,
    q_lora_rank=0,          # Lite: direct q projection
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=True,
    n_routed_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=1.0e4,
    dtype="bfloat16",
    remat="full",
    fsdp=True,                  # 15.7B total params: shard opt state (ZeRO)
)
