"""The paper's own workload: DistGER graph-embedding runs (§6.1 parameters).

mu=0.995, delta=0.001, dim=128, window=10, K=5 negatives, multi_windows=2,
gamma=2 (MPGP slack), sync period per §6.1. Graph presets mirror the paper's
table-2 datasets at R-MAT-synthetic scale knobs (the real FL/YT/LJ/OR/TW
downloads are not bundled; generators reproduce their |V|, avg-degree).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.api import EmbedConfig


PAPER_EMBED = EmbedConfig(
    method="huge",
    info_termination=True,
    mu=0.995,
    delta=1e-3,
    dim=128,
    window=10,
    negatives=5,
    multi_windows=2,
    lr=0.025,
    epochs=1,
)

ROUTINE_EMBED = dataclasses.replace(
    PAPER_EMBED, method="deepwalk", info_termination=False,
    fixed_len=80, fixed_rounds=10,
)

MPGP_GAMMA = 2.0      # §8.3: minimum average random-walk time at gamma=2


@dataclasses.dataclass(frozen=True)
class GraphPreset:
    name: str
    num_nodes: int
    avg_degree: int


# R-MAT stand-ins scaled after Table 2 (|V|, avg deg = 2|E|/|V|).
GRAPH_PRESETS: Dict[str, GraphPreset] = {
    "fl-sim": GraphPreset("fl-sim", 80_513, 146),
    "yt-sim": GraphPreset("yt-sim", 1_138_499, 5),
    "lj-sim": GraphPreset("lj-sim", 2_238_731, 13),
    "or-sim": GraphPreset("or-sim", 3_072_441, 76),
    "tw-sim": GraphPreset("tw-sim", 41_652_230, 70),
    # CPU-feasible smoke presets
    "small": GraphPreset("small", 2_000, 10),
    "medium": GraphPreset("medium", 50_000, 10),
}
