"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

24 encoder + 24 decoder layers, d_model=1024, 16H (kv=16), d_ff=8192,
vocab=256206. The audio frontend (w2v-BERT conformer stem) is a STUB per
the grid rules: ``input_specs`` provides precomputed (B, S_src, 1024)
frame embeddings (repro.models.frontend).

Shape-cell semantics: train/prefill cells split seq_len as
S_src = S_tgt = seq_len // 2; decode cells keep the decoder self-KV at
seq_len with a 4096-frame encoder memory (models/zoo.py CROSS_SRC_LEN).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=48,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256208,         # 256206 padded to a multiple of 16 for TP
    vocab_size_unpadded=256206,
    encdec=True,
    enc_layers=24,
    dec_layers=24,
    frontend="audio",
    rope_theta=1.0e4,
    dtype="bfloat16",
    remat="full",
)
