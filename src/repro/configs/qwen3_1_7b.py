"""qwen3-1.7b — dense GQA with per-head qk RMS-norm [hf:Qwen/Qwen3-1.7B].

28L  d_model=2048  16H (GQA kv=8)  d_ff=6144  vocab=151936, head_dim=128,
qk_norm (the Qwen3-family signature), rope_theta=1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1.0e6,
    dtype="bfloat16",
    remat="full",
    tie_embeddings=True,   # Qwen3 <8B ties lm_head to the embedding
)
