"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with true recurrence) — the "x" and "s" entries of block_cycle.

mLSTM maps exactly onto the SSD scan (DESIGN.md §2): with key k_t, value
v_t, query q_t and gates i_t (input) / f_t (forget),

    C_t = f_t C_{t-1} + i_t v_t k_t^T      == SSD with loga = log f,
    n_t = f_t n_{t-1} + i_t k_t               xdt = [i*v ‖ i], B = k, C = q
    y_t = (C_t q_t) / max(|n_t . q_t|, 1)

— the normalizer n rides along as one extra value channel (P+1), so the
same chunked/Pallas SSD kernel serves Mamba2 AND mLSTM.

sLSTM keeps per-unit scalar cells with *recurrent* gate connections
(R @ h_{t-1}); that recurrence is inherently sequential — lax.scan over
time, O(1)-state decode (this is why xlstm-350m runs long_500k).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


def _mdims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or cfg.num_heads
    p_dim = d_in // nh
    return d_in, nh, p_dim


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    d_in, nh, p_dim = _mdims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, d_in), dtype),
        "wk": dense_init(ks[1], (d, d_in), dtype),
        "wv": dense_init(ks[2], (d, d_in), dtype),
        "wi": dense_init(ks[3], (d, nh), jnp.float32),
        "wf": dense_init(ks[4], (d, nh), jnp.float32),
        "wo_gate": dense_init(ks[5], (d, d_in), dtype),
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": dense_init(jax.random.fold_in(key, 7), (d_in, d), dtype),
    }


def spec_mlstm(cfg: ModelConfig) -> Params:
    dax = "data" if cfg.fsdp else None
    return {
        "wq": P(dax, "model"), "wk": P(dax, "model"), "wv": P(dax, "model"),
        "wi": P(None, "model"), "wf": P(None, "model"),
        "wo_gate": P(dax, "model"),
        "norm": {"scale": P("model")},
        "out_proj": P("model", dax),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> jax.Array:
    d_in, nh, p_dim = _mdims(cfg)
    return jnp.zeros((batch, nh, p_dim, p_dim + 1), jnp.float32)


def spec_mlstm_state() -> P:
    return P(("pod", "data"), "model", None, None)


from repro.models.layers import named


@named("mlstm_mixer")
def mlstm_mixer(
    x: jax.Array, p: Params, cfg: ModelConfig,
    *, state: Optional[jax.Array] = None, return_state: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    bsz, s, d = x.shape
    d_in, nh, p_dim = _mdims(cfg)
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(bsz, s, nh, p_dim)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"]).reshape(bsz, s, nh, p_dim)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"]).reshape(bsz, s, nh, p_dim)
    k = k / (p_dim ** 0.5)
    i_gate = jnp.exp(-jax.nn.softplus(-jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"])))
    f_gate = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"]))
    loga = jnp.log(jnp.maximum(f_gate, 1e-6))                  # (B,S,nh)

    # values extended with the normalizer channel
    v_ext = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((bsz, s, nh, 1), jnp.float32)], -1
    ) * i_gate[..., None]

    bh = bsz * nh
    xdt = v_ext.swapaxes(1, 2).reshape(bh, s, p_dim + 1)
    loga_f = loga.swapaxes(1, 2).reshape(bh, s)
    b_f = k.astype(jnp.float32).swapaxes(1, 2).reshape(bh, s, p_dim)
    c_f = q.astype(jnp.float32).swapaxes(1, 2).reshape(bh, s, p_dim)

    from repro.kernels.ssm_scan import ref as ssm_ref
    new_state = None
    if state is None:
        y_ext, s_fin = ssm_ref.ssd_chunked_ref(xdt, loga_f, b_f, c_f,
                                               chunk=cfg.ssm_chunk)
        if return_state:
            new_state = s_fin.reshape(bsz, nh, p_dim, p_dim + 1)
    else:
        y_one, new_s = ssm_ref.ssd_decode_step(
            state.reshape(bh, p_dim, p_dim + 1),
            xdt[:, 0], loga_f[:, 0], b_f[:, 0], c_f[:, 0],
        )
        y_ext = y_one[:, None]
        new_state = new_s.reshape(bsz, nh, p_dim, p_dim + 1)

    y = y_ext[..., :p_dim] / jnp.maximum(jnp.abs(y_ext[..., -1:]), 1.0)
    y = y.reshape(bsz, nh, -1, p_dim).swapaxes(1, 2).reshape(bsz, -1, d_in)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", x, p["wo_gate"]))
    y = rmsnorm(y.astype(x.dtype) * o, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"]), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "w": dense_init(k1, (d, 4 * d), jnp.float32),    # z, i, f, o
        "r": dense_init(k2, (d, 4 * d), jnp.float32, scale=0.1),
        "b": jnp.zeros((4 * d,), jnp.float32),
    }


def spec_slstm(cfg: ModelConfig) -> Params:
    """sLSTM weights are REPLICATED over "model": the cell is a strict
    time-recurrence whose per-step state h feeds the next step's h @ R —
    any model-sharding of d turns that contraction into one all-reduce PER
    TIME STEP (measured: 24.6k all-reduces / 220 GB on xlstm train_4k,
    EXPERIMENTS.md §Perf iteration x3). Batch parallelism only; the cell is
    4d^2 ~ 17 MB of weights, replication is free."""
    dax = "data" if cfg.fsdp else None
    return {"w": P(dax, None), "r": P(None, None), "b": P(None)}


def init_slstm_state(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z}


def spec_slstm_state() -> Params:
    return {"c": P(("pod", "data"), "model"), "n": P(("pod", "data"), "model"),
            "h": P(("pod", "data"), "model")}


EPS = 1e-6


def _slstm_step(carry, wx_t, r):
    c, n, h = carry
    gates = wx_t + h @ r
    zp, ip, fp, op = jnp.split(gates, 4, axis=-1)
    z_t = jnp.tanh(zp)
    i_t = jax.nn.sigmoid(ip)       # exp(-softplus(-x)) == sigmoid(x)
    f_t = jax.nn.sigmoid(fp)
    o_t = jax.nn.sigmoid(op)
    c = f_t * c + i_t * z_t
    n = f_t * n + i_t
    h = o_t * c / jnp.maximum(n, EPS)
    return (c, n, h), (h, c, n)


@jax.custom_vjp
def _slstm_scan(wx_t_first, r, init):
    """wx_t_first: (S, B, 4d). Returns ((c,n,h), hs (S,B,d)).

    custom VJP so dR is ONE batched einsum over the stacked series instead
    of a per-time-step partial — autodiff through the scan emits one
    cross-batch all-reduce PER STEP for the recurrent-weight gradient
    (208 GB/device measured on xlstm train_4k; §Perf xlstm iteration 4)."""
    (c, n, h), (hs, cs, ns) = jax.lax.scan(
        lambda carry, wx_t: _slstm_step(carry, wx_t, r), init, wx_t_first)
    return (c, n, h), hs


def _slstm_fwd(wx, r, init):
    (c, n, h), (hs, cs, ns) = jax.lax.scan(
        lambda carry, wx_t: _slstm_step(carry, wx_t, r), init, wx)
    return ((c, n, h), hs), (wx, r, init, hs, cs, ns)


def _slstm_bwd(res, grads):
    wx, r, init, hs, cs, ns = res
    (dcT, dnT, dhT), dhs = grads
    c0, n0, h0 = init
    s = wx.shape[0]
    # previous-step series (t-1 values feeding step t)
    h_prev = jnp.concatenate([h0[None], hs[:-1]], axis=0)
    c_prev = jnp.concatenate([c0[None], cs[:-1]], axis=0)
    n_prev = jnp.concatenate([n0[None], ns[:-1]], axis=0)
    # recompute gate activations batched over time (cheap, local)
    pre = wx + jnp.einsum("sbd,dk->sbk", h_prev, r)
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zp)
    i = jax.nn.sigmoid(ip)
    f = jax.nn.sigmoid(fp)
    o = jax.nn.sigmoid(op)

    def back(carry, inp):
        dc, dn, dh = carry
        dh_out, z_t, i_t, f_t, o_t, c_t, n_t, cp, np_ = inp
        dh_t = dh + dh_out
        nmax = jnp.maximum(n_t, EPS)
        do = dh_t * c_t / nmax
        dc_t = dc + dh_t * o_t / nmax
        dn_t = dn - jnp.where(n_t > EPS,
                              dh_t * o_t * c_t / (nmax * nmax), 0.0)
        # c_t = f c_{t-1} + i z ;  n_t = f n_{t-1} + i
        df = dc_t * cp + dn_t * np_
        di = dc_t * z_t + dn_t
        dz = dc_t * i_t
        dpre = jnp.concatenate([
            dz * (1 - z_t * z_t),
            di * i_t * (1 - i_t),
            df * f_t * (1 - f_t),
            do * o_t * (1 - o_t),
        ], axis=-1)
        dh_prev = dpre @ r.T
        dc_prev = dc_t * f_t
        dn_prev = dn_t * f_t
        return (dc_prev, dn_prev, dh_prev), dpre

    (dc0, dn0, dh0), dpres = jax.lax.scan(
        back, (dcT, dnT, dhT),
        (dhs, z, i, f, o, cs, ns, c_prev, n_prev),
        reverse=True)
    # THE point: one local einsum + one all-reduce for dR
    dr = jnp.einsum("sbd,sbk->dk", h_prev, dpres)
    return dpres, dr, (dc0, dn0, dh0)


_slstm_scan.defvjp(_slstm_fwd, _slstm_bwd)


@named("slstm_mixer")
def slstm_mixer(
    x: jax.Array, p: Params, cfg: ModelConfig,
    *, state: Optional[Params] = None, return_state: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    bsz, s, d = x.shape
    wx = jnp.einsum("bsd,dk->bsk", x.astype(jnp.float32), p["w"]) + p["b"]
    # recurrent scan: keep per-step slices device-local (batch-sharded) —
    # sequence-sharded scan inputs are pathological (dist.context docstring)
    from repro.dist.context import constrain_scan_inputs
    wx = constrain_scan_inputs(wx, batch_dim=0)

    if state is None:
        init = (jnp.zeros((bsz, d)), jnp.full((bsz, d), 1e-6),
                jnp.zeros((bsz, d)))
    else:
        init = (state["c"], state["n"], state["h"])
    (c, n, h), hs = _slstm_scan(wx.swapaxes(0, 1), p["r"], init)
    y = hs.swapaxes(0, 1).astype(x.dtype)
    keep = (state is not None) or return_state
    new_state = {"c": c, "n": n, "h": h} if keep else None
    return y, new_state
