"""Mamba2 mixer block (scalar-identity A, SSD scan) — zamba2's "m" blocks.

Structure per block (faithful to Mamba2, n_groups=1):
  in_proj -> [z (gate), x, B, C, dt] ;  causal depthwise conv over [x,B,C] ;
  dt = softplus(dt + bias) ; loga = -exp(A_log) * dt (per head) ;
  y = SSD_scan(x*dt, loga, B, C) + D*x ;  y = RMSNorm(y * silu(z)) ;
  out_proj.

Scan impls: "chunked" (pure-jnp SSD, CPU/dry-run default), "kernel"
(Pallas), "ref" (sequential oracle). Decode keeps (conv_state, ssm_state)
and is O(1)/token — this is what makes long_500k runnable (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(d_in // max(cfg.ssm_head_dim, 1), 1)
    p_dim = d_in // nh
    return d_in, nh, p_dim, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    d_in, nh, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + nh), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def spec_mamba(cfg: ModelConfig) -> Params:
    dax = "data" if cfg.fsdp else None
    return {
        "in_proj": P(dax, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": {"scale": P("model")},
        "out_proj": P("model", dax),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    d_in, nh, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, n, p_dim), jnp.float32),
    }


def spec_mamba_state() -> Params:
    return {
        "conv": P(("pod", "data"), None, "model"),
        "ssm": P(("pod", "data"), "model", None, None),
    }


def _split_proj(z_all, d_in, n, nh):
    z, xc, b, c, dt = jnp.split(
        z_all, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    return z, xc, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


from repro.models.layers import named


@named("ssd_mixer")
def mamba_mixer(
    x: jax.Array,                 # (B, S, d)
    p: Params,
    cfg: ModelConfig,
    *,
    state: Optional[Params] = None,   # decode: (conv, ssm) running state
    return_state: bool = False,       # prefill: emit final state
) -> Tuple[jax.Array, Optional[Params]]:
    bsz, s, d = x.shape
    d_in, nh, p_dim, n = _dims(cfg)
    z_all = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xc, b, c, dt = _split_proj(z_all, d_in, n, nh)

    conv_in = jnp.concatenate([xc, b, c], axis=-1)           # (B,S,d_in+2N)
    new_state = None
    if state is None:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    else:
        # decode: roll the conv window
        window = jnp.concatenate([state["conv"], conv_in], axis=1)
        k = cfg.ssm_conv
        conv_out = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window[:, -k:], p["conv_w"])
            + p["conv_b"]
        )[:, None, :]
        new_conv = window[:, -(k - 1):]

    xs, bs, cs = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    loga = -jnp.exp(p["A_log"])[None, None, :] * dt               # (B,S,nh)

    xh = xs.reshape(bsz, -1, nh, p_dim)
    xdt = (xh.astype(jnp.float32) * dt[..., None])

    if state is None:
        # train / prefill: chunked SSD over heads
        bh = bsz * nh
        xdt_f = xdt.swapaxes(1, 2).reshape(bh, s, p_dim)
        loga_f = loga.swapaxes(1, 2).reshape(bh, s)
        b_f = jnp.broadcast_to(bs[:, None], (bsz, nh, s, n)).reshape(bh, s, n)
        c_f = jnp.broadcast_to(cs[:, None], (bsz, nh, s, n)).reshape(bh, s, n)
        from repro.kernels.ssm_scan import ref as ssm_ref
        y_f, s_fin = ssm_ref.ssd_chunked_ref(
            xdt_f.astype(jnp.float32), loga_f, b_f.astype(jnp.float32),
            c_f.astype(jnp.float32), chunk=cfg.ssm_chunk,
        )
        y = y_f.reshape(bsz, nh, s, p_dim).swapaxes(1, 2)         # (B,S,nh,P)
        if return_state:
            k = cfg.ssm_conv
            tail = conv_in[:, -(k - 1):]
            pad = k - 1 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_state = {"conv": tail,
                         "ssm": s_fin.reshape(bsz, nh, n, p_dim)}
    else:
        # decode: recurrent O(1) step (S == 1)
        from repro.kernels.ssm_scan import ref as ssm_ref
        bh = bsz * nh
        y_f, new_ssm = ssm_ref.ssd_decode_step(
            state["ssm"].reshape(bh, n, p_dim),
            xdt[:, 0].reshape(bh, p_dim),
            loga[:, 0].reshape(bh),
            jnp.broadcast_to(bs[:, 0, None], (bsz, nh, n)).reshape(bh, n),
            jnp.broadcast_to(cs[:, 0, None], (bsz, nh, n)).reshape(bh, n),
        )
        y = y_f.reshape(bsz, nh, p_dim)[:, None].reshape(bsz, 1, nh, p_dim)
        new_state = {"conv": new_conv,
                     "ssm": new_ssm.reshape(bsz, nh, n, p_dim)}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, -1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_state
