"""Encoder-decoder LM (seamless-m4t backbone).

Encoder: bidirectional attention blocks over precomputed frame embeddings
(the audio frontend is a STUB per the assignment — ``input_specs`` provides
(B, S_src, d_model) frames). Decoder: causal self-attention + cross-attention
to the encoder output + SwiGLU MLP. Serving caches: decoder self-KV plus
cross-KV computed once at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy_loss, dtype_of, embed, init_embedding, init_mlp,
    init_rmsnorm, mlp, rmsnorm, spec_embedding, spec_mlp, spec_rmsnorm,
    unembed,
)

Params = Dict[str, Any]


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": attn_mod.init_attention(k1, cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": attn_mod.init_attention(k2, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _enc_block_spec(cfg):
    return {
        "ln1": spec_rmsnorm(), "attn": attn_mod.spec_attention(cfg),
        "ln2": spec_rmsnorm(), "ffn": spec_mlp(cfg.fsdp),
    }


def _dec_block_spec(cfg):
    return {
        "ln1": spec_rmsnorm(), "self_attn": attn_mod.spec_attention(cfg),
        "ln_x": spec_rmsnorm(), "cross_attn": attn_mod.spec_attention(cfg),
        "ln2": spec_rmsnorm(), "ffn": spec_mlp(cfg.fsdp),
    }


def _stack(key, fn, n, cfg, dtype):
    reps = [fn(jax.random.fold_in(key, i), cfg, dtype) for i in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *reps)


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": init_embedding(k1, cfg.vocab_size, cfg.d_model, dtype,
                                cfg.tie_embeddings),
        "enc": _stack(k2, _enc_block_init, cfg.enc_layers, cfg, dtype),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "dec": _stack(k3, _dec_block_init, cfg.dec_layers, cfg, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }


def param_specs(cfg: ModelConfig) -> Params:
    lift = lambda tree: jax.tree_util.tree_map(
        lambda s: P(None, *s), tree, is_leaf=lambda s: isinstance(s, P))
    return {
        "embed": spec_embedding(cfg.tie_embeddings, cfg.fsdp),
        "enc": lift(_enc_block_spec(cfg)),
        "enc_norm": spec_rmsnorm(),
        "dec": lift(_dec_block_spec(cfg)),
        "final_norm": spec_rmsnorm(),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_src, d_model) precomputed frontend embeddings."""
    x = frames.astype(dtype_of(cfg.dtype))
    positions = jnp.arange(x.shape[1])

    def body(xx, p):
        from repro.dist.context import constrain_activations
        xx = constrain_activations(xx)
        h = rmsnorm(xx, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.attention(h, p["attn"], cfg, positions, causal=False)
        xx = xx + y
        h = rmsnorm(xx, p["ln2"], cfg.norm_eps)
        return xx + mlp(h, p["ffn"]), 0

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _decoder(params, cfg, x, enc_out, positions, caches=None, cache_len=None,
             mode="train"):
    def body(carry, xs):
        from repro.dist.context import constrain_activations
        xx = constrain_activations(carry)
        p = xs[0]
        c = xs[1] if caches is not None else None
        h = rmsnorm(xx, p["ln1"], cfg.norm_eps)
        y, self_c = attn_mod.attention(
            h, p["self_attn"], cfg, positions, causal=True,
            cache=(c["self"] if c is not None else None), cache_len=cache_len)
        xx = xx + y
        h = rmsnorm(xx, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            # cross-attn against the cached encoder K/V (no update)
            y = _cross_from_cache(h, p["cross_attn"], cfg, c["cross"])
            cross_c = c["cross"]
        else:
            y, cross_c = _cross_fresh(h, p["cross_attn"], cfg, enc_out,
                                      want_cache=caches is not None)
        xx = xx + y
        h = rmsnorm(xx, p["ln2"], cfg.norm_eps)
        xx = xx + mlp(h, p["ffn"])
        out_c = ({"self": self_c, "cross": cross_c}
                 if caches is not None else 0)
        return xx, out_c

    if cfg.remat == "full" and mode == "train":
        body = jax.checkpoint(body)
    xs = (params["dec"], caches) if caches is not None else (params["dec"],)
    x, new_caches = jax.lax.scan(lambda c, s: body(c, s), x, xs)
    return x, (new_caches if caches is not None else None)


def _cross_fresh(h, p, cfg, enc_out, want_cache):
    """Cross-attention computing K/V from the encoder output."""
    b, s, d = h.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", enc_out, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    from repro.kernels.flash_attention.ref import mha_chunked, mha_reference
    attend = mha_chunked if enc_out.shape[1] > 2048 else mha_reference
    y = attend(q, k, v, causal=False)
    out = jnp.einsum("bhsk,hkd->bsd", y, p["wo"])
    cache = {"k": k, "v": v} if want_cache else None
    return out, cache


def _cross_from_cache(h, p, cfg, cache):
    b, s, d = h.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", h, p["wq"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    kc, vc = cache["k"], cache["v"]
    hq, hkv = q.shape[1], kc.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, s, hd)
    scores = jnp.einsum("bhgsk,bhtk->bhgst", qg, kc).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    w = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhgst,bhtk->bhgsk", w.astype(vc.dtype), vc)
    y = y.reshape(b, hq, s, hd)
    return jnp.einsum("bhsk,hkd->bsd", y, p["wo"])


def forward_loss(params: Params, cfg: ModelConfig, frames: jax.Array,
                 tokens: jax.Array, labels: jax.Array) -> jax.Array:
    enc_out = encode(params, cfg, frames)
    x = embed(tokens, params["embed"])
    positions = jnp.arange(tokens.shape[1])
    x, _ = _decoder(params, cfg, x, enc_out, positions, mode="train")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])
    return cross_entropy_loss(logits, labels)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    dtype = dtype_of(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    one = {
        "self": attn_mod.init_cache(cfg, batch, max_len, dtype),
        "cross": {
            "k": jnp.zeros((batch, hkv, src_len, hd), dtype),
            "v": jnp.zeros((batch, hkv, src_len, hd), dtype),
        },
    }
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.dec_layers,) + x.shape), one)


def cache_specs(cfg: ModelConfig):
    one = {
        "self": attn_mod.spec_cache(cfg),
        "cross": attn_mod.spec_cache(cfg),
    }
    return jax.tree_util.tree_map(
        lambda s: P(None, *s), one, is_leaf=lambda s: isinstance(s, P))


def prefill(params: Params, cfg: ModelConfig, frames: jax.Array,
            tokens: jax.Array, max_len: int):
    """Encode source + run the prompt through the decoder, filling caches."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames)
    caches = init_caches(cfg, b, max_len, frames.shape[1])
    x = embed(tokens, params["embed"])
    positions = jnp.arange(s)
    x, caches = _decoder(params, cfg, x, enc_out, positions,
                         caches=caches, cache_len=None, mode="prefill")
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], caches


def decode_step(params: Params, cfg: ModelConfig, caches, token: jax.Array,
                cache_len: jax.Array):
    x = embed(token, params["embed"])
    positions = cache_len + jnp.arange(1)
    x, caches = _decoder(params, cfg, x, None, positions,
                         caches=caches, cache_len=cache_len, mode="decode")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], caches
