"""Modality-frontend STUBS (per the assignment grid rules).

``[audio]`` (seamless-m4t) and ``[vlm]`` (chameleon) specify the transformer
BACKBONE only; the real frontends (conformer audio encoder / VQ-GAN image
tokenizer) are out of scope. Instead:

* audio: ``input_specs`` provides precomputed frame embeddings
  (B, S_src, d_model) float32 — what the conformer stem would emit.
* vlm  : chameleon is EARLY-FUSION — images arrive as discrete VQ codes that
  live inside the 65536-entry vocabulary, so its inputs are ordinary token
  ids; ``vq_token_stream`` mimics a text+image interleave for smoke tests.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# Chameleon reserves a contiguous block of the vocab for image codes; we
# mirror that convention for the stub stream (8192 VQ codes is the public
# codebook size).
VQ_CODEBOOK = 8192


def audio_frames(key, batch: int, src_len: int, d_model: int) -> jax.Array:
    """Stand-in for conformer-stem output: unit-variance frame embeddings."""
    return jax.random.normal(key, (batch, src_len, d_model), jnp.float32)


def audio_frame_specs(batch: int, src_len: int, d_model: int):
    return jax.ShapeDtypeStruct((batch, src_len, d_model), jnp.float32)


def vq_token_stream(
    key, batch: int, seq: int, vocab: int, image_frac: float = 0.5
) -> jax.Array:
    """Interleaved text+image token ids: the first image_frac of each row is
    VQ codes (drawn from the top-of-vocab code block), the rest text ids."""
    k1, k2 = jax.random.split(key)
    n_img = int(seq * image_frac)
    img = jax.random.randint(k1, (batch, n_img), vocab - VQ_CODEBOOK, vocab,
                             jnp.int32)
    txt = jax.random.randint(k2, (batch, seq - n_img), 0,
                             vocab - VQ_CODEBOOK, jnp.int32)
    return jnp.concatenate([img, txt], axis=1)


def frontend_kind(cfg: ModelConfig) -> str:
    return cfg.frontend
