"""MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3), absorbed form.

KV is compressed to a ``kv_lora_rank`` latent plus a shared RoPE key of
``qk_rope_dim``. We run the **absorbed** (weight-folded) formulation used in
production serving:

    score_h = (q_nope_h W_uk_h^T) · c_kv  +  q_rope_h · k_rope
    y_h     = (softmax(score) · c_kv) W_uv_h

i.e. attention is MQA against the latent itself — per-head K/V are never
materialized, the cache stores only ``[c_kv ‖ k_rope]`` per token, and long
prefill rides the same chunked/flash attention path as GQA (Hkv = 1).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    qn, qr = cfg.qk_nope_dim, cfg.qk_rope_dim
    vh, rank = cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_down"] = dense_init(ks[0], (d, cfg.q_lora_rank), dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype)
        p["wq_up"] = dense_init(ks[1], (cfg.q_lora_rank, h, qn + qr), dtype)
    else:
        p["wq"] = dense_init(ks[1], (d, h, qn + qr), dtype)
    p["wkv_down"] = dense_init(ks[2], (d, rank), dtype)
    p["kv_norm"] = init_rmsnorm(rank, dtype)
    p["wk_rope"] = dense_init(ks[3], (d, qr), dtype)
    p["wk_up"] = dense_init(ks[4], (rank, h, qn), dtype)
    p["wv_up"] = dense_init(ks[5], (rank, h, vh), dtype)
    p["wo"] = dense_init(ks[6], (h, vh, d), dtype)
    return p


def spec_mla(cfg: ModelConfig) -> Params:
    dax = "data" if cfg.fsdp else None
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_down"] = P(dax, None)
        p["q_norm"] = {"scale": P(None)}
        p["wq_up"] = P(dax, "model", None)
    else:
        p["wq"] = P(dax, "model", None)
    p["wkv_down"] = P(dax, None)
    p["kv_norm"] = {"scale": P(None)}
    p["wk_rope"] = P(dax, None)
    p["wk_up"] = P(None, "model", None)
    p["wv_up"] = P(None, "model", None)
    p["wo"] = P("model", None, dax)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def spec_mla_cache() -> Params:
    return {
        "ckv": P(("pod", "data"), None, None),
        "krope": P(("pod", "data"), None, None),
    }


def _queries(x, p, cfg, positions):
    if cfg.q_lora_rank:
        qc = jnp.einsum("bsd,dr->bsr", x, p["wq_down"])
        qc = rmsnorm(qc, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bhsk", qc, p["wq_up"])
    else:
        q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    qn = q[..., : cfg.qk_nope_dim]
    qr = apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return qn, qr


from repro.models.layers import named


@named("attention")
def mla_attention(
    x: jax.Array,
    p: Params,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, d = x.shape
    h = cfg.num_heads
    rank, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
    qn, qr = _queries(x, p, cfg, positions)                   # (B,H,S,*)

    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wkv_down"]),
                  p["kv_norm"], cfg.norm_eps)                  # (B,S,rank)
    krope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["wk_rope"])[:, None],
        positions, cfg.rope_theta,
    )[:, 0]                                                    # (B,S,rope)

    # Absorb W_uk into the query: q_lat = qn @ W_uk^T  (B,H,S,rank).
    q_lat = jnp.einsum("bhsk,rhk->bhsr", qn, p["wk_up"])
    q_mqa = jnp.concatenate([q_lat, qr], axis=-1)              # (B,H,S,rank+rope)
    sm_scale = float(cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    new_cache = None
    if cache is not None and cache_len is not None:
        # ---- decode: append to cache, attend over valid prefix ------------
        ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, cache_len, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["krope"], krope, (0, cache_len, 0))
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        k_mqa = jnp.concatenate([ckv_c, kr_c], axis=-1)        # (B,T,rank+rope)
        t_len = k_mqa.shape[1]
        scores = jnp.einsum("bhsk,btk->bhst", q_mqa, k_mqa).astype(jnp.float32)
        scores = scores * sm_scale
        kv_pos = jnp.arange(t_len)
        q_pos = cache_len + jnp.arange(s)
        mask = q_pos[:, None] >= kv_pos[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(ckv_c.dtype)
        y_lat = jnp.einsum("bhst,btr->bhsr", w, ckv_c)         # (B,H,S,rank)
    else:
        # ---- train / prefill: MQA over the latent via chunked/flash -------
        if cache is not None:
            ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, 0, 0))
            kr_c = jax.lax.dynamic_update_slice(cache["krope"], krope, (0, 0, 0))
            new_cache = {"ckv": ckv_c, "krope": kr_c}
        k_mqa = jnp.concatenate([ckv, krope], axis=-1)[:, None]  # (B,1,S,r+r)
        # Value = latent padded to the same width so one kernel handles both
        # (the rope tail of V is sliced off below).
        v_mqa = jnp.pad(ckv, ((0, 0), (0, 0), (0, rope)))[:, None]
        from repro.kernels.flash_attention.ref import mha_chunked, mha_reference
        if cfg.attn_impl == "flash":
            from repro.kernels.flash_attention.ops import flash_attention
            y_pad = flash_attention(q_mqa, k_mqa, v_mqa, causal=True,
                                    sm_scale=sm_scale)
        elif s > 2048 or cfg.attn_impl == "chunked":
            y_pad = mha_chunked(q_mqa, k_mqa, v_mqa, causal=True,
                                sm_scale=sm_scale)
        else:
            y_pad = mha_reference(q_mqa, k_mqa, v_mqa, causal=True,
                                  sm_scale=sm_scale)
        y_lat = y_pad[..., :rank]                              # (B,H,S,rank)

    # Un-absorb values: y_h = y_lat @ W_uv_h, then output projection.
    y = jnp.einsum("bhsr,rhk->bhsk", y_lat, p["wv_up"])        # (B,H,S,vh)
    out = jnp.einsum("bhsk,hkd->bsd", y, p["wo"])
    return out, new_cache
