"""Model configuration shared by all 10 assigned architectures + DistGER.

One frozen dataclass covers every family; per-family fields default off.
``src/repro/configs/<arch>.py`` files instantiate these with the exact
published numbers (source cited per file).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qk_norm: bool = False

    # --- MLA (multi-head latent attention) ---------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0           # 0 -> direct q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    moe: bool = False
    n_routed_experts: int = 0
    n_shared_experts: int = 0      # shared expert width = n_shared * moe_d_ff
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    moe_dispatch_groups: int = 1   # per-group (per-data-shard) capacity
                                   # dispatch: local scatter + A2A instead of
                                   # a global scatter-add (§Perf qwen2-moe)

    # --- SSM / hybrid / xLSTM ------------------------------------------------
    # block_cycle: repeating pattern of block kinds; num_layers total blocks.
    #   "a" attention+mlp, "m" mamba2, "x" mLSTM, "s" sLSTM
    block_cycle: Tuple[str, ...] = ("a",)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128           # SSD chunk length (memory/compute knob)

    # --- encoder-decoder ------------------------------------------------------
    encdec: bool = False
    enc_layers: int = 0
    dec_layers: int = 0

    # --- frontend stub ---------------------------------------------------------
    frontend: str = "none"         # none | audio | vision

    # --- misc -------------------------------------------------------------------
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"            # none | full — per-layer activation ckpt
    attn_impl: str = "ref"         # ref | flash (Pallas; TPU deploy path)
    fsdp: bool = False             # additionally shard params over data axis
    opt_state_dtype: str = "float32"   # bf16 moments for the 405B config
    grad_accum: int = 1            # microbatches per step (gradient accumulation)
    grad_accum_dtype: str = "float32"  # bf16 accumulators for the 405B config
    vocab_size_unpadded: int = 0   # informational: pre-TP-padding vocab size
    act_seq_shard: bool = True     # Megatron-SP residual sharding; False for
                                   # scan-dominated archs (reshard overhead)
    # long-context support: "none" = quadratic attention only (skip
    # long_500k per shape rules); "state" = SSM/hybrid state-based decode.
    long_context: str = "none"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layer_cycles(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        """(cycle, n_full_cycles, remainder_pattern) covering num_layers."""
        cyc = self.block_cycle
        n = self.num_layers // len(cyc)
        rem = self.num_layers - n * len(cyc)
        return cyc, n, tuple(cyc[:rem])

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.use_mla:
                q_in = self.q_lora_rank or d
                qp = (d * self.q_lora_rank if self.q_lora_rank else 0) + (
                    q_in * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                )
                kvp = d * (self.kv_lora_rank + self.qk_rope_dim)
                kvp += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_dim + self.v_head_dim
                )
                op = self.num_heads * self.v_head_dim * d
                return qp + kvp + op
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mlp_params() -> int:
            return 3 * d * self.d_ff  # SwiGLU: gate, up, down

        def moe_params() -> int:
            routed = self.n_routed_experts * 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            router = d * self.n_routed_experts
            return routed + shared + router

        def mamba_params() -> int:
            d_in = self.ssm_expand * d
            nh = self.ssm_heads or (d_in // max(self.ssm_head_dim, 1))
            proj_in = d * (2 * d_in + 2 * self.ssm_state + nh)
            conv = self.ssm_conv * (d_in + 2 * self.ssm_state)
            proj_out = d_in * d
            return proj_in + conv + proj_out + nh

        def xlstm_params(kind: str) -> int:
            d_in = self.ssm_expand * d
            if kind == "x":  # mLSTM: q,k,v + gates + out
                return d * 3 * d_in + d * 2 * (self.ssm_heads or 4) + d_in * d + d * d_in
            return 4 * d * d + 4 * d * d + 2 * d  # sLSTM: in + recurrent gates

        total = emb
        cyc, n_cyc, rem = self.layer_cycles
        seq = list(cyc) * n_cyc + list(rem)
        if self.encdec:
            seq = ["a"] * (self.enc_layers + self.dec_layers)
        for kind in seq:
            if kind == "a":
                blk = attn_params() + (
                    moe_params() if self.moe else mlp_params()
                )
            elif kind == "m":
                blk = mamba_params()
            elif kind == "x":
                blk = xlstm_params("x")
            elif kind == "s":
                blk = xlstm_params("s")
            else:
                raise ValueError(kind)
            total += blk + 2 * d  # two RMSNorm scales
        if self.encdec:
            total += self.dec_layers * attn_params()  # cross-attention
        if self.moe and self.first_dense_layers:
            total += self.first_dense_layers * (mlp_params() - moe_params())
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        routed_all = self.num_moe_layers * self.n_routed_experts * 3 * self.d_model * self.moe_d_ff
        routed_active = self.num_moe_layers * self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - routed_all + routed_active

    @property
    def num_moe_layers(self) -> int:
        if not self.moe:
            return 0
        return self.num_layers - self.first_dense_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the evaluation grid."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Grid rules: long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.long_context == "none":
        return False, "pure full-attention arch: 524k ctx needs sub-quadratic attention (skip per shape rules)"
    return True, ""
