"""Mixture-of-Experts layer: shared + routed experts, top-k softmax router,
capacity-based dispatch (GShard-style), expert dim sharded over "model" (EP).

Dispatch is sort-free: position-in-expert comes from a masked cumulative sum
over the token axis (classic Switch/GShard formulation but WITHOUT the
(T, E, C) one-hot dispatch tensor — we scatter straight into the (E, C, d)
buffer, which is what keeps 1M-token batches feasible). Tokens beyond an
expert's capacity are dropped (contribute zero), standard for
capacity-factor routing; the router's aux loss pushes toward balance.

Qwen2-MoE convention: ONE shared-expert MLP of width
``n_shared_experts * moe_d_ff`` runs on every token in parallel with the
routed experts (HF's shared_expert_intermediate_size = 4 * 1408).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def padded_experts(cfg: ModelConfig) -> int:
    """Expert count padded to the production tensor axis (EP divisibility):
    qwen2-moe's 60 routed experts become 64 param slots; the router only
    ever selects the first n_routed_experts, pad slots carry zero tokens
    (Megatron-style expert padding)."""
    from repro.dist.sharding import PRODUCTION_MODEL_AXIS
    m = PRODUCTION_MODEL_AXIS
    return -(-cfg.n_routed_experts // m) * m


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    ep = padded_experts(cfg)
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "gate": dense_init(ks[1], (ep, d, f), dtype),
        "up": dense_init(ks[2], (ep, d, f), dtype),
        "down": dense_init(ks[3], (ep, f, d), dtype),
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * f
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(k1, (d, sf), dtype),
            "up": dense_init(k2, (d, sf), dtype),
            "down": dense_init(k3, (sf, d), dtype),
        }
    return p


def spec_moe(cfg: ModelConfig) -> Params:
    dax = "data" if cfg.fsdp else None
    p: Params = {
        "router": P(None, None),
        "gate": P("model", dax, None),   # experts over model axis (EP)
        "up": P("model", dax, None),
        "down": P("model", dax, None),
    }
    if cfg.n_shared_experts:
        p["shared"] = {
            "gate": P(dax, "model"),
            "up": P(dax, "model"),
            "down": P("model", dax),
        }
    return p


from repro.models.layers import named


@named("moe")
def moe_ffn(
    x: jax.Array,            # (B, S, d)
    p: Params,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_routed_experts, cfg.top_k
    ep = padded_experts(cfg)
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_w, gate_e = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # Per-GROUP capacity dispatch (groups ~ data shards): each group builds
    # its own (Ep, C_g, d) buffer with a LOCAL scatter; the expert einsum
    # then exchanges group-buffers for expert-shards (one all-to-all-shaped
    # reshard) instead of all-reducing a globally-scattered buffer — the
    # standard EP schedule. groups=1 reproduces the global-capacity form.
    groups = max(cfg.moe_dispatch_groups, 1)
    if t % groups != 0:
        groups = 1
    t_g = t // groups
    capacity = int(max(1, round(t_g * k / e * cfg.capacity_factor)))

    # Position of each (token, slot) within its expert via masked cumsum,
    # computed independently per group.
    flat_e = gate_e.reshape(groups, t_g * k)                  # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(
        pos_in_e, flat_e[..., None], axis=2)[..., 0]          # (G, Tg*k)
    keep = pos < capacity
    slot = flat_e * capacity + jnp.where(keep, pos, 0)        # (G, Tg*k)

    # Dispatch: local scatter into each group's (Ep*C, d) buffer.
    src = jnp.repeat(xt.reshape(groups, t_g, d), k, axis=1)   # (G, Tg*k, d)
    buf = jnp.zeros((groups, ep * capacity, d), xt.dtype)
    buf = jax.vmap(lambda b_, s_, x_, m_: b_.at[s_].add(
        jnp.where(m_[:, None], x_, 0)))(buf, slot, src, keep)
    buf = buf.reshape(groups, ep, capacity, d)

    # Expert FFN (batched over experts — EP shards this einsum; groups stay
    # on the data axis, so the buf reshard is the A2A exchange).
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["gate"]))
    u = jnp.einsum("gecd,edf->gecf", buf, p["up"])
    out = jnp.einsum("gecf,efd->gecd", g * u, p["down"])
    out = out.reshape(groups, ep * capacity, d)

    # Combine: gather each kept slot back, weighted by its gate. Keep the
    # activation dtype stable (gate weights are f32; a silent promotion here
    # would flip the residual-stream dtype and break the layer-scan carry).
    gate_flat = jnp.where(keep, gate_w.reshape(groups, t_g * k),
                          0.0).astype(xt.dtype)
    back = jax.vmap(lambda o_, s_: o_[s_])(out, slot) * gate_flat[..., None]
    y = back.reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jax.nn.silu(jnp.einsum("td,df->tf", xt, sp["gate"]))
        su = jnp.einsum("td,df->tf", xt, sp["up"])
        y = y + jnp.einsum("tf,fd->td", sg * su, sp["down"])

    return y.reshape(b, s, d).astype(x.dtype), aux
