"""Shared neural layers: RMSNorm, RoPE, SwiGLU, embeddings.

Parameters are plain nested dicts of jnp arrays; every ``init_*`` has a
matching ``spec_*`` producing the PartitionSpec tree with the SAME structure
(axis names: "data" = batch/fsdp axis group, "model" = tensor axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]


def named(scope: str):
    """Decorator: run the function under jax.named_scope so optimized-HLO
    op_name metadata attributes its ops to this module (used by the dry-run
    profiler, launch.profile, and real-TPU traces alike)."""
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with jax.named_scope(scope):
                return fn(*args, **kwargs)
        return wrapped
    return deco


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# -- initializers -------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -- RMSNorm -------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def spec_rmsnorm() -> Params:
    return {"scale": P(None)}


def rmsnorm(x: jax.Array, p: Params, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# -- RoPE ------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D even); positions: (S,) or broadcastable to x[..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# -- SwiGLU MLP --------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d, d_ff), dtype),
        "up": dense_init(k2, (d, d_ff), dtype),
        "down": dense_init(k3, (d_ff, d), dtype),
    }


def spec_mlp(fsdp: bool) -> Params:
    dax = "data" if fsdp else None
    return {
        "gate": P(dax, "model"),
        "up": P(dax, "model"),
        "down": P("model", dax),
    }


@named("mlp")
def mlp(x: jax.Array, p: Params) -> jax.Array:
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["gate"]))
    u = jnp.einsum("...d,df->...f", x, p["up"])
    return jnp.einsum("...f,fd->...d", g * u, p["down"])


# -- Embeddings ------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype, tie: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"table": dense_init(k1, (vocab, d), dtype, scale=1.0)}
    if not tie:
        p["head"] = dense_init(k2, (d, vocab), dtype)
    return p


def spec_embedding(tie: bool, fsdp: bool) -> Params:
    dax = "data" if fsdp else None
    p = {"table": P("model", dax)}   # vocab-sharded over model axis
    if not tie:
        p["head"] = P(dax, "model")
    return p


@named("embed")
def embed(tokens: jax.Array, p: Params) -> jax.Array:
    return p["table"][tokens]


@named("loss_vocab")
def unembed(x: jax.Array, p: Params) -> jax.Array:
    if "head" in p:
        return jnp.einsum("...d,dv->...v", x, p["head"])
    return jnp.einsum("...d,vd->...v", x, p["table"])


@named("loss_vocab")
def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; labels < 0 are masked.

    Written as logsumexp - picked_logit (no full log-softmax tensor): with
    vocab-sharded logits the only cross-shard exchanges are the max/sum
    reductions and the one-hot pick — the (B,S,V) tensor itself never needs
    an all-gather (the classic Megatron vocab-parallel loss)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - picked, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
