"""Decoder LM assembly: block dispatch, scan-over-layers, KV/state caches.

Layer stacking: ``cfg.block_cycle`` (e.g. ("m","m","m","m","m","a") for
zamba2) repeats to cover ``num_layers``; parameters of each cycle position
are STACKED over repetitions and the whole stack runs under one
``lax.scan`` (small compiled HLO even at 126 layers; remat wraps the scan
body). Caches mirror the same structure, scanned alongside.

Everything is functional: params/caches are nested dicts; each init_* has a
matching spec_* with the same tree structure (PartitionSpecs).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy_loss, dtype_of, embed, init_embedding, init_mlp,
    init_rmsnorm, mlp, rmsnorm, spec_embedding, spec_mlp, spec_rmsnorm,
    unembed,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Single block (kind dispatch)
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "a":
        p: Params = {"ln1": init_rmsnorm(d, dtype)}
        p["attn"] = (mla_mod.init_mla(k1, cfg, dtype) if cfg.use_mla
                     else attn_mod.init_attention(k1, cfg, dtype))
        p["ln2"] = init_rmsnorm(d, dtype)
        p["ffn"] = (moe_mod.init_moe(k2, cfg, dtype) if cfg.moe
                    else init_mlp(k2, d, cfg.d_ff, dtype))
        return p
    if kind == "m":
        return {"ln": init_rmsnorm(d, dtype),
                "mixer": mamba_mod.init_mamba(k1, cfg, dtype)}
    if kind == "x":
        p = {"ln": init_rmsnorm(d, dtype),
             "mixer": xlstm_mod.init_mlstm(k1, cfg, dtype)}
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(d, dtype)
            p["ffn"] = init_mlp(k2, d, cfg.d_ff, dtype)
        return p
    if kind == "s":
        return {"ln": init_rmsnorm(d, dtype),
                "mixer": xlstm_mod.init_slstm(k1, cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def spec_block(kind: str, cfg: ModelConfig) -> Params:
    if kind == "a":
        p: Params = {"ln1": spec_rmsnorm()}
        p["attn"] = (mla_mod.spec_mla(cfg) if cfg.use_mla
                     else attn_mod.spec_attention(cfg))
        p["ln2"] = spec_rmsnorm()
        p["ffn"] = moe_mod.spec_moe(cfg) if cfg.moe else spec_mlp(cfg.fsdp)
        return p
    if kind == "m":
        return {"ln": spec_rmsnorm(), "mixer": mamba_mod.spec_mamba(cfg)}
    if kind == "x":
        p = {"ln": spec_rmsnorm(), "mixer": xlstm_mod.spec_mlstm(cfg)}
        if cfg.d_ff:
            p["ln2"] = spec_rmsnorm()
            p["ffn"] = spec_mlp(cfg.fsdp)
        return p
    if kind == "s":
        return {"ln": spec_rmsnorm(), "mixer": xlstm_mod.spec_slstm(cfg)}
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype):
    if kind == "a":
        return (mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
                if cfg.use_mla
                else attn_mod.init_cache(cfg, batch, max_len, dtype))
    if kind == "m":
        return mamba_mod.init_mamba_state(cfg, batch, dtype)
    if kind == "x":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == "s":
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def spec_block_cache(kind: str, cfg: ModelConfig):
    if kind == "a":
        return mla_mod.spec_mla_cache() if cfg.use_mla else attn_mod.spec_cache(cfg)
    if kind == "m":
        return mamba_mod.spec_mamba_state()
    if kind == "x":
        return xlstm_mod.spec_mlstm_state()
    if kind == "s":
        return xlstm_mod.spec_slstm_state()
    raise ValueError(kind)


def apply_block(
    x: jax.Array,
    p: Params,
    kind: str,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache=None,
    cache_len=None,
    causal: bool = True,
    mode: str = "train",
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss). ``mode`` controls stateful mixers:
    train (no state), prefill (emit final state), decode (step the state)."""
    aux = jnp.float32(0.0)
    prefill_state = mode == "prefill"
    mixer_state = cache if mode == "decode" else None
    if kind == "a":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if cfg.use_mla:
            y, new_cache = mla_mod.mla_attention(
                h, p["attn"], cfg, positions, cache=cache, cache_len=cache_len)
        else:
            y, new_cache = attn_mod.attention(
                h, p["attn"], cfg, positions, causal=causal,
                cache=cache, cache_len=cache_len)
        x = x + y
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, aux = moe_mod.moe_ffn(h, p["ffn"], cfg)
        else:
            y = mlp(h, p["ffn"])
        return x + y, new_cache, aux
    if kind == "m":
        h = rmsnorm(x, p["ln"], cfg.norm_eps)
        y, new_cache = mamba_mod.mamba_mixer(
            h, p["mixer"], cfg, state=mixer_state, return_state=prefill_state)
        return x + y, new_cache, aux
    if kind == "x":
        h = rmsnorm(x, p["ln"], cfg.norm_eps)
        y, new_cache = xlstm_mod.mlstm_mixer(
            h, p["mixer"], cfg, state=mixer_state, return_state=prefill_state)
        x = x + y
        if cfg.d_ff:
            h = rmsnorm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp(h, p["ffn"])
        return x, new_cache, aux
    if kind == "s":
        h = rmsnorm(x, p["ln"], cfg.norm_eps)
        y, new_cache = xlstm_mod.slstm_mixer(
            h, p["mixer"], cfg, state=mixer_state, return_state=prefill_state)
        return x + y, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stacked groups
# ---------------------------------------------------------------------------

def _groups(cfg: ModelConfig):
    cyc, n, rem = cfg.layer_cycles
    out = []
    if n:
        out.append((tuple(cyc), n))
    if rem:
        out.append((tuple(rem), 1))
    return out


def _stack_init(key, pattern, n_rep, cfg, dtype) -> Params:
    reps = []
    for r in range(n_rep):
        kr = jax.random.fold_in(key, r)
        reps.append({
            f"b{j}": init_block(jax.random.fold_in(kr, j), kind, cfg, dtype)
            for j, kind in enumerate(pattern)
        })
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *reps)


def _stack_spec(pattern, cfg) -> Params:
    one = {f"b{j}": spec_block(kind, cfg) for j, kind in enumerate(pattern)}
    # prepend the stacking axis (unsharded) to every leaf spec
    return jax.tree_util.tree_map(
        lambda s: P(None, *s), one,
        is_leaf=lambda s: isinstance(s, P),
    )


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_blocks, k_final = jax.random.split(key, 3)
    p: Params = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype,
                                cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.frontend == "audio":
        # stub projection for precomputed frames (identity-sized)
        p["frontend"] = {"proj": jnp.eye(cfg.d_model, dtype=dtype)}
    for gi, (pattern, n_rep) in enumerate(_groups(cfg)):
        p[f"group_{gi}"] = _stack_init(
            jax.random.fold_in(k_blocks, gi), pattern, n_rep, cfg, dtype)
    return p


def param_specs(cfg: ModelConfig) -> Params:
    p: Params = {
        "embed": spec_embedding(cfg.tie_embeddings, cfg.fsdp),
        "final_norm": spec_rmsnorm(),
    }
    if cfg.frontend == "audio":
        p["frontend"] = {"proj": P(None, "model")}
    for gi, (pattern, n_rep) in enumerate(_groups(cfg)):
        p[f"group_{gi}"] = _stack_spec(pattern, cfg)
    return p


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = dtype_of(cfg.dtype)
    c: Params = {}
    for gi, (pattern, n_rep) in enumerate(_groups(cfg)):
        reps = []
        for _ in range(n_rep):
            reps.append({
                f"b{j}": init_block_cache(kind, cfg, batch, max_len, dtype)
                for j, kind in enumerate(pattern)
            })
        c[f"group_{gi}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *reps)
    return c


def cache_specs(cfg: ModelConfig) -> Params:
    c: Params = {}
    for gi, (pattern, n_rep) in enumerate(_groups(cfg)):
        one = {f"b{j}": spec_block_cache(kind, cfg)
               for j, kind in enumerate(pattern)}
        c[f"group_{gi}"] = jax.tree_util.tree_map(
            lambda s: P(None, *s), one,
            is_leaf=lambda s: isinstance(s, P),
        )
    return c


def _run_groups(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    caches: Optional[Params] = None,
    cache_len=None,
    causal: bool = True,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    aux_total = jnp.float32(0.0)
    new_caches: Params = {}
    for gi, (pattern, n_rep) in enumerate(_groups(cfg)):
        gp = params[f"group_{gi}"]
        gc = caches[f"group_{gi}"] if caches is not None else None

        remat_blocks = cfg.remat == "full" and mode == "train"

        def body(carry, xs):
            from repro.dist.context import constrain_activations
            xx, aux = carry
            xx = constrain_activations(xx)
            p_rep = xs[0]
            c_rep = xs[1] if gc is not None else None
            nc_rep = {}
            for j, kind in enumerate(pattern):
                blk_cache = c_rep[f"b{j}"] if c_rep is not None else None

                def run_block(xx_, bp_, bc_, kind=kind):
                    return apply_block(
                        xx_, bp_, kind, cfg, positions,
                        cache=bc_, cache_len=cache_len, causal=causal,
                        mode=mode)

                if remat_blocks:
                    # per-BLOCK remat: bwd keeps one block's internals live
                    # at a time even when the cycle pattern has many blocks
                    run_block = jax.checkpoint(run_block)
                xx, nc, a = run_block(xx, p_rep[f"b{j}"], blk_cache)
                aux = aux + a
                if nc is not None:
                    nc_rep[f"b{j}"] = nc
            return (xx, aux), (nc_rep if nc_rep else 0)
        xs = (gp, gc) if gc is not None else (gp,)
        (x, aux_total), ncs = jax.lax.scan(
            lambda carry, xs_: body(carry, xs_), (x, aux_total), xs)
        if caches is not None:
            new_caches[f"group_{gi}"] = ncs
    return x, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_loss(
    params: Params, cfg: ModelConfig, tokens: jax.Array, labels: jax.Array,
) -> jax.Array:
    """Mean next-token loss (tokens (B,S) int32; labels -1 masked)."""
    x = embed(tokens, params["embed"])
    positions = jnp.arange(tokens.shape[1])
    x, _, aux = _run_groups(params, x, cfg, positions, mode="train")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])
    return cross_entropy_loss(logits, labels) + 0.01 * aux


def prefill(
    params: Params, cfg: ModelConfig, tokens: jax.Array, max_len: int,
) -> Tuple[jax.Array, Params]:
    """Fill caches with a prompt; returns (last-token logits, caches)."""
    b, s = tokens.shape
    x = embed(tokens, params["embed"])
    positions = jnp.arange(s)
    caches = init_caches(cfg, b, max_len)
    x, caches, _ = _run_groups(params, x, cfg, positions,
                               caches=caches, cache_len=None, mode="prefill")
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], caches


def decode_step(
    params: Params, cfg: ModelConfig, caches: Params,
    token: jax.Array, cache_len: jax.Array,
) -> Tuple[jax.Array, Params]:
    """One serving step: token (B, 1) given cache_len cached tokens."""
    x = embed(token, params["embed"])
    positions = cache_len + jnp.arange(1)
    x, caches, _ = _run_groups(params, x, cfg, positions,
                               caches=caches, cache_len=cache_len,
                               mode="decode")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], caches
