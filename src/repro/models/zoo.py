"""Uniform model facade over the decoder-only and encoder-decoder stacks.

Batch convention (everything is a dict of arrays):
  * decoder-only : {"tokens": (B,S) i32, "labels": (B,S) i32}
  * enc-dec      : {"frames": (B,S_src,d) f32 stub frontend embeddings,
                    "tokens": (B,S_tgt) i32, "labels": (B,S_tgt) i32}

Shape-cell semantics for enc-dec (seamless): a train/prefill cell of
``seq_len`` splits it as S_src = S_tgt = seq_len // 2 (total context =
seq_len); decode cells keep the decoder self-KV at seq_len per the grid
definition and a fixed CROSS_SRC_LEN encoder memory (documented in
DESIGN.md §5). VLM (chameleon) is early-fusion: VQ image tokens are ordinary
vocabulary ids, so its batch is the decoder-only form.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_spec
from repro.models import encdec as encdec_mod
from repro.models import transformer as transformer_mod
from repro.models.config import ModelConfig, ShapeConfig

CROSS_SRC_LEN = 4096   # encoder memory length for enc-dec decode cells


def model_module(cfg: ModelConfig):
    return encdec_mod if cfg.encdec else transformer_mod


# ---------------------------------------------------------------------------
# Batch construction (concrete arrays for smoke tests / examples)
# ---------------------------------------------------------------------------

def train_batch(cfg: ModelConfig, batch: int, seq: int, key) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    if cfg.encdec:
        s_src = max(seq // 2, 1)
        s_tgt = max(seq // 2, 1)
        return {
            "frames": jax.random.normal(k1, (batch, s_src, cfg.d_model),
                                        jnp.float32),
            "tokens": jax.random.randint(k2, (batch, s_tgt), 0,
                                         cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(k2, (batch, s_tgt), 0,
                                         cfg.vocab_size, jnp.int32),
        }
    toks = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


def train_batch_specs(cfg: ModelConfig) -> Dict[str, P]:
    if cfg.encdec:
        return {"frames": batch_spec(None, None),
                "tokens": batch_spec(None), "labels": batch_spec(None)}
    return {"tokens": batch_spec(None), "labels": batch_spec(None)}


# ---------------------------------------------------------------------------
# Uniform step functions
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig) -> Callable[[Any, Dict[str, Any]], jax.Array]:
    mod = model_module(cfg)
    if cfg.encdec:
        def f(params, batch):
            return mod.forward_loss(params, cfg, batch["frames"],
                                    batch["tokens"], batch["labels"])
        return f

    def f(params, batch):
        return mod.forward_loss(params, cfg, batch["tokens"], batch["labels"])
    return f


def prefill_fn(cfg: ModelConfig, max_len: int):
    mod = model_module(cfg)
    if cfg.encdec:
        def f(params, batch):
            return mod.prefill(params, cfg, batch["frames"], batch["tokens"],
                               max_len)
        return f

    def f(params, batch):
        return mod.prefill(params, cfg, batch["tokens"], max_len)
    return f


def decode_fn(cfg: ModelConfig):
    mod = model_module(cfg)

    def f(params, caches, token, cache_len):
        return mod.decode_step(params, cfg, caches, token, cache_len)
    return f


def init_params(key, cfg: ModelConfig):
    return model_module(cfg).init_params(key, cfg)


def param_specs(cfg: ModelConfig):
    return model_module(cfg).param_specs(cfg)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                src_len: int = CROSS_SRC_LEN):
    if cfg.encdec:
        return encdec_mod.init_caches(cfg, batch, max_len, src_len)
    return transformer_mod.init_caches(cfg, batch, max_len)


def cache_specs(cfg: ModelConfig):
    return model_module(cfg).cache_specs(cfg)


# ---------------------------------------------------------------------------
# Reduced ("smoke") configs — same family, tiny dims, for CPU tests
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Scale an arch config down to CPU-smoke size, preserving the family
    structure (MoE stays MoE with fewer experts; MLA keeps latent ranks;
    hybrid keeps its cycle)."""
    small: Dict[str, Any] = dict(
        num_layers=max(2, min(4, len(cfg.block_cycle))),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1))),
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        head_dim=16 if cfg.head_dim else 0,
        dtype="float32",
        remat="none",
        fsdp=False,
    )
    if cfg.use_mla:
        small.update(q_lora_rank=32 if cfg.q_lora_rank else 0,
                     kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                     v_head_dim=16)
    if cfg.moe:
        small.update(n_routed_experts=4, top_k=min(2, cfg.top_k),
                     moe_d_ff=32,
                     n_shared_experts=min(1, cfg.n_shared_experts),
                     first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_heads=4, ssm_head_dim=0)
    if cfg.encdec:
        small.update(enc_layers=2, dec_layers=2, num_layers=4)
    if len(cfg.block_cycle) > 1:
        small["num_layers"] = 2 * len(cfg.block_cycle)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
