"""GQA attention (optional qk_norm), with KV cache for serving.

Head axes are sharded over "model"; the KV cache inherits the same sharding.
``attn_impl="flash"`` routes prefill/train through the Pallas kernel
(TPU deploy path); "ref" uses the jnp oracle (CPU dry-run path — identical
math and FLOPs, so roofline numbers are unaffected).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm

Params = Dict[str, Any]


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h, hd), dtype),
        "wk": dense_init(k2, (d, hkv, hd), dtype),
        "wv": dense_init(k3, (d, hkv, hd), dtype),
        "wo": dense_init(k4, (h, hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


def spec_attention(cfg: ModelConfig) -> Params:
    dax = "data" if cfg.fsdp else None
    p = {
        "wq": P(dax, "model", None),
        "wk": P(dax, "model", None),
        "wv": P(dax, "model", None),
        "wo": P("model", None, dax),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": P(None)}
        p["k_norm"] = {"scale": P(None)}
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
    }


def spec_cache(cfg: Optional[ModelConfig] = None) -> Params:
    """KV-cache layout choice: shard heads over "model" when the KV-head
    count divides the production tensor axis; otherwise shard the SEQUENCE
    dim (split-KV / flash-decoding style) so few-KV-head GQA models (kv=4/8)
    still spread the cache across the pod instead of replicating 16x."""
    from repro.dist.sharding import PRODUCTION_MODEL_AXIS
    if cfg is None or cfg.num_kv_heads % PRODUCTION_MODEL_AXIS == 0:
        s = P(("pod", "data"), "model", None, None)
    else:
        s = P(("pod", "data"), None, "model", None)
    return {"k": s, "v": s}


def _attend(q, k, v, *, causal: bool, impl: str, q_offset: int = 0):
    if impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "chunked" or (impl == "ref" and q.shape[2] > 2048):
        from repro.kernels.flash_attention.ref import mha_chunked
        return mha_chunked(q, k, v, causal=causal, q_offset=q_offset)
    from repro.kernels.flash_attention.ref import mha_reference
    return mha_reference(q, k, v, causal=causal, q_offset=q_offset)


from repro.models.layers import named


@named("attention")
def attention(
    x: jax.Array,                 # (B, S, d)
    p: Params,
    cfg: ModelConfig,
    positions: jax.Array,         # (S,)
    *,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,   # () int32 — tokens already cached
    kv_x: Optional[jax.Array] = None,        # cross-attention source
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (y, updated_cache). Three modes:

    * train/prefill: cache=None -> full self-attention over x.
    * prefill with cache: cache provided, cache_len=None -> fills cache[0:S].
    * decode: cache + cache_len -> writes S new tokens at cache_len, attends
      over the first cache_len + S entries (positions give RoPE phases).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bhsk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", src, p["wv"])

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    kv_positions = positions if kv_x is None else jnp.arange(src.shape[1])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, kv_positions, cfg.rope_theta)

    if cache is None:
        y = _attend(q, k, v, causal=causal, impl=cfg.attn_impl)
        new_cache = None
    elif cache_len is None:
        # prefill into cache
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        y = _attend(q, k, v, causal=causal, impl=cfg.attn_impl)
        new_cache = {"k": kc, "v": vc}
    else:
        # decode: append then attend over the valid prefix
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, cache_len, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, cache_len, 0)
        )
        # Mask: query at absolute position cache_len + i attends kv <= that.
        scale = hd ** -0.5
        hq, hkv = q.shape[1], kc.shape[1]
        group = hq // hkv
        qg = q.reshape(b, hkv, group, s, hd)
        scores = jnp.einsum("bhgsk,bhtk->bhgst", qg, kc).astype(jnp.float32) * scale
        kv_pos = jnp.arange(kc.shape[2])
        q_pos = cache_len + jnp.arange(s)
        mask = q_pos[:, None] >= kv_pos[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        y = jnp.einsum("bhgst,bhtk->bhgsk", w.astype(v.dtype), vc)
        y = y.reshape(b, hq, s, hd)
        new_cache = {"k": kc, "v": vc}

    out = jnp.einsum("bhsk,hkd->bsd", y, p["wo"])
    return out, new_cache
