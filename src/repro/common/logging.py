"""Tiny structured logger (stdlib logging, one-line setup).

``REPRO_LOG_LEVEL`` (DEBUG/INFO/WARNING/ERROR, or a number) sets the level
at first use. ``log_context(round=3, shard=1)`` pushes structured fields
that every log line emitted inside the ``with`` block carries as trailing
``key=value`` pairs — the pipeline/ingest drivers wrap their phases in it
so postmortems can grep a crash down to the exact round/shard/
graph_version without the call sites threading those fields by hand.
"""

from __future__ import annotations

import contextlib
import contextvars
import logging
import os
import sys

_CONFIGURED = False
_CONTEXT: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_log_context", default=())


class _ContextFilter(logging.Filter):
    """Append the active ``log_context`` fields to every record."""

    def filter(self, record: logging.LogRecord) -> bool:
        fields = {}
        for frame in _CONTEXT.get():
            fields.update(frame)
        record.ctx = (
            " [" + " ".join(f"{k}={v}" for k, v in fields.items()) + "]"
            if fields else "")
        return True


def _env_level(default: int = logging.INFO) -> int:
    raw = os.environ.get("REPRO_LOG_LEVEL", "").strip()
    if not raw:
        return default
    if raw.isdigit():
        return int(raw)
    return getattr(logging, raw.upper(), default)


def get_logger(name: str = "repro") -> logging.Logger:
    global _CONFIGURED
    if not _CONFIGURED:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(name)s %(levelname)s %(message)s%(ctx)s"))
        handler.addFilter(_ContextFilter())
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(_env_level())
        root.propagate = False
        _CONFIGURED = True
    return logging.getLogger(name)


@contextlib.contextmanager
def log_context(**fields):
    """Attach ``key=value`` fields to every log line in this block.

    Nested contexts merge (inner wins on key collision); the contextvar
    scoping keeps prefetch/driver threads from seeing each other's frames.
    """
    token = _CONTEXT.set(_CONTEXT.get() + (fields,))
    try:
        yield
    finally:
        _CONTEXT.reset(token)
