"""Tiny structured logger (stdlib logging, one-line setup).

``REPRO_LOG_LEVEL`` (DEBUG/INFO/WARNING/ERROR, or a number) sets the level
and is re-read on every ``get_logger`` call, so a test or operator can
flip verbosity mid-process. ``log_context(round=3, shard=1)`` pushes
structured fields that every log line emitted inside the ``with`` block
carries as trailing ``key=value`` pairs — the pipeline/ingest drivers
wrap their phases in it so postmortems can grep a crash down to the
exact round/shard/graph_version without the call sites threading those
fields by hand. ``obs.trace_span`` pushes its span fields through the
same contextvar and emits its close lines through the same handler, so
spans and log lines share one format.

Handler install is idempotent by inspection, not by module flag: the
handler we install is tagged, and ``get_logger`` only adds one when no
tagged handler is present. A pytest run that re-imports this module (or
anything else that resets module globals) can no longer stack duplicate
handlers.
"""

from __future__ import annotations

import contextlib
import contextvars
import logging
import os
import sys

_CONTEXT: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_log_context", default=())

#: Attribute used to mark the handler this module installs; idempotency
#: is "a tagged handler exists", which survives module re-imports.
_HANDLER_TAG = "_repro_handler"

_FORMAT = "%(asctime)s %(name)s %(levelname)s %(message)s%(ctx)s"


class _ContextFilter(logging.Filter):
    """Append the active ``log_context`` fields to every record."""

    def filter(self, record: logging.LogRecord) -> bool:
        fields = {}
        for frame in _CONTEXT.get():
            fields.update(frame)
        record.ctx = (
            " [" + " ".join(f"{k}={v}" for k, v in fields.items()) + "]"
            if fields else "")
        return True


def _env_level(default: int = logging.INFO) -> int:
    raw = os.environ.get("REPRO_LOG_LEVEL", "").strip()
    if not raw:
        return default
    if raw.isdigit():
        return int(raw)
    return getattr(logging, raw.upper(), default)


def _installed_handler(root: logging.Logger) -> logging.Handler | None:
    for h in root.handlers:
        if getattr(h, _HANDLER_TAG, False):
            return h
    return None


def refresh_log_level() -> int:
    """Re-read ``REPRO_LOG_LEVEL`` and apply it to the repro root logger;
    returns the applied level."""
    level = _env_level()
    logging.getLogger("repro").setLevel(level)
    return level


def get_logger(name: str = "repro") -> logging.Logger:
    root = logging.getLogger("repro")
    if _installed_handler(root) is None:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT))
        handler.addFilter(_ContextFilter())
        setattr(handler, _HANDLER_TAG, True)
        root.addHandler(handler)
        root.propagate = False
    refresh_log_level()
    return logging.getLogger(name)


def current_context_fields() -> dict:
    """The merged ``log_context`` fields active in this thread/context
    (outer→inner, inner wins). ``obs`` stamps these onto point events and
    flight-recorder dumps so a postmortem carries the same
    round/shard/graph_version the log lines do."""
    fields = {}
    for frame in _CONTEXT.get():
        fields.update(frame)
    return fields


@contextlib.contextmanager
def log_context(**fields):
    """Attach ``key=value`` fields to every log line in this block.

    Nested contexts merge (inner wins on key collision); the contextvar
    scoping keeps prefetch/driver threads from seeing each other's frames.
    """
    token = _CONTEXT.set(_CONTEXT.get() + (fields,))
    try:
        yield
    finally:
        _CONTEXT.reset(token)
