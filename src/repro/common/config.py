"""Config system: frozen dataclasses + a named registry + CLI overrides.

Every architecture config (``repro/configs/<id>.py``) registers a factory in
the global ``ARCH_REGISTRY``; launchers select with ``--arch <id>`` and apply
``key=value`` overrides (dotted keys traverse nested dataclasses).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generic, Iterator, Tuple, TypeVar

T = TypeVar("T")


def frozen_dataclass(cls):
    """Decorator: frozen dataclass usable as a pytree leaf container."""
    return dataclasses.dataclass(frozen=True)(cls)


class Registry(Generic[T]):
    """A simple name -> factory registry with helpful error messages."""

    def __init__(self, kind: str):
        self._kind = kind
        self._entries: Dict[str, Callable[[], T]] = {}

    def register(self, name: str) -> Callable[[Callable[[], T]], Callable[[], T]]:
        def deco(fn: Callable[[], T]) -> Callable[[], T]:
            if name in self._entries:
                raise ValueError(f"duplicate {self._kind} registration: {name!r}")
            self._entries[name] = fn
            return fn

        return deco

    def get(self, name: str) -> T:
        if name not in self._entries:
            known = ", ".join(sorted(self._entries))
            raise KeyError(f"unknown {self._kind} {name!r}; known: {known}")
        return self._entries[name]()

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))


def _coerce(value: str, target: Any) -> Any:
    """Coerce a CLI string to the type of ``target``."""
    if isinstance(target, bool):
        if value.lower() in ("1", "true", "yes", "on"):
            return True
        if value.lower() in ("0", "false", "no", "off"):
            return False
        raise ValueError(f"cannot parse bool from {value!r}")
    if isinstance(target, int) and not isinstance(target, bool):
        return int(value)
    if isinstance(target, float):
        return float(value)
    if target is None or isinstance(target, str):
        return value
    if isinstance(target, tuple):
        parts = [p for p in value.split(",") if p]
        elem = target[0] if target else "0"
        return tuple(_coerce(p, elem) for p in parts)
    raise TypeError(f"cannot coerce override for field of type {type(target)}")


def override_dataclass(cfg: T, overrides: Dict[str, str]) -> T:
    """Return a copy of ``cfg`` with dotted-key string overrides applied."""
    for dotted, raw in overrides.items():
        keys = dotted.split(".")
        # Walk down to the leaf owner, collecting owners for rebuild.
        owners = [cfg]
        for k in keys[:-1]:
            owners.append(getattr(owners[-1], k))
        leaf_owner = owners[-1]
        cur = getattr(leaf_owner, keys[-1])
        new_leaf_owner = dataclasses.replace(
            leaf_owner, **{keys[-1]: _coerce(raw, cur)}
        )
        # Rebuild the chain bottom-up.
        for owner, k in zip(reversed(owners[:-1]), reversed(keys[:-1])):
            new_leaf_owner = dataclasses.replace(owner, **{k: new_leaf_owner})
        cfg = new_leaf_owner
    return cfg


def parse_overrides(argv) -> Dict[str, str]:
    """Parse trailing ``key=value`` tokens from an argv list."""
    out: Dict[str, str] = {}
    for tok in argv:
        if "=" not in tok:
            raise ValueError(f"override must look like key=value, got {tok!r}")
        k, v = tok.split("=", 1)
        out[k] = v
    return out
