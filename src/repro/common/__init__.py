from repro.common.config import (
    Registry,
    frozen_dataclass,
    override_dataclass,
)
from repro.common.logging import get_logger

__all__ = [
    "Registry",
    "frozen_dataclass",
    "override_dataclass",
    "get_logger",
]
