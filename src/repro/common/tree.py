"""Pytree helpers shared across the framework."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of scalar elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (uses each leaf's dtype)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(tree: Any, dtype) -> Any:
    """Cast all inexact leaves to ``dtype`` (ints/bools untouched)."""

    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(cast, tree)


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_allclose(a: Any, b: Any, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol) for x, y in zip(la, lb))
