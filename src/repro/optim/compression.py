"""Gradient-compression policies, pluggable into the trainer.

Two compressors:

* ``HotnessSync`` — the paper's §4.2-III mechanism generalized to LM
  embedding tables: rows are frequency-ranked (token counts play the role of
  corpus occurrence counts); each sync period exchanges one row per hotness
  block instead of the full table. This is DistGER's contribution running as
  a first-class framework feature for every arch config (DESIGN.md §5).

* ``TopKErrorFeedback`` — classic sparsified all-reduce with memory
  (Stich et al.); framework-level trick for non-embedding tensors.

Both are *policies*: they decide which rows/entries synchronize and carry
their own state; the trainer applies them around the data-parallel mean.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class HotnessSync:
    """State for hotness-block embedding sync.

    ``block_starts``/``block_ends`` delimit equal-frequency rank ranges of
    the frequency-sorted table (repro.core.corpus.FrequencyOrder for graph
    corpora; token histograms for LM data)."""

    block_starts: np.ndarray
    block_ends: np.ndarray
    period: int = 50
    _step: int = 0

    @classmethod
    def from_counts(cls, counts: np.ndarray, period: int = 50) -> "HotnessSync":
        """counts[rank] = occurrences, already sorted descending."""
        counts = np.asarray(counts)
        edges = np.flatnonzero(np.diff(counts)) + 1
        starts = np.concatenate([[0], edges])
        ends = np.concatenate([edges, [len(counts)]])
        return cls(block_starts=starts, block_ends=ends, period=period)

    def due(self) -> bool:
        self._step += 1
        return self._step % self.period == 0

    def sample_rows(self, rng: np.random.Generator) -> np.ndarray:
        u = rng.random(len(self.block_starts))
        span = self.block_ends - self.block_starts
        return (self.block_starts + np.floor(u * span)).astype(np.int64)

    def bytes_per_period(self, dim: int, replicas: int) -> float:
        return float(len(self.block_starts) * dim * 4 * replicas)

    def full_bytes(self, num_rows: int, dim: int, replicas: int) -> float:
        return float(num_rows * dim * 4 * replicas)


@dataclasses.dataclass
class TopKErrorFeedback:
    """Error-feedback top-k sparsification state (one tree of residuals)."""

    k_frac: float = 0.01
    residual: Optional[Any] = None

    def init(self, grads: Any) -> None:
        self.residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def compress(self, grads: Any) -> Tuple[Any, Any]:
        """Returns (sparse_grads_to_allreduce, new_residual_tree)."""
        if self.residual is None:
            self.init(grads)

        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            flat = corrected.reshape(-1)
            k = max(1, int(flat.shape[0] * self.k_frac))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            sparse = jnp.zeros_like(flat).at[idx].set(flat[idx])
            return sparse.reshape(g.shape).astype(g.dtype), \
                (flat - sparse).reshape(g.shape)

        pairs = jax.tree_util.tree_map(one, grads, self.residual)
        sparse = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        self.residual = resid
        return sparse, resid

    def wire_bytes(self, grads: Any) -> float:
        """Index+value bytes per all-reduce vs dense."""
        total = sum(x.size for x in jax.tree_util.tree_leaves(grads))
        k = int(total * self.k_frac)
        return float(k * 8)   # 4B value + 4B index
