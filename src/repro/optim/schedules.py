"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.float32(lr)
    return f


def linear_warmup(lr: float, warmup: int, total: int, end_frac: float = 0.0):
    def f(step):
        s = jnp.float32(step)
        warm = s / jnp.maximum(warmup, 1)
        frac = (s - warmup) / jnp.maximum(total - warmup, 1)
        decay = 1.0 - (1.0 - end_frac) * jnp.clip(frac, 0.0, 1.0)
        return jnp.float32(lr) * jnp.where(s < warmup, warm, decay)
    return f


def cosine_warmup(lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def f(step):
        s = jnp.float32(step)
        warm = s / jnp.maximum(warmup, 1)
        frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.float32(lr) * jnp.where(s < warmup, warm, cos)
    return f


def word2vec_linear(lr: float, min_lr: float, total: int):
    """The Skip-Gram convention: linear decay to min_lr over the corpus."""
    def f(step):
        frac = jnp.clip(jnp.float32(step) / jnp.maximum(total, 1), 0.0, 1.0)
        return jnp.maximum(jnp.float32(lr) * (1 - frac), jnp.float32(min_lr))
    return f
