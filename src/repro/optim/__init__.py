"""Optimizers, LR schedules, gradient compression."""

from repro.optim.optimizers import (  # noqa: F401
    AdamWConfig, SGDConfig, init_opt_state, opt_update,
)
from repro.optim.schedules import (  # noqa: F401
    cosine_warmup, linear_warmup, constant,
)
