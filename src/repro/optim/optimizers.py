"""Functional optimizers (AdamW / SGD-momentum) with dtype-configurable
moments — bf16 moments halve optimizer HBM for the 405B config
(cfg.opt_state_dtype), the standard frontier-scale memory recipe.

State layout mirrors the param tree: {"m": tree, "v": tree, "count": ()}.
Moment trees inherit the PARAMETER sharding specs (the caller passes the
param spec tree through ``opt_specs``), so FSDP shards optimizer state the
same way it shards weights (ZeRO style).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" for the 405B recipe
    grad_clip: float = 1.0          # global-norm clip; 0 disables


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.9
    weight_decay: float = 0.0
    moment_dtype: str = "float32"
    grad_clip: float = 0.0


def _mdt(cfg) -> Any:
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def init_opt_state(params: Any, cfg) -> Any:
    dt = _mdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    if isinstance(cfg, AdamWConfig):
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_specs(param_specs: Any, cfg) -> Any:
    """Optimizer-state spec tree: moments shard exactly like the params."""
    from jax.sharding import PartitionSpec as P
    if isinstance(cfg, AdamWConfig):
        return {"m": param_specs, "v": param_specs, "count": P()}
    return {"m": param_specs, "count": P()}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _named(scope):
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with jax.named_scope(scope):
                return fn(*args, **kwargs)
        return wrapped
    return deco


@_named("optimizer")
def opt_update(
    grads: Any,
    state: Any,
    params: Any,
    cfg,
    lr: jax.Array,
) -> Tuple[Any, Any, jax.Array]:
    """One step. Returns (new_params, new_state, grad_norm). Math in f32,
    stored moments in cfg.moment_dtype, params keep their own dtype."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    dt = _mdt(cfg)

    if isinstance(cfg, AdamWConfig):
        b1, b2 = cfg.b1, cfg.b2
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
            if cfg.weight_decay:
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return newp, m32.astype(dt), v32.astype(dt)

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm

    # SGD with momentum
    def upd(p, g, m):
        g32 = g.astype(jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
        m32 = cfg.momentum * m.astype(jnp.float32) + g32
        newp = (p.astype(jnp.float32) - lr * m32).astype(p.dtype)
        return newp, m32.astype(dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    return new_p, {"m": new_m, "count": count}, gnorm
