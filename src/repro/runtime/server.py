"""LM serving adapter: prefill + decode over the shared slot pool.

The generic slot-pool/wave machinery (continuous batching, admission,
versioned state) lives in ``repro.runtime.serve`` — the embedding
``EmbedServer`` is the primary consumer. This module keeps the original
LM ``Server`` as a thin adapter over the same ``wave_batches`` refill
order: a fixed pool of B slots holds in-flight requests; finished slots
are refilled from the queue each decode tick. The decode step is the
same ``serve_step`` the dry-run lowers for the decode_* cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import zoo
from repro.models.config import ModelConfig
from repro.runtime.serve import wave_batches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    max_len: int = 256
    greedy: bool = True


class Server:
    """Single-model batched server (decoder-only archs)."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig):
        assert not cfg.encdec, "use EncDecServer for enc-dec archs"
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(zoo.prefill_fn(cfg, scfg.max_len))
        self._decode = jax.jit(zoo.decode_fn(cfg))

    def _sample(self, logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def serve(self, requests: List[Request]) -> List[Request]:
        """Process all requests; batches of ``batch_slots`` at a time.

        Requests inside one batch share a prompt length (padded); decode
        runs to the max requested new tokens with per-slot early stop."""
        out: List[Request] = []
        for wave in wave_batches(list(requests), self.scfg.batch_slots):
            out.extend(self._serve_wave(wave))
        return out

    def _serve_wave(self, wave: List[Request]) -> List[Request]:
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        cache_len = jnp.int32(plen)
        cur = self._sample(logits)[:, None]
        budget = max(r.max_new_tokens for r in wave)
        gen = [cur]
        for t in range(budget - 1):
            logits, caches = self._decode(self.params, caches, cur, cache_len)
            cache_len = cache_len + 1
            cur = self._sample(logits)[:, None]
            gen.append(cur)
        g = np.asarray(jnp.concatenate(gen, axis=1))
        for i, r in enumerate(wave):
            r.output = g[i, : r.max_new_tokens]
        return wave


def throughput_stats(n_tokens: int, seconds: float) -> Dict[str, float]:
    return {"tokens": n_tokens, "seconds": seconds,
            "tok_per_s": n_tokens / max(seconds, 1e-9)}
