"""Resilient continuous-ingest driver: WAL → apply → refresh → snapshot.

PR 4 made edge churn a one-shot call (``refresh_embedding``); the ROADMAP
asks for continuous ingestion at production cadence, and at production
cadence the driver must survive crashes at ANY point of its own protocol.
This module is that driver. The durability protocol per churn batch is

    append   — the ``EdgeBatch`` is serialized into a write-ahead log
               record (length + CRC32 framed) and **fsynced** before the
               driver acknowledges it: an accepted batch can never be
               lost, only re-applied;
    apply    — the batch is staged into the ``DeltaCSR`` overlay;
    refresh  — the incremental refresh absorbs the staged churn (subset
               re-walk + in-place fine-tune), with bounded retry and
               exponential backoff — each retry RESTORES the pipeline
               from the last snapshot first, so a half-applied refresh is
               never retried on top of itself;
    snapshot — the pipeline checkpoints (atomic, fsynced) with the WAL
               sequence number it now covers (``applied_seq``);
    truncate — WAL records at or below ``applied_seq`` are dropped (atomic
               rewrite): the log only ever holds churn the snapshot does
               not.

Crash recovery (``IngestDriver.recover``) inverts the protocol: restore
the newest valid snapshot, replay the un-truncated WAL tail (records past
the snapshot's ``applied_seq``; a torn final record — the crash landed
mid-append — is detected by the CRC frame and discarded), and re-run
apply → refresh → snapshot → truncate. Because refresh re-walks under the
original round keys and fine-tunes under persisted step-keyed RNG, the
recovered state is bit-identical to a run that never crashed.

Bounded staleness: ``staleness()`` accounts appended-vs-applied sequence
numbers and pending churn volume; ``IngestConfig.max_pending_edges`` turns
the bound into backpressure (a submit that crosses it forces a refresh
instead of letting the embedding drift arbitrarily far behind the graph).

SLO-driven degradation (DESIGN.md §12): ``IngestConfig.staleness_slo_s``
sets a wall-clock deadline per batch — submit → applied within that many
seconds. Each drain picks the cheapest refresh mode that (predicted by a
per-mode wall-clock EMA, with headroom) still fits the oldest pending
batch's remaining budget: ``full`` → ``no_finetune`` (exact walks, phi
lags) → ``detect_only`` (graph adoption + affected-set detection only;
the affected roots accumulate as DEBT and are re-walked by the next
non-degraded drain). Per-batch submit→applied latency percentiles, the
chosen modes, SLO violations and outstanding debt are all surfaced
through ``staleness()``.

Admission control: ``submit`` validates batches
(``graph.delta.validate_edge_batch``) BEFORE the WAL append — a
malformed batch (out-of-range ids, NaN weights) must be rejected at the
door, not become durable and crash every replay of the log.
"""

from __future__ import annotations

import dataclasses
import io
import os
import struct
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.ckpt.checkpoint import read_meta
from repro.common.logging import get_logger, log_context
from repro.graph.delta import EdgeBatch, validate_edge_batch
from repro.runtime.faults import FaultInjector, NULL_INJECTOR

log = get_logger("repro.runtime.ingest")

_HEADER = struct.Struct("<QII")          # (seq, payload_len, crc32)


def _encode_batch(batch: EdgeBatch) -> bytes:
    buf = io.BytesIO()
    arrays = {"insert": batch.insert, "delete": batch.delete}
    if batch.insert_weights is not None:
        arrays["insert_weights"] = batch.insert_weights
    np.savez(buf, **arrays)
    return buf.getvalue()


def _decode_batch(payload: bytes) -> EdgeBatch:
    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        return EdgeBatch(
            insert=z["insert"], delete=z["delete"],
            insert_weights=(z["insert_weights"]
                            if "insert_weights" in z.files else None))


class WriteAheadLog:
    """Append-only, CRC-framed, fsync-on-append batch log.

    Record layout: ``<QII`` header (monotonic seq, payload length, CRC32 of
    the payload) followed by the payload (an npz of the batch arrays).
    ``replay`` stops at the first torn record — a short header, a short
    payload, or a CRC mismatch all mean the crash landed mid-append, and
    everything from that offset on is garbage by construction (records are
    written in order and fsynced before acknowledgement).
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    # -- write side --------------------------------------------------------
    def append(self, seq: int, batch: EdgeBatch,
               faults: FaultInjector = NULL_INJECTOR) -> int:
        payload = _encode_batch(batch)
        record = _HEADER.pack(seq, len(payload),
                              zlib.crc32(payload)) + payload
        if faults.torn("wal"):
            # Crash mid-append: only a prefix of the record reaches disk.
            with open(self.path, "ab") as f:
                f.write(record[:max(1, len(record) // 2)])
                f.flush()
                os.fsync(f.fileno())
            from repro.runtime.faults import SimulatedFailure
            raise SimulatedFailure(f"torn WAL append at seq {seq}")
        with obs.trace_span("ingest.wal_append", seq=seq,
                            bytes=len(record)):
            with open(self.path, "ab") as f:
                f.write(record)
                f.flush()
                os.fsync(f.fileno())
        obs.inc("ingest.wal_bytes", len(record))
        return seq

    # -- read side ---------------------------------------------------------
    def replay(self, after_seq: int = 0
               ) -> Tuple[List[Tuple[int, EdgeBatch]], int]:
        """(records with seq > after_seq, valid_prefix_bytes). Torn tails
        are detected, reported, and excluded."""
        if not os.path.exists(self.path):
            return [], 0
        with open(self.path, "rb") as f:
            data = f.read()
        records, off = [], 0
        while off + _HEADER.size <= len(data):
            seq, length, crc = _HEADER.unpack_from(data, off)
            body = data[off + _HEADER.size: off + _HEADER.size + length]
            if len(body) < length or zlib.crc32(body) != crc:
                log.warning("WAL %s: torn record at offset %d (seq %d) — "
                            "discarding tail", self.path, off, seq)
                break
            if seq > after_seq:
                records.append((seq, _decode_batch(body)))
            off += _HEADER.size + length
        else:
            if off < len(data):
                log.warning("WAL %s: %d trailing bytes (torn header) — "
                            "discarding", self.path, len(data) - off)
        return records, off

    def truncate_upto(self, applied_seq: int) -> None:
        """Atomically drop records with seq <= applied_seq (and any torn
        tail). The usual steady state truncates to an empty log."""
        keep, _ = self.replay(after_seq=applied_seq)
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for seq, batch in keep:
                payload = _encode_batch(batch)
                f.write(_HEADER.pack(seq, len(payload),
                                     zlib.crc32(payload)) + payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        dir_fd = os.open(os.path.dirname(self.path) or ".", os.O_RDONLY)
        try:
            os.fsync(dir_fd)
        finally:
            os.close(dir_fd)


@dataclasses.dataclass
class IngestConfig:
    apply_every: int = 1            # WAL batches per refresh application
    max_pending_edges: Optional[int] = None   # staleness bound (backpressure)
    max_retries: int = 3            # refresh retries (after restore) per drain
    backoff_s: float = 0.05         # exponential: backoff_s * 2**attempt
    snapshot_dir: str = "snapshots"
    wal_name: str = "wal.log"
    # -- admission control (validate BEFORE the WAL append) -----------------
    validate: bool = True
    self_loop_policy: str = "drop"        # "drop" | "forbid" | "allow"
    duplicate_policy: str = "allow"       # same choices, within-batch dups
    # -- staleness SLO / degrade ladder (DESIGN.md §12) ---------------------
    staleness_slo_s: Optional[float] = None   # submit->applied deadline
    slo_headroom: float = 1.5       # mode fits if ema * headroom <= budget
    latency_window: int = 64        # submit->applied percentile history


class IngestDriver:
    """Long-running churn driver around one ``StreamingEmbedPipeline``.

    ``submit`` is the ingress: batches become durable in the WAL
    immediately and are absorbed (apply → refresh → snapshot → truncate)
    every ``apply_every`` batches, or sooner when ``max_pending_edges``
    backpressure trips, or explicitly via ``drain()``. ``recover`` rebuilds
    a driver after a process death from the snapshot + WAL tail alone.
    """

    def __init__(self, root: str, pipeline, *,
                 detect: str = "traversal",
                 cfg: IngestConfig = IngestConfig(),
                 refresh_kwargs: Optional[Dict[str, Any]] = None,
                 faults: FaultInjector = NULL_INJECTOR,
                 sleep: Callable[[float], None] = time.sleep,
                 clock: Callable[[], float] = time.monotonic,
                 server: Optional[Any] = None,
                 _initial_snapshot: bool = True):
        from repro.core.incremental import IncrementalRefresh

        self.root = root
        self.cfg = cfg
        self.detect = detect
        self.refresh_kwargs = dict(refresh_kwargs or {})
        self.faults = faults
        self.sleep = sleep
        self.clock = clock
        self.server = server            # optional EmbedServer to publish to
        self.pipeline = pipeline
        self.refresher = IncrementalRefresh(pipeline, detect=detect)
        self.ckpt_dir = os.path.join(root, cfg.snapshot_dir)
        self.wal = WriteAheadLog(os.path.join(root, cfg.wal_name))
        self.applied_seq = 0
        self.appended_seq = 0
        self._pending: List[Tuple[int, EdgeBatch]] = []
        self.drains = 0
        self.retries = 0
        # SLO / degrade-ladder state (DESIGN.md §12). The latency history
        # is an obs.Histogram: one bounded reservoir serves both the
        # staleness() percentiles and the exported ingest.latency_s
        # metric. The driver owns the object (its window follows
        # cfg.latency_window and a fresh driver starts empty); attach()
        # makes the registry export it.
        self._submit_t: Dict[int, float] = {}
        self._latency = obs.Histogram(window=max(cfg.latency_window, 1))
        obs.REGISTRY.attach("ingest.latency_s", self._latency)
        self._wall_ema: Dict[str, float] = {}
        self.mode_counts = {"full": 0, "no_finetune": 0, "detect_only": 0}
        self.last_mode: Optional[str] = None
        self.slo_violations = 0
        self._debt: Optional[np.ndarray] = None   # deferred affected roots
        if _initial_snapshot:
            # The recovery base: a driver must never hold churn the WAL
            # covers without a snapshot to replay it against.
            self._snapshot()
            self._publish()

    # -- ingress -----------------------------------------------------------
    def submit(self, batch: EdgeBatch) -> int:
        """Durably accept one churn batch; absorb when the cadence or the
        staleness bound says so. Returns the batch's WAL sequence number.

        Validation happens BEFORE the WAL append: a rejected batch raises
        ``ValueError`` and leaves no trace — neither the log nor the seq
        counter advances."""
        if self.cfg.validate:
            batch = validate_edge_batch(
                batch, self.pipeline.graph.num_nodes,
                self_loops=self.cfg.self_loop_policy,
                duplicates=self.cfg.duplicate_policy)
        seq = self.appended_seq + 1
        with obs.trace_span("ingest.submit", seq=seq,
                            graph_version=self._graph_version()):
            self.wal.append(seq, batch, faults=self.faults)
            self.appended_seq = seq
            self._pending.append((seq, batch))
            self._submit_t[seq] = self.clock()
            self.faults.fire("wal_append", seq)
        over_staleness = (
            self.cfg.max_pending_edges is not None
            and self.pending_edges() > self.cfg.max_pending_edges)
        if len(self._pending) >= self.cfg.apply_every or over_staleness:
            self.drain()
        return seq

    def pending_edges(self) -> int:
        return sum(b.num_changes for _, b in self._pending)

    def staleness(self) -> Dict[str, Any]:
        """Bounded-staleness accounting: how far the served embedding lags
        the accepted churn — sequence lag, wall-clock lag (submit→applied
        latency percentiles, oldest pending age vs the SLO), degrade-mode
        history and outstanding detect-only debt."""
        pct = {f"latency_p{q}_s": self._latency.percentile(q)
               for q in (50, 90, 99)}
        oldest = (self._submit_t.get(self._pending[0][0])
                  if self._pending else None)
        return {
            "appended_seq": self.appended_seq,
            "applied_seq": self.applied_seq,
            "pending_batches": len(self._pending),
            "pending_edges": self.pending_edges(),
            "max_pending_edges": self.cfg.max_pending_edges,
            "graph_version": self._graph_version(),
            "drains": self.drains,
            "retries": self.retries,
            **pct,
            "oldest_pending_age_s": (self.clock() - oldest
                                     if oldest is not None else None),
            "staleness_slo_s": self.cfg.staleness_slo_s,
            "slo_violations": self.slo_violations,
            "last_mode": self.last_mode,
            "mode_counts": dict(self.mode_counts),
            "debt_roots": (int(self._debt.sum())
                           if self._debt is not None else 0),
            "wall_ema_s": dict(self._wall_ema),
        }

    def _graph_version(self) -> int:
        from repro.graph.delta import graph_version
        return int(graph_version(self.pipeline.graph))

    # -- absorption --------------------------------------------------------
    def _choose_mode(self) -> str:
        """Pick the cheapest refresh mode that still fits the oldest
        pending batch's remaining SLO budget (predicted by the per-mode
        wall EMA with headroom). No SLO → always full. A mode never run
        has no EMA and is optimistically assumed to fit — the ladder needs
        one measurement before it can shed. A blown budget sheds straight
        to detect_only (the deadline is already lost; spend the least)."""
        cfg = self.cfg
        if cfg.staleness_slo_s is None or not self._pending:
            return "full"
        oldest = self._submit_t.get(self._pending[0][0])
        if oldest is None:                      # recovered batch: no clock
            return "full"
        budget = cfg.staleness_slo_s - (self.clock() - oldest)
        if budget <= 0:
            return "detect_only"
        for mode in ("full", "no_finetune", "detect_only"):
            ema = self._wall_ema.get(mode)
            if ema is None or ema * cfg.slo_headroom <= budget:
                return mode
        return "detect_only"

    def drain(self) -> Optional[Any]:
        """Absorb all pending batches: apply → refresh (bounded retry with
        restore-from-snapshot between attempts) → snapshot → truncate.
        The refresh runs at the degrade-ladder mode the SLO budget allows;
        a detect-only drain banks its affected roots as debt, paid (as
        ``extra_affected``) by the next non-degraded drain."""
        if not self._pending:
            return None
        batches = list(self._pending)
        last_seq = batches[-1][0]
        mode = self._choose_mode()
        # log_context stays unconditional (log fields must not depend on
        # telemetry being enabled); the span nests inside with the same
        # fields.
        with log_context(applied_seq=self.applied_seq, target_seq=last_seq,
                         graph_version=self._graph_version(), mode=mode), \
                obs.trace_span("ingest.drain", applied_seq=self.applied_seq,
                               target_seq=last_seq, mode=mode):
            stats = self._apply_with_retry(batches, mode)
            self.applied_seq = last_seq
            self._pending = []
            self._snapshot()
            self.wal.truncate_upto(self.applied_seq)
            if self.server is not None:
                self.server.note_refresh("ok")
            self._publish()
            self.drains += 1
            now = self.clock()
            for seq, _ in batches:
                t = self._submit_t.pop(seq, None)
                if t is None:
                    continue
                self._latency.observe(now - t)
                if (self.cfg.staleness_slo_s is not None
                        and now - t > self.cfg.staleness_slo_s):
                    self.slo_violations += 1
                    obs.inc("ingest.slo_violations")
            self.mode_counts[mode] += 1
            self.last_mode = mode
            obs.inc("ingest.drains")
            obs.inc(f"ingest.mode.{mode}")
            obs.set_gauge("ingest.applied_seq", self.applied_seq)
            obs.set_gauge("ingest.graph_version", self._graph_version())
            wall = float(getattr(stats, "wall_s", 0.0))
            obs.observe("ingest.refresh.s", wall)
            prev = self._wall_ema.get(mode)
            self._wall_ema[mode] = (wall if prev is None
                                    else 0.5 * prev + 0.5 * wall)
            if mode == "detect_only":
                m = np.asarray(self.refresher.last_affected_mask, bool)
                self._debt = m.copy() if self._debt is None \
                    else (self._debt | m)
            else:
                self._debt = None        # paid via extra_affected
            log.info("drained %d batches (%d edges) in %s refresh: "
                     "affected=%s wall=%.3fs", len(batches),
                     sum(b.num_changes for _, b in batches), mode,
                     getattr(stats, "affected", "?"),
                     getattr(stats, "wall_s", float("nan")))
        return stats

    def _apply_with_retry(self, batches, mode: str = "full") -> Any:
        cfg = self.cfg
        extra = self._debt if mode != "detect_only" else None
        for attempt in range(cfg.max_retries + 1):
            try:
                for _, b in batches:
                    self.refresher.apply_updates(b)
                return self.refresher.refresh(faults=self.faults,
                                              mode=mode,
                                              extra_affected=extra,
                                              **self.refresh_kwargs)
            except Exception as e:
                # A failed refresh may have spliced part of the ring /
                # mutated the overlay: restore the pre-churn snapshot
                # before any retry so the batch is never applied on top
                # of its own wreckage. An attached server moves to the
                # stale-ok rung immediately — readers keep the last good
                # version while the retry loop runs.
                obs.span_event("ingest.retry", attempt=attempt,
                               error=type(e).__name__)
                if self.server is not None:
                    self.server.note_refresh("degraded")
                self._restore_last_snapshot()
                if attempt >= cfg.max_retries:
                    if self.server is not None:
                        self.server.note_refresh("failed")
                    obs.dump_flight_record(
                        "ingest_retries_exhausted", attempt=attempt,
                        error=type(e).__name__, mode=mode)
                    raise
                self.retries += 1
                obs.inc("ingest.retries")
                delay = cfg.backoff_s * (2 ** attempt)
                log.warning("refresh attempt %d failed (%s: %s); restored "
                            "snapshot, backing off %.3fs", attempt,
                            type(e).__name__, e, delay)
                self.sleep(delay)

    def _snapshot(self) -> None:
        self.pipeline.save(self.ckpt_dir, faults=self.faults,
                           meta_extra={"applied_seq": int(self.applied_seq),
                                       "ingest": True})

    def _publish(self) -> None:
        """Offer the newest snapshot to the attached ``EmbedServer``.

        Serve-side failures — a torn candidate, a gate rejection, a
        swap-window fault drill — must never take down ingest: the server
        keeps its active version (flight-recording terminal cases
        itself), and the NEXT snapshot is simply offered again."""
        if self.server is None:
            return
        try:
            self.server.offer_snapshot(self.ckpt_dir)
        except Exception as e:
            obs.inc("ingest.publish_failed")
            log.warning("snapshot publish failed (%s: %s); server keeps "
                        "its active version", type(e).__name__, e)

    def _restore_last_snapshot(self) -> None:
        from repro.core.incremental import IncrementalRefresh
        from repro.runtime.trainer import StreamingEmbedPipeline

        self.pipeline = StreamingEmbedPipeline.resume(
            self.ckpt_dir, self.pipeline.policy, self.pipeline.spec,
            self.pipeline.cfg)
        self.refresher = IncrementalRefresh(self.pipeline,
                                            detect=self.detect)

    # -- crash recovery ----------------------------------------------------
    @classmethod
    def recover(cls, root: str, policy, spec, dsgl_cfg, *,
                detect: str = "traversal",
                cfg: IngestConfig = IngestConfig(),
                refresh_kwargs: Optional[Dict[str, Any]] = None,
                faults: FaultInjector = NULL_INJECTOR,
                sleep: Callable[[float], None] = time.sleep,
                clock: Callable[[], float] = time.monotonic,
                server: Optional[Any] = None,
                ) -> "IngestDriver":
        """Rebuild a driver after a crash: newest valid snapshot + WAL tail.

        Replays every durable-but-unapplied batch through the normal
        absorption path (apply → refresh → snapshot → truncate), so a
        recovered driver ends in exactly the state the crashed one was
        headed for — including the case where the crash hit mid-refresh or
        mid-snapshot (the torn artifact is skipped by the validating
        loaders) or mid-append (the torn WAL record is dropped; that batch
        was never acknowledged).
        """
        from repro.runtime.trainer import StreamingEmbedPipeline

        ckpt_dir = os.path.join(root, cfg.snapshot_dir)
        step, meta = read_meta(ckpt_dir)
        pipeline = StreamingEmbedPipeline.resume(
            ckpt_dir, policy, spec, dsgl_cfg, step=step)
        driver = cls(root, pipeline, detect=detect, cfg=cfg,
                     refresh_kwargs=refresh_kwargs, faults=faults,
                     sleep=sleep, clock=clock, server=server,
                     _initial_snapshot=False)
        driver.applied_seq = int(meta.get("applied_seq", 0))
        tail, _ = driver.wal.replay(after_seq=driver.applied_seq)
        driver.appended_seq = (tail[-1][0] if tail else driver.applied_seq)
        with log_context(applied_seq=driver.applied_seq,
                         wal_tail=len(tail)):
            log.info("recovering ingest driver from snapshot %d + %d WAL "
                     "tail batches", step, len(tail))
        if tail:
            driver._pending = tail
            driver.drain()
        else:
            # Nothing to replay; still drop any torn tail bytes.
            driver.wal.truncate_upto(driver.applied_seq)
            driver._publish()
        return driver

    def embeddings(self):
        return self.pipeline.embeddings()
