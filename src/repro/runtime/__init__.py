"""Distributed runtime: trainer (fault-tolerant), server, elasticity."""
