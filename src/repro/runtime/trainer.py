"""Fault-tolerant trainers: the LM trainer (sharded step, checkpoint/
restart, failure injection, straggler-mitigated input pipeline) and the
device-resident DSGL embedding trainer.

The LM step function is the same one the dry-run lowers (launch/steps.py);
this module adds the *runtime* posture around it:

* step-granular checkpoints (params + opt state + data cursor + RNG),
  atomic commit, restore-and-continue is bit-exact (tested);
* ``FailureInjector`` raises a simulated node failure at a chosen step;
  ``run_with_restarts`` demonstrates the restart loop a cluster agent
  would drive — resume from the latest checkpoint, replay nothing;
* data fetches go through ``BackupShardFetcher`` (speculative backup after
  a deadline) so one slow host does not stall the step (straggler policy).

``DSGLTrainer`` is the embedding-side runtime: per-shard walk streams
assemble (C, S, G, W, T) chunks on a prefetch thread while the device runs
the previous chunk's fused ``train_chunk`` scan — host work and device
work overlap, and the device never waits on per-step negative sampling or
uploads (the NOMAD overlap argument, on one process).

``StreamingEmbedPipeline`` fuses the two halves of DistGER end to end:
the partition-sharded walk engine appends finished rounds into a
device-resident ``CorpusRing`` and the DSGL learner consumes ring slots as
stacked shard chunks via one device gather — walks never round-trip
through host numpy, and round r+1's walk generation is dispatched before
round r's training so the two overlap (walk rounds stay gated by the
Eq. 7 ΔD controller). See DESIGN.md §9.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt.checkpoint import (
    latest_step, load_checkpoint, restore_into, save_checkpoint,
)
from repro.common.logging import get_logger, log_context
from repro.data.pipeline import BackupShardFetcher, TokenStream
from repro.models import zoo
from repro.models.config import ModelConfig
from repro.optim.optimizers import AdamWConfig, init_opt_state, opt_update
from repro.optim.schedules import cosine_warmup

# The fault-injection machinery lives in repro.runtime.faults; the names
# are re-exported here because this module introduced them (existing tests
# and callers import them from repro.runtime.trainer).
from repro.runtime.faults import (            # noqa: F401  (re-export)
    NULL_INJECTOR, FailureInjector, FaultInjector, SimulatedFailure,
)

log = get_logger("repro.runtime.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 20
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    batch: int = 4
    seq_len: int = 64
    lr: float = 3e-4
    warmup: int = 10
    seed: int = 0
    straggler_deadline_s: float = 5.0


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, schedule):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    loss_of = zoo.loss_fn(cfg)

    def step_fn(params, opt_state, batch, step):
        lr = schedule(step)
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params, opt_state, gnorm = opt_update(
            grads, opt_state, params, opt_cfg, lr)
        return params, opt_state, {"loss": loss, "gnorm": gnorm, "lr": lr}

    return jax.jit(step_fn, donate_argnums=(0, 1))


class Trainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 injector: Optional[FailureInjector] = None,
                 delay_injector: Optional[Callable[[int], float]] = None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.injector = injector or FailureInjector()
        opt_cfg = AdamWConfig(moment_dtype=model_cfg.opt_state_dtype)
        self.opt_cfg = opt_cfg
        self.schedule = cosine_warmup(tcfg.lr, tcfg.warmup, tcfg.steps)
        self.step_fn = make_train_step(model_cfg, opt_cfg, self.schedule)

        stream = TokenStream(
            vocab_size=model_cfg.vocab_size, batch_per_shard=tcfg.batch,
            seq_len=tcfg.seq_len, seed=tcfg.seed)
        self.fetcher = BackupShardFetcher(
            primary=stream.batch_at, backup=stream.batch_at,
            deadline_s=tcfg.straggler_deadline_s,
            delay_injector=delay_injector)
        self.metrics_log: list = []

    # --- state ----------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = zoo.init_params(key, self.model_cfg)
        opt_state = init_opt_state(params, self.opt_cfg)
        return {"params": params, "opt": opt_state}

    def save(self, state, step: int):
        save_checkpoint(self.tcfg.ckpt_dir, step, state,
                        meta={"data_step": step, "seed": self.tcfg.seed})

    def try_restore(self, template):
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return None, 0
        _, arrays, meta = load_checkpoint(self.tcfg.ckpt_dir, last)
        state = restore_into(template, arrays)
        return state, int(meta["data_step"])

    # --- loops ----------------------------------------------------------------
    def run(self, start_state=None, start_step: int = 0) -> Dict[str, Any]:
        """Run to completion or until an (injected) failure propagates."""
        state = start_state if start_state is not None else self.init_state()
        step = start_step
        while step < self.tcfg.steps:
            self.injector.check(step)
            batch_np = self.fetcher.fetch(step)
            if "labels" in batch_np and self.model_cfg.encdec:
                batch_np = dict(batch_np)
                batch_np["frames"] = np.random.default_rng(step).normal(
                    size=(self.tcfg.batch, self.tcfg.seq_len // 2,
                          self.model_cfg.d_model)).astype(np.float32)
            batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
            params, opt, metrics = self.step_fn(
                state["params"], state["opt"], batch, jnp.int32(step))
            state = {"params": params, "opt": opt}
            self.metrics_log.append(
                {k: float(v) for k, v in metrics.items()} | {"step": step})
            step += 1
            if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                self.save(state, step)
        return {"state": state, "final_step": step,
                "metrics": self.metrics_log,
                "straggler_stats": self.fetcher.stats}

    def run_with_restarts(self, max_restarts: int = 4) -> Dict[str, Any]:
        """The cluster-agent loop: restart from the latest checkpoint on
        failure. Demonstrates end-to-end checkpoint/restart fault tolerance."""
        template = self.init_state()
        restarts = 0
        while True:
            state, start = self.try_restore(template)
            if state is None:
                state, start = template, 0
            try:
                out = self.run(start_state=state, start_step=start)
                out["restarts"] = restarts
                return out
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise


# ---------------------------------------------------------------------------
# Device-resident DSGL embedding trainer
# ---------------------------------------------------------------------------


class DSGLTrainer:
    """Chunked, prefetched driver around ``core.dsgl.train_chunk``.

    Host side: one ``WalkCorpusStream`` per shard replica; a ``Prefetcher``
    thread stacks the next (C, S, G, W, T) chunk while the device runs the
    current one. Device side: stacked replica matrices stay resident across
    the whole run — per chunk there is exactly one walk upload, one fused
    scan over C lifetimes (negatives drawn in-jit from the alias table) and,
    in the sharded regime, one hotness-row exchange.
    """

    def __init__(self, walks_rank: np.ndarray, order, cfg,
                 *, num_shards: int = 1, prefetch_depth: int = 2):
        from repro.core import sync as sync_mod
        from repro.core.dsgl import build_alias_table, init_embeddings
        from repro.data.pipeline import WalkCorpusStream, stacked_shard_chunk

        self.cfg = cfg
        self.num_shards = num_shards
        self.order = order
        self.chunk = max(cfg.sync_period, 1)
        self.streams = [
            WalkCorpusStream(
                walks=walks_rank, group_size=cfg.batch_groups,
                multi_windows=cfg.multi_windows, seed=cfg.seed,
                shard_id=s, num_shards=num_shards)
            for s in range(num_shards)
        ]
        self._stack = stacked_shard_chunk
        self._sync = sync_mod
        self.starts, self.ends = order.hotness_blocks()
        self.neg_table = build_alias_table(order.sorted_ocn, cfg.neg_power)
        self.prefetch_depth = prefetch_depth

        n = len(order.to_rank)
        key = jax.random.PRNGKey(cfg.seed)
        keys = jax.random.split(key, num_shards + 1)
        self.key = keys[0]
        reps = [init_embeddings(n, cfg.dim, k) for k in keys[1:]]
        self.phi_in = jnp.stack([r[0] for r in reps])
        self.phi_out = jnp.stack([r[1] for r in reps])

    def steps_per_epoch(self) -> int:
        return min(s.steps_per_epoch() for s in self.streams)

    def _lrs(self, global_step: int, count: int, total: int) -> jnp.ndarray:
        fracs = (global_step + np.arange(count)) / max(total, 1)
        return jnp.asarray(
            np.maximum(self.cfg.lr * (1.0 - fracs), self.cfg.min_lr),
            jnp.float32)

    def run(self) -> Dict[str, Any]:
        from repro.core.dsgl import train_chunk
        from repro.data.pipeline import Prefetcher

        cfg = self.cfg
        spe = self.steps_per_epoch()
        total = cfg.epochs * spe
        rng = np.random.default_rng(cfg.seed)

        # Chunk schedule clamped at epoch boundaries (each epoch is its own
        # shuffle; a chunk must not wrap into re-trained duplicates of the
        # previous epoch or overrun the configured step count).
        schedule = [
            (epoch, step0, min(step0 + self.chunk, spe) - step0)
            for epoch in range(cfg.epochs)
            for step0 in range(0, spe, self.chunk)
        ]

        def fetch(chunk_idx: int) -> np.ndarray:
            epoch, step0, count = schedule[chunk_idx % len(schedule)]
            return self._stack(self.streams, epoch, step0, count)

        prefetcher = Prefetcher(fetch, depth=self.prefetch_depth)
        losses: list = []
        t0 = time.perf_counter()
        sync_bytes = 0.0
        do_sync = self.num_shards > 1
        # Hot loop: telemetry is a flag check when off, two clock reads +
        # a histogram add when on (the obs_overhead bench measures this).
        tele = obs.enabled()
        try:
            for c, (epoch, step0, count) in enumerate(schedule):
                t_c = time.perf_counter() if tele else 0.0
                _, chunk_np = prefetcher.next()
                wb = jnp.asarray(chunk_np)
                rows = (jnp.asarray(self._sync.sample_hotness_rows(
                    self.starts, self.ends, rng), jnp.int32)
                    if do_sync else jnp.zeros(0, jnp.int32))
                self.key, sub = jax.random.split(self.key)
                self.phi_in, self.phi_out, loss = train_chunk(
                    self.phi_in, self.phi_out, wb, self.neg_table, rows, sub,
                    self._lrs(epoch * spe + step0, count, total),
                    cfg.window, cfg.negatives, cfg.use_kernel, do_sync)
                losses.append(loss)
                if do_sync:
                    sync_bytes += float(
                        rows.size * cfg.dim * 4 * self.num_shards * 2)
                if tele:
                    obs.observe("train.chunk_dispatch.s",
                                time.perf_counter() - t_c)
                    obs.inc("train.steps", count)
        finally:
            prefetcher.close()
        jax.block_until_ready(self.phi_in)
        wall = time.perf_counter() - t0
        steps = total
        if tele:
            obs.set_gauge("train.steps_per_s", steps / max(wall, 1e-9))
            obs.set_gauge("train.sync_bytes", sync_bytes)
        return {
            "steps": steps,
            "steps_per_s": steps / max(wall, 1e-9),
            "loss": [float(v) for v in
                     np.concatenate([np.asarray(l).reshape(-1)
                                     for l in losses])],
            "sync_bytes": sync_bytes,
            "wall_s": wall,
        }

    def embeddings(self):
        """(phi_in, phi_out) in rank space, replica-averaged."""
        if self.num_shards > 1:
            return (jnp.mean(self.phi_in, axis=0),
                    jnp.mean(self.phi_out, axis=0))
        return self.phi_in[0], self.phi_out[0]


# ---------------------------------------------------------------------------
# Fused walk→train streaming pipeline
# ---------------------------------------------------------------------------


class StreamingEmbedPipeline:
    """partition-sharded walks → device corpus ring → DSGL, overlapped.

    Per round r the host (1) syncs once on the (|V|,) occurrence counts —
    the Eq. 7 controller input (gated on a WINDOWED-mean ΔD when
    ``rounds_cfg["window"]`` > 1, which keeps tight deltas from pinning
    small-graph runs at max_rounds on sampling noise — DESIGN.md §9), also
    reused to rebuild the node-space negative alias table and the hotness
    blocks; (2) if the controller says continue, DISPATCHES round r+1's
    walks; (3) enqueues round r's training chunks, whose (C, S, G, W, T)
    input is one device gather from the ring
    (``data.pipeline.ring_chunk_indices``). Walks therefore never
    leave the device between sampler and learner, and on a multi-device
    mesh the walk shards compute round r+1 while the trainer replicas run
    round r (on one device the queues interleave; the host never stalls).

    Embeddings stay in NODE space (no rank relabeling is needed because
    the frequency order evolves with the stream); hotness-block sync rows
    are mapped rank→node per round. The learning-rate schedule is fixed a
    priori at ``epochs * max_rounds * steps_per_round`` steps — the walk
    controller decides the corpus, not the schedule — and after sampling
    stops the pipeline keeps consuming re-shuffled ring slots until the
    schedule completes (the word2vec single-decayed-pass convention,
    §6.4 recipe).

    ``overlap=False`` serializes the phases (block after every walk round
    and every train call) — the baseline the walk→train overlap-efficiency
    benchmark compares against.
    """

    def __init__(self, graph, policy, spec, rounds_cfg: Dict, dsgl_cfg,
                 *, assignment: Optional[np.ndarray] = None,
                 num_shards: int = 1, walker_batch: int = 4096,
                 overlap: bool = True, health=None):
        from repro.core.corpus import CorpusRing
        from repro.core.dsgl import init_embeddings
        from repro.core.termination import WalkCountController

        if getattr(policy, "needs_edge_cm", False) and graph.edge_cm is None:
            graph = graph.with_edge_cm()
        self.graph = graph
        self.policy = policy
        self.spec = spec
        self.cfg = dsgl_cfg
        self.num_shards = max(num_shards, 1)
        # Walk-dispatch shard count. It starts equal to the DSGL replica
        # count but the two are independent degrees of freedom: elastic
        # reconfiguration drops walk_shards to k-1 when a shard dies while
        # the (S, N, d) replica stack — a TRAINING ensemble choice baked
        # into phi's shape — stays at S.
        self.walk_shards = self.num_shards
        self.assignment = (None if assignment is None
                           else jnp.asarray(assignment, jnp.int32))
        self.walker_batch = walker_batch
        self.overlap = overlap
        # Self-healing runtime state (DESIGN.md §12): the optional health
        # watchdog, the divergence-rollback lr multiplier (persisted — a
        # backed-off run resumes backed off), and the elastic-reconfig log.
        self.health = health
        self._lr_scale = 1.0
        self._reconfigs: list = []
        self._faults: FaultInjector = NULL_INJECTOR
        # Snapshot hand-off hooks (DESIGN.md §14): called with
        # (path, seq, meta) after every COMMITTED snapshot — the serving
        # side subscribes here to learn that a new candidate version
        # exists. Never called for torn/crashed writes.
        self._snapshot_hooks: list = []
        self.controller = WalkCountController(**rounds_cfg)
        self.degrees = np.asarray(graph.degrees(), dtype=np.int64)

        n = graph.num_nodes
        self.sources = np.arange(n, dtype=np.int32)
        # Retain as many full rounds as fit a ~0.5 GB slot budget; older
        # rounds retire on wrap (training reads the current round's slots
        # plus, in the tail, whatever is retained; ocn accumulates across
        # wraps). One round is the floor — the round-aligned slot map needs
        # it resident — so a graph whose single round cannot fit the int32
        # occurrence guard must use the host-spilling two-phase path.
        budget_rounds = max(1, (1 << 27) // max(spec.max_len * n, 1))
        self.ring_rounds = min(self.controller.max_rounds, budget_rounds)
        if self.ring_rounds * n * spec.max_len >= 2**31:
            raise ValueError(
                f"one walk round (|V|={n} x max_len={spec.max_len}) exceeds "
                "the device corpus-ring budget; use "
                "embed_graph(streaming=False), which spills rounds to host")
        self.ring = CorpusRing.create(self.ring_rounds * n, spec.max_len, n)
        per = dsgl_cfg.batch_groups * dsgl_cfg.multi_windows
        self.steps_per_round = max(n // self.num_shards // per, 1)
        self.total_steps = (dsgl_cfg.epochs * self.controller.max_rounds
                            * self.steps_per_round)
        self.global_step = 0

        key = jax.random.PRNGKey(dsgl_cfg.seed)
        self.key_walk, self.key_train, *rep_keys = jax.random.split(
            key, 2 + self.num_shards)
        reps = [init_embeddings(n, dsgl_cfg.dim, k) for k in rep_keys]
        self.phi_in = jnp.stack([r[0] for r in reps])      # (S, N, d)
        self.phi_out = jnp.stack([r[1] for r in reps])
        # Device-accumulated walk stats: summed without forcing a sync.
        self._stats = {k: jnp.zeros(()) for k in (
            "supersteps", "accepts", "rejects", "msg_count", "msg_bytes",
            "msg_bytes_analytic")}
        self._ft = None       # (start_step, total, lr0) fine-tune schedule
        # Host mirror of the ring layout: which ROOT VERTEX and which walk
        # ROUND each slot currently holds (-1 = never written). Maintained
        # from the host-known dispatch chunks — no device sync — so the
        # incremental refresh can locate every resident walk of an
        # affected vertex (and the round key that produced it) even after
        # partial extra rounds or ring wraps, where slot arithmetic fails.
        self._slot_root = np.full(self.ring.capacity, -1, np.int64)
        self._slot_round = np.full(self.ring.capacity, -1, np.int64)
        self._cursor = 0
        self._rounds_walked = 0
        # Crash-consistent run cursor (all persisted by ``save``): the run
        # loop is a state machine over phase ∈ rounds → tail → done with
        # ``_trained_rounds`` counting fully-trained rounds, so ``resume``
        # re-enters the exact round the snapshot committed and replays
        # forward deterministically (round keys are fold_in(key_walk, r),
        # training keys fold_in(key_train, global_step)).
        self._rounds_cfg = dict(rounds_cfg)
        self._trained_rounds = 0
        self._phase = "rounds"          # rounds | tail | done
        self._ckpt_seq = 0              # snapshot numbering (monotonic)
        self._ckpt_root: Optional[str] = None
        self._ckpt_every = 0
        self._ckpt_tick = 0
        self._ckpt_keep: Optional[int] = None

    # --- walk side --------------------------------------------------------
    def _run_round(self, r: int, sources: Optional[np.ndarray] = None,
                   faults: FaultInjector = NULL_INJECTOR):
        """Dispatch all walk batches of round r; returns async
        (chunk_sources, state) pairs.

        Under vertex-keyed RNG every chunk of a round shares the ROUND key
        (lane draws are disambiguated by source-vertex id, not position),
        which is what lets the incremental refresh re-walk an arbitrary
        subset of sources later and reproduce this round's walks
        bit-for-bit without knowing the original chunk boundaries.

        ``faults`` fires the ``superstep`` injection point at every chunk
        dispatch — the host boundary where a crash interrupts a round with
        some walks computed but nothing committed to the ring; recovery
        simply re-dispatches the whole round under its original key.
        """
        from repro.core.walker import run_walk_batch

        if sources is None:
            sources = self.sources
        by_vertex = self.spec.rng_mode == "vertex"
        round_key = jax.random.fold_in(self.key_walk, r)
        pairs = []
        with obs.trace_span("walk.round", round=r, walks=len(sources)):
            for start in range(0, len(sources), self.walker_batch):
                faults.fire("superstep", f"round {r} chunk @{start}")
                chunk = np.asarray(sources[start:start + self.walker_batch])
                k = (round_key if by_vertex
                     else jax.random.fold_in(round_key, start))
                pairs.append((chunk, run_walk_batch(
                    self.graph, jnp.asarray(chunk, jnp.int32), k,
                    self.policy, self.spec, self.assignment,
                    num_shards=self.walk_shards
                    if self.assignment is not None else None)))
                obs.inc("walk.batches")
            obs.inc("walk.dispatched", len(sources))
        return pairs

    def _append(self, pairs, round_idx: int):
        # Donated: the old ring version is dropped right here; XLA aliases
        # the buffers when no queued trainer gather still reads them and
        # falls back to a copy when one does — either way no per-batch
        # full-ring copy survives on the steady-state hot path.
        from repro.core.corpus import ring_append_donated
        cap = self.ring.capacity
        for chunk, st in pairs:
            self.ring = ring_append_donated(
                self.ring, st.path, st.info.L.astype(jnp.int32))
            slots = (self._cursor + np.arange(len(chunk))) % cap
            self._slot_root[slots] = chunk
            self._slot_round[slots] = round_idx
            self._cursor = int((self._cursor + len(chunk)) % cap)
            for k in self._stats:
                self._stats[k] = self._stats[k] + getattr(st, k)

    # --- train side -------------------------------------------------------
    def _lrs(self, count: int) -> jnp.ndarray:
        # _lr_scale is the divergence-rollback backoff multiplier (1.0
        # until the watchdog ever trips; exact-1.0 multiply is bit-neutral).
        if self._ft is not None:
            start, total, lr0 = self._ft     # fine-tune mini-schedule
            fracs = (self.global_step - start + np.arange(count)) / max(
                total, 1)
            return jnp.asarray(
                np.maximum(lr0 * self._lr_scale * (1.0 - fracs),
                           self.cfg.min_lr),
                jnp.float32)
        fracs = (self.global_step + np.arange(count)) / max(self.total_steps, 1)
        return jnp.asarray(
            np.maximum(self.cfg.lr * self._lr_scale * (1.0 - fracs),
                       self.cfg.min_lr),
            jnp.float32)

    def _train_slots(self, base: int, pool: int, ocn_host: np.ndarray,
                     steps: int, table=None, order=None):
        """Enqueue ``steps`` training steps over ring slots [base, base+pool).

        ``table``/``order`` let callers whose ocn is frozen (the schedule
        tail) reuse one alias-table/argsort build across calls instead of
        redoing the O(N) host work per iteration."""
        from repro.core.corpus import FrequencyOrder
        from repro.core.dsgl import (
            build_alias_table, train_chunk, train_chunk_checked,
        )
        from repro.core.sync import sample_hotness_rows
        from repro.data.pipeline import ring_chunk_indices

        cfg = self.cfg
        if table is None:
            table = build_alias_table(ocn_host, cfg.neg_power)  # node space
        replicated = self.num_shards > 1
        rng = np.random.default_rng(cfg.seed * 9176 + self.global_step)
        if order is None:
            order = FrequencyOrder.from_ocn(ocn_host) if replicated else None
        chunk = max(min(cfg.sync_period, steps), 1)
        done = 0
        tele = obs.enabled()
        while done < steps:
            t_c = time.perf_counter() if tele else 0.0
            count = min(chunk, steps - done)
            # Improvement-III cadence: one hotness exchange per sync_period
            # LIFETIMES (global steps), not per dispatched chunk — rounds
            # are often much shorter than a sync period, and averaging the
            # replicas every few steps collapses the diversity that makes
            # the replica ensemble train well (measured: AUC 0.64 -> 0.86).
            sync_now = replicated and (
                self.global_step // cfg.sync_period
                != (self.global_step + count) // cfg.sync_period)
            ck = jax.random.fold_in(self.key_train, self.global_step)
            idx = ring_chunk_indices(
                ck, base, pool, count, self.num_shards,
                cfg.batch_groups, cfg.multi_windows)
            wb = self.ring.walks[idx]                     # (C,S,G,W,T) gather
            if sync_now:
                starts, ends = order.hotness_blocks()
                rows_rank = sample_hotness_rows(starts, ends, rng)
                rows = jnp.asarray(order.to_node[rows_rank], jnp.int32)
            else:
                rows = jnp.zeros(0, jnp.int32)
            ck2 = jax.random.fold_in(self.key_train, 2 * self.total_steps
                                     + self.global_step)
            lrs = self._lrs(count)
            # Divergence corruption sites (watchdog tests/chaos sweeps):
            # poison a few phi rows with NaN, or blow the chunk lr up —
            # both produce REAL divergences for the watchdog to catch.
            if self._faults.inject("phi_nan"):
                self.phi_in = self.phi_in.at[:, :4, :].set(jnp.nan)
            if self._faults.inject("lr_spike"):
                lrs = lrs * 1e4
            check = (self.health is not None
                     and self.health.due(self.global_step, count))
            if check:
                self.phi_in, self.phi_out, _, hs = train_chunk_checked(
                    self.phi_in, self.phi_out, wb, table, rows, ck2,
                    lrs, cfg.window, cfg.negatives,
                    cfg.use_kernel, sync_now)
            else:
                self.phi_in, self.phi_out, _ = train_chunk(
                    self.phi_in, self.phi_out, wb, table, rows, ck2,
                    lrs, cfg.window, cfg.negatives,
                    cfg.use_kernel, sync_now)
            self.global_step += count
            done += count
            if tele:
                obs.observe("train.chunk_dispatch.s",
                            time.perf_counter() - t_c)
                obs.inc("train.steps", count)
            if check:
                # One host pull of 5 scalars; raises DivergenceError on a
                # verdict — run()'s heal loop owns the reaction.
                self.health.observe(
                    {k: v for k, v in hs.items()},
                    step=self.global_step, count=count,
                    slots=np.unique(np.asarray(idx)))

    # --- driver -----------------------------------------------------------
    def run(self, *, ckpt_root: Optional[str] = None,
            ckpt_every_rounds: int = 0,
            ckpt_keep: Optional[int] = None,
            faults: FaultInjector = NULL_INJECTOR,
            liveness=None) -> Dict[str, Any]:
        """Run (or CONTINUE, after ``resume``) the walk→train lifecycle.

        The loop is a state machine over persisted cursors (see ``save``):
        phase ``rounds`` iterates round r = ``_trained_rounds`` with the
        invariant that rounds 0..r are appended and the ΔD gate holds r
        decisions; phase ``tail`` re-consumes the frozen ring until the
        a-priori schedule completes. A snapshot taken at any iteration
        boundary is therefore a consistent cut, and because every source of
        randomness is keyed off persisted state (round keys
        fold_in(key_walk, r), train keys fold_in(key_train, global_step),
        hotness rng seeded by global_step), a resumed run replays the
        remaining rounds/chunks bit-identically to the uninterrupted one.

        ``ckpt_root``/``ckpt_every_rounds`` enable periodic snapshots (one
        every N round/tail iterations plus a final one); ``ckpt_keep``
        bounds retention (older snapshots are pruned after each commit);
        ``faults`` is the injection harness (production default never
        fires).

        Self-healing (DESIGN.md §12): when a ``HealthMonitor`` is attached
        the training chunks run watchdog reductions at its cadence, and a
        divergence verdict rolls the pipeline back to the last consistent
        snapshot, backs the learning rate off, quarantines (re-walks) the
        offending ring slots, and re-enters this state machine — bounded
        by ``HealthConfig.max_rollbacks``. When a ``LivenessProbe`` is
        passed, every round boundary polls shard liveness and a
        persistently-dead walk shard triggers ``elastic_reconfigure``
        (continue at k-1 shards) instead of stalling the round.
        """
        from repro.runtime.health import DivergenceError

        t0 = time.perf_counter()
        self._ckpt_root, self._ckpt_every = ckpt_root, ckpt_every_rounds
        self._ckpt_keep = ckpt_keep
        self._faults = faults
        try:
            if (self.health is not None and ckpt_root
                    and latest_step(ckpt_root) is None):
                # The watchdog needs a rollback base before the first
                # divergence can possibly be detected.
                self.save(ckpt_root, faults=faults)
            while True:
                try:
                    result = self._run_phases(faults, liveness)
                    break
                except DivergenceError as err:
                    self._heal_divergence(err, faults)
        finally:
            self._faults = NULL_INJECTOR
        result["wall_s"] = time.perf_counter() - t0
        return result

    def _run_phases(self, faults: FaultInjector, liveness) -> Dict[str, Any]:
        from repro.core.info import relative_entropy_dpq

        n = len(self.sources)
        if self._phase == "rounds":
            if self._rounds_walked == 0:
                self._append(self._run_round(0, faults=faults), 0)
                self._rounds_walked = 1
            while True:
                r = self._trained_rounds
                with log_context(round=r):
                    faults.fire("round", r)
                    self._poll_liveness(liveness, faults)
                    ocn_host = np.asarray(self.ring.ocn)  # per-round sync
                    cont = self.controller.update_d(
                        relative_entropy_dpq(self.degrees, ocn_host))
                    if cont and self.overlap:
                        nxt = self._run_round(r + 1, faults=faults)  # ∥ train
                    self._train_slots((r * n) % self.ring.capacity, n,
                                      ocn_host, self.steps_per_round)
                    if not self.overlap:
                        jax.block_until_ready(self.phi_in)
                    self._trained_rounds = r + 1
                    if not cont:
                        break
                    if not self.overlap:
                        nxt = self._run_round(r + 1, faults=faults)
                        jax.block_until_ready(nxt[-1][1].path)
                    self._append(nxt, r + 1)
                    self._rounds_walked = r + 2
                    self._maybe_snapshot(faults)
            self._phase = "tail"
            obs.span_event("pipeline.phase", phase="tail",
                           round=self._trained_rounds,
                           step=self.global_step)
            self._maybe_snapshot(faults)

        if self._phase == "tail":
            # Schedule-completion tail: re-consume the filled ring until
            # the a-priori lr schedule ends (extra decayed passes over the
            # corpus). ocn is frozen now, so the alias table / frequency
            # order are built once and reused across every tail iteration
            # (and rebuilt identically on resume — they are pure functions
            # of the persisted ring.ocn).
            from repro.core.corpus import FrequencyOrder
            from repro.core.dsgl import build_alias_table

            ocn_host = np.asarray(self.ring.ocn)
            filled = self.ring.num_filled
            tail_table = build_alias_table(ocn_host, self.cfg.neg_power)
            tail_order = (FrequencyOrder.from_ocn(ocn_host)
                          if self.num_shards > 1 else None)
            while self.global_step < self.total_steps:
                faults.fire("tail", self.global_step)
                self._train_slots(
                    0, filled, ocn_host,
                    min(self.steps_per_round,
                        self.total_steps - self.global_step),
                    table=tail_table, order=tail_order)
                self._maybe_snapshot(faults)
            jax.block_until_ready(self.phi_in)
            self._phase = "done"
            obs.span_event("pipeline.phase", phase="done",
                           step=self.global_step)
            if self._ckpt_root and self._ckpt_every:
                self.save(self._ckpt_root, faults=faults)   # final snapshot

        phi_in, phi_out = self.embeddings(as_numpy=False)
        stats = {k: float(v) for k, v in self._stats.items()}
        stats["mean_len"] = (float(np.asarray(self.ring.lengths).sum())
                             / max(self.ring.num_filled, 1))
        stats["d_history"] = list(self.controller.history)
        # Export the walk-engine accumulators exactly where the run loop
        # already pulled them to host — no extra device syncs.
        if obs.enabled():
            for k in self._stats:
                obs.set_gauge(f"walk.{k}", stats[k])
            obs.set_gauge("walk.mean_len", stats["mean_len"])
            obs.set_gauge("walk.rounds", self.controller.rounds)
            obs.set_gauge("train.global_step", self.global_step)
        return {
            "phi_in": phi_in, "phi_out": phi_out,
            "rounds": self.controller.rounds,
            "steps": self.global_step,
            "ring": self.ring,
            "stats": stats,
            "health": (self.health.report()
                       if self.health is not None else None),
            "reconfigs": list(self._reconfigs),
            "lr_scale": float(self._lr_scale),
        }

    # --- crash-consistent snapshots (DESIGN.md §11) ------------------------
    def _maybe_snapshot(self, faults: FaultInjector) -> None:
        if not self._ckpt_root or not self._ckpt_every:
            return
        self._ckpt_tick += 1
        if self._ckpt_tick % self._ckpt_every == 0:
            self.save(self._ckpt_root, faults=faults)

    def _state_tree(self) -> Dict[str, Any]:
        from repro.core.corpus import ring_export

        tree: Dict[str, Any] = {
            "phi_in": self.phi_in,
            "phi_out": self.phi_out,
            "ring": ring_export(self.ring),
            "slot_root": self._slot_root,
            "slot_round": self._slot_round,
            "key_walk": self.key_walk,
            "key_train": self.key_train,
            "stats": dict(self._stats),
            "graph": {"indptr": self.graph.indptr,
                      "indices": self.graph.indices},
        }
        if self.graph.weights is not None:
            tree["graph"]["weights"] = self.graph.weights
        if self.graph.edge_cm is not None:
            tree["graph"]["edge_cm"] = self.graph.edge_cm
        if self.assignment is not None:
            tree["assignment"] = self.assignment
        return tree

    def save(self, root: str, *, faults: FaultInjector = NULL_INJECTOR,
             meta_extra: Optional[Dict[str, Any]] = None) -> str:
        """Checkpoint the COMPLETE walk→train state as one atomic
        ``repro.ckpt`` tree: phi replicas, the corpus ring (walks, lengths,
        ocn, cursor — lossless), the host slot→root/slot→round maps, both
        RNG keys, the ΔD controller history, the run cursors, the MPGP
        assignment, and the graph's CSR arrays (so recovery needs no
        external graph handle and restores the exact mutated topology).

        ``faults`` can crash the write two ways: the ``ckpt_write`` point
        fires before anything is written (the snapshot is simply lost) and
        ``torn("ckpt")`` commits the directory, then corrupts its manifest
        before raising — the committed-but-unsynced-data crash the reader
        fallback in ``ckpt.checkpoint`` exists for.
        """
        from repro.graph.delta import graph_version

        with obs.trace_span("ckpt.write", seq=self._ckpt_seq,
                            round=self._trained_rounds,
                            step=self.global_step, phase=self._phase):
            return self._save_inner(root, faults, meta_extra,
                                    graph_version)

    def _save_inner(self, root, faults, meta_extra, graph_version) -> str:
        faults.fire("ckpt_write", self._ckpt_seq)
        torn = faults.torn("ckpt")
        meta = {
            "kind": "streaming_pipeline",
            "global_step": int(self.global_step),
            "cursor": int(self._cursor),
            "rounds_walked": int(self._rounds_walked),
            "trained_rounds": int(self._trained_rounds),
            "phase": self._phase,
            "controller": self.controller.to_state(),
            "rounds_cfg": self._rounds_cfg,
            "total_steps": int(self.total_steps),
            "num_shards": int(self.num_shards),
            "walk_shards": int(self.walk_shards),
            "lr_scale": float(self._lr_scale),
            "walker_batch": int(self.walker_batch),
            "overlap": bool(self.overlap),
            "graph_version": int(graph_version(self.graph)),
        }
        if meta_extra:
            meta.update(meta_extra)
        path = save_checkpoint(root, self._ckpt_seq, self._state_tree(),
                               meta=meta)
        if torn:
            with open(os.path.join(path, "manifest.json"), "w") as f:
                f.write('{"step": ')          # data blocks never hit disk
            raise SimulatedFailure(
                f"torn checkpoint write at snapshot {self._ckpt_seq}")
        with log_context(round=self._trained_rounds,
                         graph_version=meta["graph_version"]):
            log.info("snapshot %d committed at %s (phase=%s step=%d)",
                     self._ckpt_seq, path, self._phase, self.global_step)
        obs.inc("ckpt.writes")
        obs.set_gauge("ckpt.last_seq", self._ckpt_seq)
        seq = self._ckpt_seq
        self._ckpt_seq += 1
        if self._ckpt_keep:
            from repro.ckpt.checkpoint import prune_steps
            prune_steps(root, self._ckpt_keep)
        for hook in self._snapshot_hooks:
            hook(path, seq, meta)
        return path

    def add_snapshot_hook(self, hook) -> None:
        """Subscribe ``hook(path, seq, meta)`` to committed snapshots —
        the serve-side hand-off (an ``EmbedServer`` offer, a replication
        push). Hooks run AFTER the atomic commit and after retention
        pruning, so the path they see is durable."""
        self._snapshot_hooks.append(hook)

    @classmethod
    def resume(cls, root: str, policy, spec, dsgl_cfg, *,
               step: Optional[int] = None,
               rounds_cfg: Optional[Dict] = None,
               walker_batch: Optional[int] = None,
               overlap: Optional[bool] = None,
               health=None) -> "StreamingEmbedPipeline":
        """Rebuild a pipeline from the newest VALID snapshot under ``root``
        (or an explicit ``step``) and re-enter its exact cursor state.

        The caller re-provides the non-serializable plan objects (policy,
        spec, dsgl config — the same posture as ``Trainer.try_restore``
        rebuilding from the model config); everything mutable, including
        the graph itself, comes out of the checkpoint. Call ``run()`` on
        the result to continue — the rounds/chunks past the cursor
        re-dispatch under their original round keys, so the finished
        embedding is bit-identical to the uninterrupted run's.
        """
        from repro.core.corpus import ring_import
        from repro.core.termination import WalkCountController
        from repro.graph.csr import CSRGraph

        step_loaded, arrays, meta = load_checkpoint(root, step)
        if meta.get("kind") != "streaming_pipeline":
            raise ValueError(
                f"checkpoint at {root} step {step_loaded} is not a "
                "streaming-pipeline snapshot")
        graph = CSRGraph(
            indptr=jnp.asarray(arrays["graph/indptr"], jnp.int32),
            indices=jnp.asarray(arrays["graph/indices"], jnp.int32),
            weights=(jnp.asarray(arrays["graph/weights"], jnp.float32)
                     if "graph/weights" in arrays else None),
            edge_cm=(jnp.asarray(arrays["graph/edge_cm"], jnp.int32)
                     if "graph/edge_cm" in arrays else None),
        )
        pipe = cls(
            graph, policy, spec,
            rounds_cfg if rounds_cfg is not None else meta["rounds_cfg"],
            dsgl_cfg,
            assignment=arrays.get("assignment"),
            num_shards=int(meta["num_shards"]),
            walker_batch=(walker_batch if walker_batch is not None
                          else int(meta["walker_batch"])),
            overlap=(overlap if overlap is not None
                     else bool(meta["overlap"])),
            health=health)
        ring = ring_import({k: arrays[f"ring/{k}"] for k in
                            ("walks", "lengths", "ocn", "cursor", "total")})
        if ring.capacity != pipe.ring.capacity:
            raise ValueError(
                f"snapshot ring capacity {ring.capacity} does not match "
                f"the rebuilt pipeline's {pipe.ring.capacity}; resume with "
                "the original rounds_cfg/spec")
        pipe.ring = ring
        pipe.phi_in = jnp.asarray(arrays["phi_in"], jnp.float32)
        pipe.phi_out = jnp.asarray(arrays["phi_out"], jnp.float32)
        pipe.key_walk = jnp.asarray(arrays["key_walk"])
        pipe.key_train = jnp.asarray(arrays["key_train"])
        pipe._stats = {k: jnp.asarray(arrays[f"stats/{k}"])
                       for k in pipe._stats}
        pipe._slot_root = np.asarray(arrays["slot_root"], np.int64)
        pipe._slot_round = np.asarray(arrays["slot_round"], np.int64)
        pipe.controller = WalkCountController.from_state(meta["controller"])
        pipe.global_step = int(meta["global_step"])
        pipe.total_steps = int(meta["total_steps"])
        pipe._cursor = int(meta["cursor"])
        pipe._rounds_walked = int(meta["rounds_walked"])
        pipe._trained_rounds = int(meta["trained_rounds"])
        pipe._phase = meta["phase"]
        # Self-healing cursors (absent in pre-watchdog snapshots).
        pipe.walk_shards = int(meta.get("walk_shards", meta["num_shards"]))
        pipe._lr_scale = float(meta.get("lr_scale", 1.0))
        pipe._ckpt_seq = step_loaded + 1
        log.info("resumed pipeline from %s snapshot %d "
                 "(phase=%s round=%d step=%d)", root, step_loaded,
                 pipe._phase, pipe._trained_rounds, pipe.global_step)
        obs.span_event("ckpt.resume", snapshot=step_loaded,
                       phase=pipe._phase, round=pipe._trained_rounds,
                       step=pipe.global_step)
        obs.inc("ckpt.resumes")
        return pipe

    def corpus(self):
        """Materialize the ring as a host ``Corpus`` (API boundary only)."""
        from repro.core.corpus import Corpus, ring_to_numpy
        walks, lengths = ring_to_numpy(self.ring)
        stats = {k: float(v) for k, v in self._stats.items()}
        stats["d_history"] = list(self.controller.history)
        stats["mean_len"] = float(lengths.mean()) if len(lengths) else 0.0
        return Corpus(walks=walks, lengths=lengths,
                      ocn=np.asarray(self.ring.ocn, dtype=np.int64),
                      rounds=self.controller.rounds, stats=stats)

    def embeddings(self, as_numpy: bool = True):
        """Current (phi_in, phi_out) in node space, replica-averaged."""
        if self.num_shards > 1:
            phi_in = jnp.mean(self.phi_in, axis=0)
            phi_out = jnp.mean(self.phi_out, axis=0)
        else:
            phi_in, phi_out = self.phi_in[0], self.phi_out[0]
        if as_numpy:
            return np.asarray(phi_in), np.asarray(phi_out)
        return phi_in, phi_out

    # --- incremental refresh (repro.core.incremental drives this) ---------
    def corpus_slots(self):
        """(walks, roots, valid) for the resident ring slots.

        ``roots`` is the host-maintained slot→source-vertex map (updated
        at every append from the dispatch chunks, so it survives partial
        refresh rounds and ring wraps where slot arithmetic would lie);
        ``valid`` masks slots ever written. This is the corpus surface
        affected-vertex detection reads (one host pull per refresh).
        """
        walks = np.asarray(self.ring.walks)
        return walks, self._slot_root, self._slot_root >= 0

    def _rewalk_resident(self, root_mask: np.ndarray,
                         faults: FaultInjector = NULL_INJECTOR
                         ) -> Tuple[int, int]:
        """Re-walk every resident walk rooted in ``root_mask`` under its
        ORIGINAL round key and splice it into the slot its predecessor
        occupies (``ring_replace`` keeps ocn exact: − old tokens + new).

        Shared by the incremental refresh (stale roots after churn) and
        shard-loss recovery (resident roots of a dead shard) — in both
        cases vertex-keyed RNG makes the subset walks bit-identical to a
        full-batch round. Fires ``refresh_splice`` once per resident round
        BEFORE that round's splices land — an injected crash therefore dies
        with earlier rounds spliced and later rounds stale, the exact
        half-updated-ring hazard; recovery (resume from the pre-refresh
        snapshot, replay the churn, redo the refresh) must never expose
        that intermediate state. Returns (rewalk_walks, rounds_resident).
        """
        from repro.core.corpus import ring_replace_donated
        from repro.graph.delta import graph_version

        n = len(self.sources)
        slot_ids = np.arange(self.ring.capacity)
        aff_slot = (self._slot_root >= 0) & np.asarray(root_mask)[
            np.maximum(self._slot_root, 0)]
        rounds_resident = np.unique(self._slot_round[aff_slot])
        rewalk_walks = 0
        gv = int(graph_version(self.graph)) if obs.enabled() else None
        for r in rounds_resident:
            # The refresh_splice injection point fires INSIDE the span so
            # a chaos crash dumps a flight record whose faulting span
            # carries the round/graph_version (and, via log_context, the
            # shard) it died in.
            with obs.trace_span("refresh.splice", round=int(r),
                                graph_version=gv):
                faults.fire("refresh_splice", int(r))
                sel = aff_slot & (self._slot_round == r)
                roots_r = self._slot_root[sel]
                slot_of = np.full(n, -1, np.int64)
                slot_of[roots_r] = slot_ids[sel]
                for chunk, st in self._run_round(int(r), sources=roots_r,
                                                 faults=faults):
                    slots = slot_of[chunk]
                    self.ring = ring_replace_donated(
                        self.ring, jnp.asarray(slots, jnp.int32), st.path,
                        st.info.L.astype(jnp.int32))
                    for k in self._stats:
                        self._stats[k] = self._stats[k] + getattr(st, k)
                    rewalk_walks += len(chunk)
                obs.inc("refresh.rewalk_walks", int(len(roots_r)))
        return rewalk_walks, int(len(rounds_resident))

    def recover_shard_loss(self, shard_id: int, *,
                           faults: FaultInjector = NULL_INJECTOR
                           ) -> Dict[str, Any]:
        """Degraded-mode recovery for one lost walk shard: instead of
        restarting every in-flight round globally, re-walk ONLY the lost
        shard's resident roots through the subset-re-walk path under their
        original round keys. Vertex-keyed RNG makes the recovered walks
        bit-identical to what the lost shard had produced, so the ring —
        and everything downstream of it — is exactly restored, not
        approximated. Requires ``WalkSpec.rng_mode == 'vertex'``."""
        if self.spec.rng_mode != "vertex":
            raise ValueError(
                "shard-loss recovery requires WalkSpec.rng_mode='vertex'")
        n = len(self.sources)
        if self.assignment is None:
            if shard_id != 0:
                raise ValueError(
                    f"pipeline has no shard assignment (shard {shard_id})")
            mask = np.ones(n, bool)       # single shard: everything resident
        else:
            mask = np.asarray(self.assignment) == shard_id
        t0 = time.perf_counter()
        with log_context(shard=shard_id):
            rewalk, rounds = self._rewalk_resident(mask, faults)
            jax.block_until_ready(self.ring.walks)
            log.info("shard-loss recovery re-walked %d walks over %d "
                     "resident rounds", rewalk, rounds)
        return {
            "shard": int(shard_id),
            "lost_roots": int(mask.sum()),
            "rewalk_walks": int(rewalk),
            "rounds_resident": int(rounds),
            "wall_s": float(time.perf_counter() - t0),
        }

    # --- self-healing runtime (DESIGN.md §12) ------------------------------
    def _heal_divergence(self, err, faults: FaultInjector) -> None:
        """React to a watchdog verdict: roll back to the last consistent
        snapshot, back the learning rate off, quarantine the offending ring
        slots, and let ``run`` re-enter the state machine.

        The quarantine re-walks the roots whose slots fed the diverging
        chunk under their ORIGINAL round keys — on a clean ring this is a
        bit-identical no-op (vertex-keyed RNG), and if the divergence was
        seeded by corrupt walk data the regenerated slots heal it, so the
        replay cannot deterministically re-diverge on the same poison. The
        backoff handles the other deterministic-replay hazard (a genuine
        optimizer blow-up at this lr). Re-raises when no snapshot root is
        configured or ``max_rollbacks`` is exhausted — then the supervisor
        (``run_with_restarts``) is the right layer.
        """
        report = err.report
        mon = self.health
        if not self._ckpt_root or mon is None or mon.exhausted():
            raise err
        # Resolve slots → roots BEFORE restoring: the snapshot's slot map
        # may predate the rounds the diverging chunk trained on.
        roots = self._slot_root[report.slots]
        roots = np.unique(roots[roots >= 0])
        self._restore_in_place()
        self._lr_scale *= mon.cfg.lr_backoff
        quarantined = 0
        if self.spec.rng_mode == "vertex" and len(roots):
            mask = np.zeros(len(self.sources), bool)
            mask[roots] = True
            quarantined, _ = self._rewalk_resident(mask, faults)
        mon.note_rollback(restored_step=self.global_step,
                          lr_scale=self._lr_scale, quarantined=quarantined)
        obs.span_event("pipeline.heal", kind=report.kind,
                       detected_step=report.step,
                       restored_step=self.global_step,
                       lr_scale=self._lr_scale, quarantined=quarantined)
        obs.inc("pipeline.heals")
        log.warning(
            "divergence (%s) at step %d: rolled back to step %d, lr scale "
            "now %.3g, quarantined %d resident walks",
            report.kind, report.step, self.global_step, self._lr_scale,
            quarantined)

    def _restore_in_place(self) -> int:
        """Adopt the newest valid snapshot's state into THIS object (the
        in-place form of ``resume`` — run-loop wiring like the watchdog,
        checkpoint config and reconfig log survive the rollback). Returns
        the restored global step."""
        q = StreamingEmbedPipeline.resume(
            self._ckpt_root, self.policy, self.spec, self.cfg)
        keep = {k: self.__dict__[k] for k in (
            "health", "_ckpt_root", "_ckpt_every", "_ckpt_keep",
            "_faults", "_reconfigs", "_snapshot_hooks")}
        self.__dict__.update(q.__dict__)
        self.__dict__.update(keep)
        return self.global_step

    def _poll_liveness(self, liveness, faults: FaultInjector) -> None:
        """Round-boundary probe sweep: a persistently-dead walk shard is
        reassigned to the survivors instead of stalling the BSP round.
        A snapshot lands right after a reconfiguration (when checkpointing
        is on) so a later divergence rollback can never resurrect a dead
        shard's assignment."""
        if liveness is None:
            return
        for dead in liveness.poll(faults):
            name = liveness.names[dead]
            log.warning(
                "walk shard %d (launch id %d) missed %d consecutive "
                "liveness probes — reconfiguring elastically",
                dead, name, liveness.misses_to_dead)
            stats = self.elastic_reconfigure(dead, faults=faults)
            stats["launch_id"] = int(name)
            liveness.remove(dead)
            if self._ckpt_root and (self._ckpt_every or self.health):
                self.save(self._ckpt_root, faults=faults)
        for name in liveness.rejoinable():
            log.info(
                "walk shard (launch id %d) answered %d consecutive "
                "liveness probes — growing back elastically",
                name, liveness.hits_to_live)
            stats = self.elastic_rejoin(faults=faults)
            stats["launch_id"] = int(name)
            liveness.rejoin(name)
            if self._ckpt_root and (self._ckpt_every or self.health):
                self.save(self._ckpt_root, faults=faults)

    def elastic_reconfigure(self, dead_shard: int, *,
                            faults: FaultInjector = NULL_INJECTOR
                            ) -> Dict[str, Any]:
        """Continue at k-1 walk shards after a persistent shard loss.

        The dead shard's vertices re-enter the MPGP stream (highest degree
        first) and are assigned to the SURVIVING partitions by the same
        Eq. 14/15 argmax that placed them originally; the partition-local
        CSR store is rebuilt with the untouched survivors' slices reused
        (``graph.csr.reassign_partitioned_csr``); and the dead shard's
        resident walker fragments migrate by re-walking their roots under
        the original round keys — bit-identical to what the lost shard had
        produced, because vertex-keyed walks are invariant to the shard
        count (the engine's k-invariance contract). Walks rooted at
        surviving shards' vertices are never touched, so the ring — and
        the embedding — stays on the fault-free trajectory.

        The DSGL replica count (phi's leading axis) is NOT changed: it is
        a training ensemble choice, not a walk-dispatch property.
        """
        from repro.core.mpgp import compact_assignment, reassign_dead_shard
        from repro.core.shard_engine import reconfigure_partitions

        if self.assignment is None:
            raise ValueError(
                "elastic reconfiguration needs a shard assignment")
        if self.spec.rng_mode != "vertex":
            raise ValueError(
                "elastic reconfiguration requires WalkSpec.rng_mode="
                "'vertex' (walker-fragment migration re-walks under the "
                "original round keys)")
        k = self.walk_shards
        if not 0 <= dead_shard < k:
            raise ValueError(f"dead shard {dead_shard} not in [0, {k})")
        if k <= 1:
            raise ValueError("cannot reconfigure away the last walk shard")
        t0 = time.perf_counter()
        old_asn = np.asarray(self.assignment)
        orphan_mask = old_asn == dead_shard
        new_full = reassign_dead_shard(self.graph, old_asn, dead_shard,
                                       num_parts=k, tau_weight="degree")
        compacted, old_of_new = compact_assignment(new_full, dead_shard,
                                                   num_parts=k)
        eng = reconfigure_partitions(
            self.graph, old_asn, compacted, k - 1,
            old_of_new=old_of_new, key_obj=self.graph)
        self.assignment = jnp.asarray(compacted, jnp.int32)
        self.walk_shards = k - 1
        rewalk, rounds = self._rewalk_resident(orphan_mask, faults)
        jax.block_until_ready(self.ring.walks)
        stats = {
            "dead_shard": int(dead_shard),
            "walk_shards": int(self.walk_shards),
            "moved_roots": int(orphan_mask.sum()),
            "moved_frac": float(orphan_mask.mean()),
            "rewalk_walks": int(rewalk),
            "rounds_resident": int(rounds),
            "reused_shards": int(eng["reused_shards"]),
            "rebuilt_shards": int(eng["rebuilt_shards"]),
            "wall_s": float(time.perf_counter() - t0),
        }
        self._reconfigs.append(stats)
        obs.span_event("pipeline.reconfig", dead_shard=int(dead_shard),
                       walk_shards=int(self.walk_shards),
                       moved_roots=stats["moved_roots"],
                       rewalk_walks=stats["rewalk_walks"])
        obs.inc("pipeline.reconfigs")
        obs.set_gauge("walk.shards", self.walk_shards)
        with log_context(shard=dead_shard):
            log.info(
                "elastic reconfiguration: %d orphan roots -> %d survivors "
                "(%d/%d slices reused), %d resident walks migrated in "
                "%.3fs", stats["moved_roots"], self.walk_shards,
                stats["reused_shards"], k - 1, rewalk, stats["wall_s"])
        return stats

    def elastic_rejoin(self, *, faults: FaultInjector = NULL_INJECTOR
                       ) -> Dict[str, Any]:
        """Grow back k → k+1 walk shards after capacity returns.

        The returned shard re-enters the dispatch space with the HIGHEST
        id (appended — survivors' ids never move, so in-flight host state
        keyed by dispatch id stays valid). ``mpgp.rejoin_shard`` carves a
        donor region out of the overloaded survivors (BFS around the most
        loaded survivor's hub, Eq. 15 capacity bookkeeping) and the
        partition-local CSR store rebuilds with every NON-donor slice
        reused (``reassign_partitioned_csr``, split direction).

        Unlike a shard death, NO walk data is lost or invalidated:
        vertex-keyed walks are invariant to the shard count AND the
        assignment (the engine's k-invariance contract), so the ring — and
        the embedding trajectory — is untouched. Re-join is pure dispatch
        topology: the next round simply fans out over k+1 shards.
        """
        from repro.core.mpgp import rejoin_shard
        from repro.core.shard_engine import reconfigure_partitions

        if self.assignment is None:
            raise ValueError("elastic re-join needs a shard assignment")
        if self.spec.rng_mode != "vertex":
            raise ValueError(
                "elastic re-join requires WalkSpec.rng_mode='vertex' "
                "(walk dispatch must be assignment-invariant)")
        k = self.walk_shards
        t0 = time.perf_counter()
        old_asn = np.asarray(self.assignment)
        new_asn, moved = rejoin_shard(self.graph, old_asn, num_parts=k,
                                      tau_weight="degree")
        old_of_new = np.concatenate(
            [np.arange(k, dtype=np.int64), [-1]])
        eng = reconfigure_partitions(
            self.graph, old_asn, new_asn, k + 1,
            old_of_new=old_of_new, num_shards_old=k, key_obj=self.graph)
        self.assignment = jnp.asarray(new_asn, jnp.int32)
        self.walk_shards = k + 1
        stats = {
            "kind": "rejoin",
            "walk_shards": int(self.walk_shards),
            "moved_roots": int(moved.sum()),
            "moved_frac": float(moved.mean()),
            "reused_shards": int(eng["reused_shards"]),
            "rebuilt_shards": int(eng["rebuilt_shards"]),
            "wall_s": float(time.perf_counter() - t0),
        }
        self._reconfigs.append(stats)
        obs.span_event("pipeline.rejoin",
                       walk_shards=int(self.walk_shards),
                       moved_roots=stats["moved_roots"])
        obs.inc("pipeline.rejoins")
        obs.set_gauge("walk.shards", self.walk_shards)
        log.info(
            "elastic re-join: %d donor roots -> returned shard %d "
            "(%d/%d slices reused) in %.3fs", stats["moved_roots"], k,
            stats["reused_shards"], k + 1, stats["wall_s"])
        return stats

    def refresh(self, new_graph, affected_mask: np.ndarray, *,
                fine_tune_steps: Optional[int] = None,
                fine_tune_frac: float = 0.5,
                fine_tune_lr_scale: float = 0.3,
                max_extra_rounds: int = 2,
                faults: FaultInjector = NULL_INJECTOR) -> Dict[str, Any]:
        """Absorb a mutated graph: re-walk ONLY the affected roots through
        the sharded engine, splice the delta corpus into the ring, continue
        the seeded ΔD gate, and fine-tune DSGL in place.

        Per retained round r the affected roots re-walk under round r's
        ORIGINAL key; vertex-keyed RNG reproduces exactly the walks a
        from-scratch round on the mutated graph would give them, and
        ``ring_replace`` swaps them into their original round-aligned
        slots — every other slot (every walk rooted at an unaffected
        vertex) stays bit-identical. The Eq. 7 controller then continues
        from the PRIOR run's D_r history: if churn moved the
        degree/occurrence divergence by more than delta, extra
        affected-subset rounds append until it re-converges (bounded by
        ``max_extra_rounds``). Finally DSGL fine-tunes over the refreshed
        ring on a decayed mini-schedule (``fine_tune_frac`` of the
        original schedule at ``fine_tune_lr_scale``·lr), with the negative
        alias table rebuilt from the exact refreshed occurrence counts.
        """
        from repro.core.info import relative_entropy_dpq
        from repro.core.termination import WalkCountController
        from repro.graph.delta import graph_version

        if self.spec.rng_mode != "vertex":
            raise ValueError("refresh requires WalkSpec.rng_mode='vertex'")
        n = len(self.sources)
        if new_graph.num_nodes != n:
            raise ValueError(
                f"refresh cannot change the vertex set yet "
                f"({new_graph.num_nodes} != {n}); rebuild with embed_graph")
        if (getattr(self.policy, "needs_edge_cm", False)
                and new_graph.edge_cm is None):
            new_graph = new_graph.with_edge_cm()
        t0 = time.perf_counter()
        gv = int(graph_version(new_graph))
        with obs.trace_span("refresh.enter", graph_version=gv):
            faults.fire("refresh", gv)
        self.graph = new_graph
        self.degrees = np.asarray(new_graph.degrees(), dtype=np.int64)

        affected = np.nonzero(np.asarray(affected_mask))[0].astype(np.int32)
        cap = self.ring.capacity
        sup0 = int(jnp.sum(self._stats["supersteps"]))

        # --- re-walk every resident walk of an affected root; splice ------
        # each new walk into the slot its stale predecessor occupies.
        # Rounds are re-walked under their ORIGINAL round keys, so the
        # spliced walks are bit-identical to a from-scratch round on the
        # mutated graph; a root's slot within a round comes from the
        # slot_root map (a full round holds every root once, a partial
        # extra round from an earlier refresh only its subset).
        rewalk_walks, retained = self._rewalk_resident(affected_mask, faults)

        # --- seeded ΔD gate: append extra subset rounds if D moved --------
        hist = list(self.controller.history)
        gate = WalkCountController(
            delta=self.controller.delta, min_rounds=1,
            max_rounds=len(hist) + 1 + max_extra_rounds,
            window=self.controller.window, seed_history=hist)
        extra = 0
        r_next = self._rounds_walked
        while len(affected):
            ocn_host = np.asarray(self.ring.ocn)
            if not gate.update_d(relative_entropy_dpq(self.degrees,
                                                      ocn_host)):
                break
            # Appends must FIT: a wrap would overwrite retained walks of
            # UNAFFECTED roots (breaking the kept-walk bit-identity
            # contract) and _ring_append never subtracts the overwritten
            # tokens, so ocn would drift. A full ring simply stops the
            # top-up — the spliced per-round re-walks above already
            # refreshed the corpus.
            if int(self.ring.total) + len(affected) > cap:
                break
            self._append(self._run_round(r_next, sources=affected), r_next)
            rewalk_walks += len(affected)
            extra += 1
            r_next += 1
        self._rounds_walked = r_next
        self.controller = gate        # next refresh seeds from here

        # --- fine-tune DSGL over the refreshed ring -----------------------
        from repro.core.corpus import FrequencyOrder
        from repro.core.dsgl import build_alias_table

        ocn_host = np.asarray(self.ring.ocn)
        filled = self.ring.num_filled
        ft = (int(fine_tune_steps) if fine_tune_steps is not None
              else max(1, int(fine_tune_frac * self.total_steps)))
        self._ft = (self.global_step, ft,
                    float(self.cfg.lr * fine_tune_lr_scale))
        try:
            table = build_alias_table(ocn_host, self.cfg.neg_power)
            order = (FrequencyOrder.from_ocn(ocn_host)
                     if self.num_shards > 1 else None)
            done = 0
            while done < ft:
                step = min(self.steps_per_round, ft - done)
                self._train_slots(0, filled, ocn_host, step,
                                  table=table, order=order)
                done += step
        finally:
            self._ft = None
        jax.block_until_ready(self.phi_in)

        sup1 = int(jnp.sum(self._stats["supersteps"]))
        obs.inc("refresh.count")
        obs.observe("refresh.s", time.perf_counter() - t0)
        obs.set_gauge("refresh.affected", int(len(affected)))
        obs.set_gauge("refresh.graph_version", gv)
        return {
            "affected": int(len(affected)),
            "affected_frac": float(len(affected) / max(n, 1)),
            "retained_rounds": int(retained),
            "extra_rounds": int(extra),
            "rewalk_walks": int(rewalk_walks),
            "rewalk_supersteps": int(sup1 - sup0),
            "fine_tune_steps": int(ft),
            "wall_s": float(time.perf_counter() - t0),
        }

    def adopt_graph(self, new_graph) -> None:
        """Detector-only degraded refresh (DESIGN.md §12): adopt the
        mutated topology — so future walks, reconfigurations and snapshots
        see the true graph — WITHOUT re-walking or fine-tuning. The ring
        keeps its stale walks; the caller (the SLO-driven ingest ladder)
        carries the affected-root set as debt and pays it on the next
        non-degraded refresh."""
        if new_graph.num_nodes != len(self.sources):
            raise ValueError(
                f"adopt_graph cannot change the vertex set "
                f"({new_graph.num_nodes} != {len(self.sources)})")
        if (getattr(self.policy, "needs_edge_cm", False)
                and new_graph.edge_cm is None):
            new_graph = new_graph.with_edge_cm()
        self.graph = new_graph
        self.degrees = np.asarray(new_graph.degrees(), dtype=np.int64)
