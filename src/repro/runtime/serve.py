"""Fault-tolerant embedding serving (DESIGN.md §14).

The ingest → refresh → snapshot loop (PR 6/8) produces crash-consistent
embedding versions; this module is the read side that makes the loop a
production system: an ``EmbedServer`` on the continuous-batching
slot-pool pattern (the generic wave scheduler the LM ``runtime.server``
uses lives here as ``wave_batches``), answering

* **pair scoring** — ``(u, candidates)`` → dot-product scores, the link-
  prediction primitive (``benchmarks.common.link_prediction_auc`` uses
  the same ``(phi[u] * phi[v]).sum(-1)`` convention);
* **top-K over V** — ``(u, k)`` → the k highest-scoring vertices with
  self excluded, via a batched device product + ``lax.top_k``.

Robustness is the contract, not a feature:

* **Versioned snapshot swap** — the server holds embedding version v
  (loaded from the PR-6 crash-consistent snapshots through
  ``ckpt.read_meta`` / ``load_checkpoint``; torn steps are invisible and
  the newest VALID one is used) while ingest produces v+1, then swaps
  atomically: a wave captures its snapshot reference at formation, so
  requests batched pre-swap finish on v and post-swap batches read v+1 —
  a half-swapped read cannot be expressed.
* **Health-gated swap** — a candidate must pass ``health.SnapshotGate``
  (finite phi, version/graph_version monotonicity, norm-vs-EMA gates)
  before it is eligible; a divergent refresh never reaches readers.
* **SLO-aware degraded reads** — the serve-side degrade ladder mirrors
  the ingest ladder (DESIGN.md §12): *fresh* → *stale-ok* (keep serving
  v while the v+1 refresh is degraded / retrying / rejected; every
  response is stamped ``served_version`` / ``staleness_s``) → *shed*
  (reject at admission when the queue's predicted wait — wave-wall EMA ×
  headroom, the same predictor ``IngestDriver`` uses — blows the
  request deadline, or the queue is full).
* **Fault drills** — ``FaultInjector`` points ``swap`` (inside the swap
  window, before the commit: the active version must keep serving),
  ``serve_wave`` (the wave is re-queued — admitted queries are never
  dropped), and the ``queue_overflow`` corruption site; terminal serve
  failures (no valid snapshot and no active version) dump a flight
  record before raising.

Scoring is **order-pinned**: the d products accumulate in explicit
index order (XLA does not reassociate float adds) and product /
accumulation run as separate executables (so LLVM cannot contract
mul+add into an FMA), making device scores bit-identical to the NumPy
oracle (``oracle_scores`` / ``oracle_topk``) — the serving path is
testable against ground truth at the bit level.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterable, Iterator, List, \
    Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt.checkpoint import load_checkpoint
from repro.common.logging import get_logger
from repro.runtime.faults import FaultInjector, NULL_INJECTOR
from repro.runtime.health import SnapshotGate, SnapshotGateConfig

log = get_logger("repro.runtime.serve")


class ServeError(RuntimeError):
    """Terminal serve failure (no servable version exists)."""


# ---------------------------------------------------------------------------
# Slot-pool wave batching (shared with the LM server)
# ---------------------------------------------------------------------------

def wave_batches(items: Sequence, slots: int) -> Iterator[list]:
    """Yield consecutive waves of at most ``slots`` items: the refill
    order of a fixed slot pool fed from a queue (continuous batching)."""
    slots = max(int(slots), 1)
    for i in range(0, len(items), slots):
        yield list(items[i:i + slots])


# ---------------------------------------------------------------------------
# Order-pinned scoring kernels + NumPy oracle
# ---------------------------------------------------------------------------

def chain_dot(a, b):
    """Dot product along the last axis: elementwise products, then an
    EXPLICIT left-to-right chain of adds. This is the oracle-side half of
    the bit-reproducibility contract — neither numpy nor XLA reassociates
    floating-point adds, so the only divergence hazard is FMA contraction
    (LLVM fusing ``acc + a*b`` into one rounding). The device path below
    forecloses it by splitting product and accumulation into SEPARATE
    jitted executables: the accumulate kernel contains no multiply, so
    there is nothing to contract."""
    prod = a * b
    acc = prod[..., 0]
    for j in range(1, prod.shape[-1]):
        acc = acc + prod[..., j]
    return acc


@jax.jit
def _pair_products_jit(phi: jax.Array, u: jax.Array,
                       cand: jax.Array) -> jax.Array:
    """(B,) query nodes × (B, C) candidate ids → (B, C, d) products."""
    return phi[u][:, None, :] * phi[cand]


@jax.jit
def _all_products_jit(phi: jax.Array, u: jax.Array) -> jax.Array:
    """(B,) query nodes → (B, N, d) products against every vertex. The
    materialized product tensor is the price of exact reproducibility;
    an approximate fast path would use a matmul here."""
    return phi[u][:, None, :] * phi[None, :, :]


@jax.jit
def _accumulate_jit(prod: jax.Array) -> jax.Array:
    """Left-to-right add chain over the last axis — adds only, so FMA
    contraction cannot perturb the result (see ``chain_dot``)."""
    acc = prod[..., 0]
    for j in range(1, prod.shape[-1]):
        acc = acc + prod[..., j]
    return acc


def _score_candidates(phi: jax.Array, u: jax.Array,
                      cand: jax.Array) -> jax.Array:
    """(B,) query nodes × (B, C) candidate ids → (B, C) scores."""
    return _accumulate_jit(_pair_products_jit(phi, u, cand))


@functools.partial(jax.jit, static_argnames=("k",))
def _topk_from_scores_jit(scores: jax.Array, u: jax.Array, k: int):
    """(B, N) scores → (values, ids) of the k best, self excluded."""
    scores = scores.at[jnp.arange(u.shape[0]), u].set(-jnp.inf)
    return jax.lax.top_k(scores, k)


def _topk(phi: jax.Array, u: jax.Array, k: int):
    """(B,) query nodes → (values, ids) of the k best vertices."""
    return _topk_from_scores_jit(
        _accumulate_jit(_all_products_jit(phi, u)), u, k)


def oracle_scores(phi: np.ndarray, u: int,
                  candidates: np.ndarray) -> np.ndarray:
    """NumPy reference for pair scoring — same chain, same order."""
    phi = np.asarray(phi, np.float32)
    cand = np.asarray(candidates)
    return chain_dot(phi[int(u)][None, :], phi[cand])


def oracle_topk(phi: np.ndarray, u: int, k: int):
    """NumPy reference for top-K: (values, ids), self excluded, ties
    broken toward the lower id (matching ``lax.top_k``)."""
    phi = np.asarray(phi, np.float32)
    scores = chain_dot(phi[int(u)][None, :], phi)
    scores[int(u)] = -np.inf
    ids = np.argsort(-scores, kind="stable")[:k]
    return scores[ids], ids


# ---------------------------------------------------------------------------
# Request / response / snapshot types
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Query:
    """One admitted read: pair scoring (``candidates``) or top-K (``k``)."""

    qid: int
    u: int
    candidates: Optional[np.ndarray] = None
    k: int = 0
    deadline_s: Optional[float] = None
    submit_t: float = 0.0


@dataclasses.dataclass
class Response:
    """Every response is stamped with the version that produced it and
    how stale that version is — the degraded-read contract: a reader can
    always tell fresh from stale-ok."""

    qid: int
    u: int
    ids: np.ndarray             # candidate ids (echoed) or top-K ids
    scores: np.ndarray
    served_version: int
    served_graph_version: int
    staleness_s: float
    freshness: str              # "fresh" | "stale"
    latency_s: float


@dataclasses.dataclass
class EmbedSnapshot:
    """One immutable servable version. ``phi`` lives on device; waves
    capture the whole object by reference, so a swap can never tear a
    wave's read."""

    phi: jax.Array              # (N, d) node embeddings
    version: int                # checkpoint step (snapshot sequence)
    graph_version: int
    global_step: int
    created_t: float            # server clock at swap commit


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 32       # slot-pool width per wave
    max_queue: int = 1024       # admission queue bound (overflow → shed)
    default_deadline_s: Optional[float] = None   # per-request unless set
    headroom: float = 1.5       # predicted wait = waves × EMA × headroom
    ema_beta: float = 0.5       # wave-wall EMA decay (as IngestDriver)
    latency_window: int = 256   # response-latency percentile history


_UNSET = object()


class EmbedServer:
    """Versioned, SLO-aware embedding read path over one slot pool.

    Single writer (the ingest/refresh lifecycle offering snapshots),
    many readers (``submit`` + ``tick``). The active-version pointer,
    the queue, and the ladder state share one lock; scoring itself runs
    outside it on the wave's captured snapshot.
    """

    def __init__(self, cfg: ServeConfig = ServeConfig(), *,
                 gate: Optional[SnapshotGate] = None,
                 faults: FaultInjector = NULL_INJECTOR,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.gate = gate or SnapshotGate(SnapshotGateConfig())
        self.faults = faults
        self.clock = clock
        self._lock = threading.Lock()
        self._queue: Deque[Query] = deque()
        self._active: Optional[EmbedSnapshot] = None
        self._next_qid = 0
        self._wave_ema: Optional[float] = None
        self._newer_pending = False     # a newer candidate exists but was
                                        # rejected (torn / unhealthy)
        self.refresh_state = "ok"       # "ok" | "degraded" | "failed"
        self.responses: Dict[int, Response] = {}
        # -- accounting ------------------------------------------------------
        self.admitted = 0
        self.served = 0
        self.shed: Dict[str, int] = {}
        self.swaps = 0
        self.rejected_candidates = 0
        self.wave_faults = 0
        self.served_by_version: Dict[int, int] = {}
        self.served_by_freshness = {"fresh": 0, "stale": 0}
        self._latency = obs.Histogram(window=max(cfg.latency_window, 1))
        obs.REGISTRY.attach("serve.latency_s", self._latency)

    # -- versioned snapshot swap --------------------------------------------
    def offer_snapshot(self, root: str, step: Optional[int] = None) -> bool:
        """Load, health-gate, and (if admitted) atomically swap in the
        newest valid checkpoint under ``root``. Returns True on swap.

        Torn/corrupt steps are invisible to the loader (it falls back to
        the newest valid one); a fallback that is not newer than the
        active version is a no-op, not a regression. A candidate the gate
        rejects leaves the active version serving and marks the ladder
        stale (a newer version exists but is unhealthy). Having NO active
        version and no servable candidate is terminal: flight-record dump
        + raise — there is nothing to degrade to.
        """
        with obs.trace_span("serve.offer", root=str(root)):
            try:
                loaded, arrays, meta = load_checkpoint(
                    root, step, only=("phi_in",))
            except (FileNotFoundError, OSError, ValueError) as e:
                obs.inc("serve.offer.unreadable")
                if self._active is None:
                    obs.dump_flight_record("serve_no_snapshot",
                                           root=str(root), error=str(e))
                    raise ServeError(
                        f"no servable snapshot under {root}: {e}") from e
                log.warning("snapshot offer unreadable (%s); keeping "
                            "version %d", e, self._active.version)
                return False

            if self._active is not None and loaded <= self._active.version:
                # Re-offer of the active (or an older fallback after a
                # torn newer step): nothing to do, nothing unhealthy.
                obs.inc("serve.offer.not_newer")
                return False

            phi = np.asarray(arrays["phi_in"], np.float32)
            if phi.ndim == 3:           # (S, N, d) replicas → node space
                phi = phi[0] if phi.shape[0] == 1 else phi.mean(axis=0)
            gv = int(meta.get("graph_version", 0))
            # The swap window: a crash here (drill point "swap") leaves
            # the previous version serving AND the gate's monotonic
            # record untouched, so the same step can be re-offered —
            # the gate must only remember snapshots that COMMITTED.
            self.faults.fire("swap", note=loaded)
            ok, reason = self.gate.admit(phi, version=loaded,
                                         graph_version=gv)
            if not ok:
                self.rejected_candidates += 1
                if self._active is None:
                    obs.dump_flight_record("serve_candidate_rejected",
                                           root=str(root), version=loaded,
                                           gate_reason=reason)
                    raise ServeError(
                        f"candidate snapshot {loaded} rejected ({reason}) "
                        "with no active version to fall back to")
                with self._lock:
                    self._newer_pending = True
                log.warning("candidate snapshot %d rejected (%s); serving "
                            "version %d stale", loaded, reason,
                            self._active.version)
                return False

            snap = EmbedSnapshot(
                phi=jnp.asarray(phi), version=int(loaded),
                graph_version=gv,
                global_step=int(meta.get("global_step", 0)),
                created_t=self.clock())
            # The commit is a single pointer store under the lock.
            with self._lock:
                self._active = snap
                self._newer_pending = False
            self.swaps += 1
            obs.inc("serve.swaps")
            obs.set_gauge("serve.active_version", loaded)
            obs.set_gauge("serve.active_graph_version", gv)
            obs.span_event("serve.swap", version=loaded, graph_version=gv)
            return True

    def note_refresh(self, state: str) -> None:
        """Ingest-side refresh status feed: "ok" | "degraded" | "failed".
        Anything but "ok" moves responses to the stale-ok rung until the
        next successful swap."""
        assert state in ("ok", "degraded", "failed"), state
        with self._lock:
            self.refresh_state = state
        obs.inc(f"serve.refresh.{state}")

    def active_version(self) -> Optional[int]:
        with self._lock:
            return None if self._active is None else self._active.version

    def active_phi(self) -> Optional[np.ndarray]:
        with self._lock:
            snap = self._active
        return None if snap is None else np.asarray(snap.phi)

    # -- admission ----------------------------------------------------------
    def submit(self, u: int, candidates: Optional[Iterable[int]] = None, *,
               k: Optional[int] = None, deadline_s: Any = _UNSET
               ) -> Optional[int]:
        """Admit one query (returns its qid) or shed it (returns None).

        Shedding happens only at admission — an admitted query is always
        answered (fresh or stale): no version at all, a full queue (or
        the ``queue_overflow`` drill), or a predicted wait that blows the
        deadline all reject at the door with backpressure.
        """
        if deadline_s is _UNSET:
            deadline_s = self.cfg.default_deadline_s
        now = self.clock()
        with self._lock:
            if self._active is None:
                return self._shed("no_version")
            if self.faults.inject("queue_overflow") \
                    or len(self._queue) >= self.cfg.max_queue:
                return self._shed("overflow")
            if deadline_s is not None and self._wave_ema is not None:
                waves_ahead = len(self._queue) // self.cfg.batch_slots + 1
                predicted = waves_ahead * self._wave_ema \
                    * self.cfg.headroom
                if predicted > deadline_s:
                    return self._shed("deadline")
            qid = self._next_qid
            self._next_qid += 1
            q = Query(qid=qid, u=int(u),
                      candidates=(None if candidates is None
                                  else np.asarray(candidates, np.int32)),
                      k=int(k or 0), deadline_s=deadline_s, submit_t=now)
            self._queue.append(q)
            self.admitted += 1
        obs.inc("serve.admitted")
        return qid

    def _shed(self, reason: str) -> None:
        """(lock held) Count one shed admission."""
        self.shed[reason] = self.shed.get(reason, 0) + 1
        obs.inc(f"serve.shed.{reason}")
        return None

    # -- the serving loop ---------------------------------------------------
    def tick(self) -> List[Response]:
        """Score one wave from the queue on the snapshot captured at wave
        formation. On a wave fault the wave is re-queued at the front and
        the failure propagates — admitted queries survive the crash."""
        with self._lock:
            if not self._queue:
                return []
            take = min(len(self._queue), max(self.cfg.batch_slots, 1))
            wave = [self._queue.popleft() for _ in range(take)]
            snap = self._active
            freshness = self._freshness_locked()
        t0 = self.clock()
        try:
            self.faults.fire("serve_wave", note=len(wave))
            with obs.trace_span("serve.wave", size=len(wave),
                                version=snap.version):
                scored = self._score_wave(wave, snap)
        except Exception:
            with self._lock:
                self._queue.extendleft(reversed(wave))
            self.wave_faults += 1
            obs.inc("serve.wave_faults")
            raise
        now = self.clock()
        wall = now - t0
        with self._lock:
            b = self.cfg.ema_beta
            self._wave_ema = (wall if self._wave_ema is None
                              else b * self._wave_ema + (1 - b) * wall)
        out = []
        for q, (ids, scores) in zip(wave, scored):
            resp = Response(
                qid=q.qid, u=q.u, ids=ids, scores=scores,
                served_version=snap.version,
                served_graph_version=snap.graph_version,
                staleness_s=max(now - snap.created_t, 0.0),
                freshness=freshness, latency_s=now - q.submit_t)
            self.responses[q.qid] = resp
            out.append(resp)
            self.served += 1
            self.served_by_version[snap.version] = \
                self.served_by_version.get(snap.version, 0) + 1
            self.served_by_freshness[freshness] += 1
            self._latency.observe(resp.latency_s)
        obs.inc("serve.responses", len(out))
        obs.set_gauge("serve.staleness_s",
                      max(now - snap.created_t, 0.0))
        return out

    def _freshness_locked(self) -> str:
        return ("fresh" if self.refresh_state == "ok"
                and not self._newer_pending else "stale")

    def _score_wave(self, wave: List[Query], snap: EmbedSnapshot) -> list:
        """Batched device scoring of one wave. Top-K queries group by k,
        pair queries by a padded candidate bucket (powers of two, to
        bound recompiles); padding never leaks — per-query slices are
        trimmed before the response."""
        results: Dict[int, tuple] = {}
        topk_groups: Dict[int, List[Query]] = {}
        cand_groups: Dict[int, List[Query]] = {}
        for q in wave:
            if q.candidates is None:
                topk_groups.setdefault(q.k, []).append(q)
            else:
                width = max(1, 1 << (len(q.candidates) - 1).bit_length()) \
                    if len(q.candidates) else 1
                cand_groups.setdefault(width, []).append(q)
        for k, group in topk_groups.items():
            u = jnp.asarray([q.u for q in group], jnp.int32)
            vals, ids = _topk(snap.phi, u, k)
            vals, ids = np.asarray(vals), np.asarray(ids)
            for i, q in enumerate(group):
                results[q.qid] = (ids[i], vals[i])
        for width, group in cand_groups.items():
            cand = np.zeros((len(group), width), np.int32)
            for i, q in enumerate(group):
                cand[i, :len(q.candidates)] = q.candidates
            u = jnp.asarray([q.u for q in group], jnp.int32)
            scores = np.asarray(
                _score_candidates(snap.phi, u, jnp.asarray(cand)))
            for i, q in enumerate(group):
                n = len(q.candidates)
                results[q.qid] = (np.asarray(q.candidates), scores[i, :n])
        return [results[q.qid] for q in wave]

    def drain(self) -> List[Response]:
        """Tick until the queue is empty; responses in completion order."""
        out: List[Response] = []
        while True:
            batch = self.tick()
            if not batch:
                return out
            out.extend(batch)

    def serve(self, queries: List[Dict[str, Any]]) -> List[Optional[Response]]:
        """Convenience: submit a list of ``{"u", "candidates"|"k", ...}``
        dicts, drain, and return responses aligned to the input order
        (``None`` where admission shed the query)."""
        qids = [self.submit(spec["u"], spec.get("candidates"),
                            k=spec.get("k"),
                            deadline_s=spec.get("deadline_s", _UNSET))
                for spec in queries]
        self.drain()
        return [None if qid is None else self.responses.get(qid)
                for qid in qids]

    # -- accounting ---------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            depth = len(self._queue)
            active = self._active
            freshness = self._freshness_locked()
        shed_total = sum(self.shed.values())
        return {
            "admitted": self.admitted,
            "served": self.served,
            "shed": dict(self.shed),
            "shed_total": shed_total,
            "offered_total": self.admitted + shed_total,
            "availability": self.served / max(self.admitted, 1),
            "swaps": self.swaps,
            "rejected_candidates": self.rejected_candidates,
            "wave_faults": self.wave_faults,
            "queue_depth": depth,
            "active_version": None if active is None else active.version,
            "refresh_state": self.refresh_state,
            "freshness": freshness,
            "served_by_version": dict(self.served_by_version),
            "served_by_freshness": dict(self.served_by_freshness),
            "latency_p50_s": self._latency.percentile(50),
            "latency_p99_s": self._latency.percentile(99),
        }
