"""Fault-injection harness for the walk→train lifecycle.

The LM trainer shipped a step-granular ``FailureInjector``; this module
generalizes it into named **injection points** threaded through the whole
embedding pipeline so recovery invariants can be exercised at every host
boundary where a real crash can land:

    ``superstep``   — between walk dispatch chunks inside a round (a crash
                      mid-round: some chunks walked, none committed);
    ``round``       — at the top of a round iteration (after the ΔD
                      decision, before training);
    ``tail``        — between schedule-tail training iterations;
    ``refresh``     — at refresh entry (churn staged, nothing spliced);
    ``refresh_splice`` — between per-round ``ring_replace`` splices inside
                      a refresh (the half-updated-ring hazard);
    ``ckpt_write``  — immediately before a snapshot commits (the snapshot
                      is lost; recovery must fall back one snapshot);
    ``wal_append``  — after a WAL record is durable but before it applies.

Each point carries a cumulative occurrence counter (monotonic across
supervisor restarts — the same injector object rides through the restart
loop), and a plan maps point → occurrence indices at which to raise
``SimulatedFailure``. Every planned occurrence fires at most once, which is
exactly the "crash once, then the retry succeeds" shape a restart test
needs.

Torn-write simulation: ``torn("ckpt")`` / ``torn("wal")`` report whether
the *current* occurrence should leave a torn artifact behind (half a WAL
record, a committed checkpoint directory with a corrupt manifest) before
raising — the writer cooperates by truncating its own output. This models
a crash midway through the physical write, the case the fsync-before-
rename and WAL-checksum protocols exist for.

Silent-corruption simulation: ``inject(kind)`` is the non-crashing sibling
of ``torn`` — it reports whether the current occurrence of a *corruption
site* should poison its data instead of raising. The pipeline's training
loop consults ``inject("phi_nan")`` (overwrite embedding rows with NaN —
a flipped bit / bad DMA) and ``inject("lr_spike")`` (multiply the chunk's
learning rates — a scheduler bug / optimizer blow-up) so the health
watchdog's divergence → rollback → backoff path can be exercised against
*real* divergences, not mocked verdicts.

Liveness simulation: ``probe_ok(shard)`` answers a liveness probe for one
walk shard; ``down_plan`` maps shard id → probe occurrence from which the
shard stops answering FOREVER (persistent loss — a dead machine, not a
transient timeout). ``LivenessProbe`` turns consecutive missed probes into
a dead-shard declaration the pipeline reacts to with elastic
reconfiguration.

``run_with_restarts`` is the generic supervisor loop a cluster agent would
drive: attempt → on ``SimulatedFailure`` recover from durable state →
re-attempt, bounded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

from repro import obs


class SimulatedFailure(RuntimeError):
    """Stands in for a node crash / preemption."""


#: Canonical pipeline injection points (tests sweep these).
PIPELINE_POINTS = ("superstep", "round", "tail", "ckpt_write")
INGEST_POINTS = ("wal_append", "refresh", "refresh_splice")
#: Serve-side injection points (DESIGN.md §14): ``swap`` fires inside the
#: snapshot-swap window (before the commit — the active version must stay
#: serving), ``serve_wave`` between admission and wave scoring. The
#: ``queue_overflow`` corruption site (via ``inject``) forces admission to
#: behave as if the queue were full — a shed drill without real load.
SERVE_POINTS = ("swap", "serve_wave")


@dataclasses.dataclass
class FaultInjector:
    """Raise ``SimulatedFailure`` at planned (point, occurrence) pairs.

    plan:  {"round": (1,), "wal_append": (0,)} — fail the 2nd time the
           ``round`` point is reached and the 1st ``wal_append``.
    torn_plan: occurrences at which the failure should additionally leave
           a torn artifact ({"ckpt": (0,), "wal": (0,)}); consumed by the
           writer via ``torn(kind)`` *before* the matching ``fire``.
    inject_plan: occurrences at which a corruption site should poison its
           data in place of crashing ({"phi_nan": (2,)}); consumed via
           ``inject(kind)`` — no exception is raised, the corruption is
           expected to be CAUGHT downstream (by the health watchdog).
    down_plan: {shard_id: probe_occurrence} — the shard stops answering
           liveness probes from that occurrence on (persistent loss). A
           ``(start, stop)`` tuple value makes the outage TRANSIENT: the
           shard misses probes for occurrences ``start <= i < stop`` and
           answers again afterwards (capacity returns — the re-JOIN drill).
    """

    plan: Mapping[str, Iterable[int]] = dataclasses.field(default_factory=dict)
    torn_plan: Mapping[str, Iterable[int]] = dataclasses.field(
        default_factory=dict)
    inject_plan: Mapping[str, Iterable[int]] = dataclasses.field(
        default_factory=dict)
    down_plan: Mapping[int, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._plan = {p: set(occ) for p, occ in dict(self.plan).items()}
        self._torn = {p: set(occ) for p, occ in dict(self.torn_plan).items()}
        self._inject = {p: set(occ)
                        for p, occ in dict(self.inject_plan).items()}
        self._down = {}
        for s, t in dict(self.down_plan).items():
            if isinstance(t, (tuple, list)):
                start, stop = t
                self._down[int(s)] = (int(start), int(stop))
            else:
                self._down[int(s)] = int(t)
        self.counts: Dict[str, int] = {}
        self.fired: list = []          # [(point, occurrence), ...]
        self.injected: list = []       # [(kind, occurrence), ...]

    def fire(self, point: str, note: Any = None) -> None:
        """Count one occurrence of ``point``; raise if the plan says so."""
        i = self.counts.get(point, 0)
        self.counts[point] = i + 1
        planned = self._plan.get(point)
        if planned and i in planned:
            planned.discard(i)         # fire at most once per occurrence
            self.fired.append((point, i))
            # Postmortem first, crash second: the dump carries the open
            # spans (round/shard/graph_version) of the site that died.
            obs.span_event("fault.fire", point=point, occurrence=i,
                           note=note)
            obs.inc(f"faults.fired.{point}")
            obs.dump_flight_record(f"fault_{point}", point=point,
                                   occurrence=i, note=note)
            raise SimulatedFailure(
                f"injected failure at {point}[{i}]"
                + (f" ({note})" if note is not None else ""))

    def torn(self, kind: str) -> bool:
        """Should the current write of ``kind`` be left torn? (Consumes the
        planned occurrence; the caller raises via ``fire`` afterwards.)"""
        i = self.counts.get(f"torn_{kind}", 0)
        self.counts[f"torn_{kind}"] = i + 1
        planned = self._torn.get(kind)
        if planned and i in planned:
            planned.discard(i)
            obs.span_event("fault.torn", kind=kind, occurrence=i)
            obs.inc(f"faults.torn.{kind}")
            return True
        return False

    def inject(self, kind: str) -> bool:
        """Should the current occurrence of corruption site ``kind`` poison
        its data? Counts the occurrence and consumes the planned one — like
        ``torn``, but no exception follows: the corruption is silent and
        must be *detected* by the layer under test."""
        i = self.counts.get(f"inject_{kind}", 0)
        self.counts[f"inject_{kind}"] = i + 1
        planned = self._inject.get(kind)
        if planned and i in planned:
            planned.discard(i)
            self.injected.append((kind, i))
            obs.span_event("fault.inject", kind=kind, occurrence=i)
            obs.inc(f"faults.injected.{kind}")
            return True
        return False

    def probe_ok(self, shard: int) -> bool:
        """Answer one liveness probe for ``shard`` (ids are the ORIGINAL
        launch-time shard names — they stay stable across elastic
        reconfigurations). A shard planned down at occurrence t misses
        every probe from its t-th on (persistent loss); a ``(start, stop)``
        plan misses only inside that occurrence window (transient outage —
        the machine comes back and may re-JOIN)."""
        i = self.counts.get(f"probe_{shard}", 0)
        self.counts[f"probe_{shard}"] = i + 1
        t = self._down.get(int(shard))
        if t is None:
            return True
        if isinstance(t, tuple):
            start, stop = t
            return not (start <= i < stop)
        return i < t

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._plan.values()) + sum(
            len(v) for v in self._torn.values()) + sum(
            len(v) for v in self._inject.values())


class NullInjector(FaultInjector):
    """Injector that never fires (the production default)."""

    def __init__(self):
        super().__init__(plan={}, torn_plan={})

    def fire(self, point: str, note: Any = None) -> None:  # noqa: D102
        pass

    def torn(self, kind: str) -> bool:                     # noqa: D102
        return False

    def inject(self, kind: str) -> bool:                   # noqa: D102
        return False

    def probe_ok(self, shard: int) -> bool:                # noqa: D102
        return True


NULL_INJECTOR = NullInjector()


@dataclasses.dataclass
class LivenessProbe:
    """Consecutive-miss liveness detector over the walk shards.

    Shards are tracked by their ORIGINAL launch-time ids (``names``) so an
    injector's ``down_plan`` stays meaningful across elastic
    reconfigurations that compact the dispatch id space. ``poll`` probes
    every still-tracked shard once and returns the CURRENT dispatch ids of
    shards that just crossed ``misses_to_dead`` consecutive misses —
    exactly the ids ``StreamingEmbedPipeline.elastic_reconfigure``
    expects. A successful probe resets the shard's miss counter, so a
    transient hiccup shorter than the threshold never triggers a (costly,
    irreversible) reconfiguration. After reacting, callers MUST call
    ``remove(dispatch_id)`` so the probe's id space tracks the compacted
    assignment.

    Removed shards keep being probed: ``hits_to_live`` consecutive
    *successful* probes of a dead name mark it rejoin-eligible
    (``rejoinable()``) — the symmetric hysteresis to ``misses_to_dead``,
    so one lucky probe of a flapping machine never triggers a (costly)
    k → k+1 re-JOIN. After growing back, callers MUST call
    ``rejoin(name)``; the shard re-enters the dispatch space at the END
    (matching ``mpgp.rejoin_shard``, which appends the returned shard).
    """

    num_shards: int
    misses_to_dead: int = 2
    hits_to_live: int = 2

    def __post_init__(self):
        self.names = list(range(self.num_shards))   # index = dispatch id
        self.misses = [0] * self.num_shards
        self.dead_names: list = []
        self.dead_hits: Dict[int, int] = {}         # name -> consecutive oks
        self.probes = 0

    def poll(self, faults: "FaultInjector" = NULL_INJECTOR) -> list:
        """One probe sweep; returns newly-dead shards as dispatch ids,
        in descending order (safe to reconfigure + ``remove`` one by one,
        ids below a removed one are untouched). Dead names are probed in
        the same sweep so rejoin eligibility accrues."""
        newly_dead = []
        self.probes += 1
        for i, name in enumerate(self.names):
            if faults.probe_ok(name):
                self.misses[i] = 0
                continue
            self.misses[i] += 1
            if self.misses[i] >= self.misses_to_dead:
                newly_dead.append(i)
        for name in self.dead_names:
            if faults.probe_ok(name):
                self.dead_hits[name] = self.dead_hits.get(name, 0) + 1
            else:
                self.dead_hits[name] = 0
        return sorted(newly_dead, reverse=True)

    def remove(self, dispatch_id: int) -> int:
        """Stop tracking a declared-dead shard; ids above it shift down by
        one (matching ``mpgp.compact_assignment``). Returns the shard's
        stable launch-time name."""
        name = self.names.pop(dispatch_id)
        self.misses.pop(dispatch_id)
        self.dead_names.append(name)
        self.dead_hits[name] = 0
        return name

    def rejoinable(self) -> list:
        """Dead names that answered ``hits_to_live`` consecutive probes —
        capacity is back and the pipeline may grow k → k+1."""
        return [n for n in self.dead_names
                if self.dead_hits.get(n, 0) >= self.hits_to_live]

    def rejoin(self, name: int) -> int:
        """Re-track a returned shard. It gets the HIGHEST dispatch id
        (appended), mirroring ``mpgp.rejoin_shard``'s id layout. Returns
        the new dispatch id."""
        self.dead_names.remove(name)
        self.dead_hits.pop(name, None)
        self.names.append(name)
        self.misses.append(0)
        return len(self.names) - 1


@dataclasses.dataclass
class FailureInjector:
    """Step-granular injector (the original LM-trainer interface, kept as
    the compatibility surface; ``FaultInjector`` is the generalized form)."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_restarts(
    attempt: Callable[[int], Any],
    *,
    recover: Optional[Callable[[int], None]] = None,
    max_restarts: int = 8,
) -> Tuple[Any, int]:
    """Supervisor loop: run ``attempt(restart_idx)``; on ``SimulatedFailure``
    call ``recover(restart_idx)`` (restore from durable state) and retry.

    Returns (result, restarts). Raises the last failure once
    ``max_restarts`` is exhausted — a supervisor must not loop forever on a
    deterministic crash.
    """
    restarts = 0
    while True:
        try:
            return attempt(restarts), restarts
        except SimulatedFailure as e:
            restarts += 1
            obs.span_event("supervisor.restart", restart=restarts,
                           error=str(e))
            obs.inc("supervisor.restarts")
            if restarts > max_restarts:
                obs.dump_flight_record("restarts_exhausted",
                                       restarts=restarts, error=str(e))
                raise
            if recover is not None:
                recover(restarts)
