"""Training health watchdog: detect divergence, drive rollback + backoff.

PR 6 made crashes survivable; a *silent* divergence — NaN from a bad
reduction, a loss blow-up from an optimizer spike — survives every crash
protocol because nothing crashes: the poisoned phi just keeps training and
the damage shows up days later as a bad AUC. This module is the detection
half of the self-healing loop (DESIGN.md §12):

* ``core.dsgl.train_chunk_checked`` computes four scalar reductions inside
  the training dispatch itself (non-finite counts over phi and the chunk
  losses, the update Frobenius norm, the phi norm) — one extra host pull
  per check, no extra dispatch;
* ``HealthMonitor`` consumes them on the host at a deterministic cadence
  (keyed off ``global_step``, so a rolled-back replay re-checks the same
  windows), maintains loss / update-norm EMAs, and raises
  ``DivergenceError`` on a non-finite observation or an EMA spike;
* ``StreamingEmbedPipeline`` catches the error, restores the last
  consistent snapshot IN PLACE, scales the learning rate down by
  ``lr_backoff`` (persisted — a resumed process keeps the backoff), and
  quarantines the offending ring slots by re-walking their roots under the
  original round keys before resuming the run loop.

Detection latency is bounded by ``check_every`` training steps; the
monitor records it (steps between the last clean check and the detection)
for the BENCH_recovery degraded-mode rows.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro import obs


class DivergenceError(RuntimeError):
    """Training diverged; carries the triggering ``HealthReport``."""

    def __init__(self, report: "HealthReport"):
        super().__init__(
            f"training divergence ({report.kind}) at step {report.step}: "
            f"loss={report.loss:.4g} ema={report.loss_ema:.4g} "
            f"nonfinite={report.nonfinite}")
        self.report = report


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """One divergence verdict: what tripped, where, and which ring slots
    the diverging chunk was trained from (the quarantine set)."""

    kind: str                   # "nonfinite" | "loss_spike" | "update_spike"
    step: int                   # global_step AFTER the offending chunk
    loss: float
    loss_ema: float
    nonfinite: int
    update_norm: float
    slots: np.ndarray           # ring slots gathered by the offending chunk
    detection_steps: int        # steps since the previous clean check


@dataclasses.dataclass
class HealthConfig:
    """Watchdog thresholds (DESIGN.md §12 lists the tuning rationale)."""

    check_every: int = 1        # check cadence in GLOBAL STEPS (lifetimes);
                                # a chunk is checked when it crosses a
                                # multiple, so cadence survives replay
    ema_beta: float = 0.8       # loss / update-norm EMA decay per check
    spike_factor: float = 4.0   # loss > factor * EMA → divergence
    update_spike_factor: float = 0.0   # same gate on update norm (0 = off,
                                       # the norm is still tracked/reported)
    warmup_checks: int = 3      # EMA burn-in before the spike gates arm
    lr_backoff: float = 0.5     # lr multiplier applied per rollback
    max_rollbacks: int = 3      # give up (re-raise) after this many


@dataclasses.dataclass
class HealthMonitor:
    """Host-side divergence detector fed by ``train_chunk_checked``.

    The monitor is pure bookkeeping — it never touches device state. The
    pipeline owns the reaction (rollback / backoff / quarantine) and calls
    ``note_rollback`` so ``report()`` carries the full healing history for
    benchmarks and operators.
    """

    cfg: HealthConfig = dataclasses.field(default_factory=HealthConfig)

    def __post_init__(self):
        self.loss_ema: Optional[float] = None
        self.update_ema: Optional[float] = None
        self.checks = 0
        self.detections: List[HealthReport] = []
        self.rollbacks = 0
        self.quarantined_slots = 0
        self._last_check_step = 0

    # -- cadence -----------------------------------------------------------
    def due(self, global_step: int, count: int) -> bool:
        """Should the chunk covering steps [global_step, global_step+count)
        run through the checked path? Deterministic in ``global_step`` so a
        rolled-back replay re-checks the exact same windows."""
        ce = max(self.cfg.check_every, 1)
        return (global_step // ce) != ((global_step + count) // ce)

    # -- observation -------------------------------------------------------
    def observe(self, stats: Dict[str, Any], *, step: int, count: int,
                slots: np.ndarray) -> None:
        """Digest one checked chunk's reductions; raise ``DivergenceError``
        on a non-finite observation or an EMA spike.

        ``stats`` are the device scalars of ``train_chunk_checked``;
        ``count`` the chunk's step count (losses are normalized per step so
        the EMA is chunk-size invariant); ``slots`` the ring slots the
        chunk gathered (the quarantine candidates on divergence).
        """
        cfg = self.cfg
        self.checks += 1
        nonfinite = int(stats["nonfinite"]) + int(stats["loss_nonfinite"])
        loss = float(stats["loss_sum"]) / max(count, 1)
        update = float(stats["update_norm"])
        detection_steps = step - self._last_check_step

        # Telemetry piggybacks on the scalars already pulled to host for
        # the verdict — no additional device syncs.
        obs.inc("health.checks")
        obs.set_gauge("health.loss", loss)
        obs.set_gauge("health.update_norm", update)
        obs.set_gauge("health.phi_norm", float(stats.get("phi_norm", 0.0)))
        obs.set_gauge("health.nonfinite", nonfinite)

        kind = None
        if nonfinite > 0:
            kind = "nonfinite"
        elif (self.loss_ema is not None
                and self.checks > cfg.warmup_checks
                and loss > cfg.spike_factor * max(self.loss_ema, 1e-12)):
            kind = "loss_spike"
        elif (cfg.update_spike_factor > 0
                and self.update_ema is not None
                and self.checks > cfg.warmup_checks
                and np.isfinite(update)
                and update > cfg.update_spike_factor
                * max(self.update_ema, 1e-12)):
            kind = "update_spike"

        if kind is not None:
            report = HealthReport(
                kind=kind, step=step, loss=loss,
                loss_ema=float(self.loss_ema or 0.0),
                nonfinite=nonfinite, update_norm=update,
                slots=np.asarray(slots), detection_steps=detection_steps)
            self.detections.append(report)
            obs.span_event("health.divergence", kind=kind, step=step,
                           loss=loss, nonfinite=nonfinite,
                           detection_steps=detection_steps)
            obs.inc(f"health.divergence.{kind}")
            obs.dump_flight_record(f"divergence_{kind}", kind=kind,
                                   step=step, loss=loss,
                                   nonfinite=nonfinite)
            raise DivergenceError(report)

        # Clean check: fold into the EMAs, advance the detection clock.
        b = cfg.ema_beta
        self.loss_ema = (loss if self.loss_ema is None
                         else b * self.loss_ema + (1 - b) * loss)
        if np.isfinite(update):
            self.update_ema = (update if self.update_ema is None
                               else b * self.update_ema + (1 - b) * update)
        self._last_check_step = step

    # -- healing bookkeeping (called by the pipeline) ----------------------
    def note_rollback(self, *, restored_step: int, lr_scale: float,
                      quarantined: int) -> None:
        self.rollbacks += 1
        self.quarantined_slots += int(quarantined)
        obs.span_event("health.rollback", restored_step=restored_step,
                       lr_scale=lr_scale, quarantined=int(quarantined))
        obs.inc("health.rollbacks")
        # Replay restarts below the EMA's reference point; reset the
        # detection clock so latency accounting stays truthful.
        self._last_check_step = restored_step

    def exhausted(self) -> bool:
        return self.rollbacks >= self.cfg.max_rollbacks

    def report(self) -> Dict[str, Any]:
        """Operator/benchmark summary of the watchdog's run."""
        return {
            "checks": self.checks,
            "detections": len(self.detections),
            "rollbacks": self.rollbacks,
            "quarantined_slots": self.quarantined_slots,
            "loss_ema": self.loss_ema,
            "update_ema": self.update_ema,
            "detection_kinds": [d.kind for d in self.detections],
            "detection_steps": [d.detection_steps for d in self.detections],
        }


# ---------------------------------------------------------------------------
# Snapshot admission gate (serve-side health, DESIGN.md §14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SnapshotGateConfig:
    """Admission thresholds for candidate serving snapshots.

    The serve-side sibling of ``HealthConfig``: instead of watching
    per-chunk training reductions, the gate judges a whole candidate
    embedding table before it can reach readers. The norm-spike gate uses
    the same EMA-vs-factor shape as ``HealthMonitor`` so the two halves of
    the health story tune the same way.
    """

    min_mean_norm: float = 1e-8     # below → degenerate (all-zero) table
    spike_factor: float = 8.0       # mean norm > factor * EMA → reject;
                                    # < EMA / factor → reject (collapse)
    ema_beta: float = 0.8           # EMA decay over ADMITTED snapshots
    warmup_admits: int = 1          # admitted snapshots before spike arms


@dataclasses.dataclass
class SnapshotGate:
    """Health-gate a candidate embedding snapshot before a serve swap.

    Checks, in order: every phi entry finite; embedding version strictly
    monotonic (a re-published or rolled-back step must not regress
    readers); graph_version monotonic (serving must never step back to a
    pre-churn graph); mean row norm above ``min_mean_norm`` and within
    ``spike_factor`` of the EMA over previously-admitted snapshots. A
    divergent refresh that escaped the training watchdog is stopped here —
    the last line of defense before readers.

    ``admit`` returns ``(ok, reason)`` and never raises: the server owns
    the reaction (keep serving the active version, count the rejection).
    """

    cfg: SnapshotGateConfig = dataclasses.field(
        default_factory=SnapshotGateConfig)

    def __post_init__(self):
        self.norm_ema: Optional[float] = None
        self.admits = 0
        self.last_version: Optional[int] = None
        self.last_graph_version: Optional[int] = None
        self.rejections: List[Dict[str, Any]] = []

    def admit(self, phi: np.ndarray, *, version: int,
              graph_version: int = 0) -> tuple:
        cfg = self.cfg
        phi = np.asarray(phi)
        reason = None
        mean_norm = 0.0
        if not np.all(np.isfinite(phi)):
            reason = "nonfinite_phi"
        elif self.last_version is not None and version <= self.last_version:
            reason = "version_regression"
        elif (self.last_graph_version is not None
                and graph_version < self.last_graph_version):
            reason = "graph_version_regression"
        else:
            mean_norm = float(
                np.linalg.norm(phi.reshape(phi.shape[0], -1), axis=1).mean())
            if mean_norm < cfg.min_mean_norm:
                reason = "degenerate_norm"
            elif (self.norm_ema is not None
                    and self.admits >= cfg.warmup_admits
                    and not (self.norm_ema / cfg.spike_factor
                             <= mean_norm
                             <= self.norm_ema * cfg.spike_factor)):
                reason = "norm_spike"

        if reason is not None:
            rec = {"reason": reason, "version": int(version),
                   "graph_version": int(graph_version),
                   "mean_norm": mean_norm}
            self.rejections.append(rec)
            obs.span_event("serve.gate.reject", **rec)
            obs.inc(f"serve.gate.rejected.{reason}")
            return False, reason

        b = cfg.ema_beta
        self.norm_ema = (mean_norm if self.norm_ema is None
                         else b * self.norm_ema + (1 - b) * mean_norm)
        self.admits += 1
        self.last_version = int(version)
        self.last_graph_version = int(graph_version)
        obs.inc("serve.gate.admitted")
        return True, None
