from repro.kernels.ssm_scan import ops, ref
from repro.kernels.ssm_scan.ops import ssd_chunked_scan

__all__ = ["ops", "ref", "ssd_chunked_scan"]
