"""Pallas TPU kernel: chunked SSD scan (Mamba2), linear-time attention dual.

Grid = (BH, S/Q) with the chunk dimension innermost; the (N x P) state is
VMEM scratch carried across chunks (same revisiting pattern as flash
attention). Each chunk of length Q does three MXU matmuls:

    intra:  y  = ((C B^T) ⊙ L) xdt        L[i,j] = exp(cum_i - cum_j), i>=j
    inter:  y += (C ⊙ exp(cum)) S_prev
    state:  S  = exp(cum_Q) S_prev + (B ⊙ exp(cum_Q - cum))^T xdt

This is the paper-pool Mamba2 SSD decomposition adapted to VMEM tiling:
chunk length Q=128/256 keeps the (Q x Q) decay-masked score tile and the
(N x P) state resident; HBM traffic is exactly one pass over x/B/C.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,   # (1, Q, P)
    loga_ref,  # (1, Q)
    b_ref,     # (1, Q, N)
    c_ref,     # (1, Q, N)
    y_ref,     # (1, Q, P)
    sfin_ref,  # (1, N, P)
    s_scr,     # (N, P) f32 scratch — carried state
    *,
    nc: int,
    q_len: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    xdt = xdt_ref[0].astype(jnp.float32)    # (Q, P)
    loga = loga_ref[0].astype(jnp.float32)  # (Q,)
    b = b_ref[0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0].astype(jnp.float32)        # (Q, N)

    cum = jnp.cumsum(loga)                  # inclusive cumulative log-decay
    total = cum[q_len - 1]

    # intra-chunk: decay-masked "attention" scores
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    li = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    l_mask = jnp.where(li >= lj, decay, 0.0)
    y = jnp.dot(scores * l_mask, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    s_prev = s_scr[...]
    y = y + jnp.dot(c * jnp.exp(cum)[:, None], s_prev,
                    preferred_element_type=jnp.float32)

    # state update for the next chunk
    b_scaled = b * jnp.exp(total - cum)[:, None]
    s_scr[...] = jnp.exp(total) * s_prev + jnp.dot(
        b_scaled.T, xdt, preferred_element_type=jnp.float32
    )

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        sfin_ref[0] = s_scr[...]


def ssd_chunked_pallas(
    xdt: jax.Array,    # (BH, S, P)
    loga: jax.Array,   # (BH, S)
    b: jax.Array,      # (BH, S, N)
    c: jax.Array,      # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    bh, s, p = xdt.shape
    n = b.shape[-1]
    assert s % chunk == 0, "caller pads to chunk multiples"
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, nc=nc, q_len=chunk)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c_: (i, c_, 0)),
            pl.BlockSpec((1, chunk), lambda i, c_: (i, c_)),
            pl.BlockSpec((1, chunk, n), lambda i, c_: (i, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c_: (i, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c_: (i, c_, 0)),
            pl.BlockSpec((1, n, p), lambda i, c_: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), xdt.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, loga, b, c)
    return y, sfin
