"""Pure-jnp oracle for the Mamba2/SSD selective state-space scan.

Per head with state S in R^{N x P} (N = d_state, P = head_dim), scalar
decay a_t = exp(loga_t) (Mamba2's scalar-identity A):

    S_t = a_t * S_{t-1} + B_t ⊗ xdt_t          (B_t in R^N, xdt_t in R^P)
    y_t = C_t^T S_t                             (C_t in R^N)

``xdt`` is x with the Delta step already folded in (x * dt); ``loga`` is
dt * A (negative). The sequential lax.scan here is the ground truth the
chunked Pallas kernel must reproduce.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_scan_reference(
    xdt: jax.Array,    # (BH, S, P)
    loga: jax.Array,   # (BH, S)
    b: jax.Array,      # (BH, S, N)
    c: jax.Array,      # (BH, S, N)
    s0: jax.Array | None = None,   # (BH, N, P) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (BH,S,P), final_state (BH,N,P))."""
    bh, s, p = xdt.shape
    n = b.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((bh, n, p), jnp.float32)

    def one(xdt_i, loga_i, b_i, c_i, s0_i):
        def step(state, inputs):
            x_t, la_t, b_t, c_t = inputs
            state = jnp.exp(la_t) * state + jnp.outer(b_t, x_t)
            y_t = c_t @ state
            return state, y_t

        state, ys = jax.lax.scan(step, s0_i, (xdt_i, loga_i, b_i, c_i))
        return ys, state

    y, s_fin = jax.vmap(one)(
        xdt.astype(jnp.float32), loga.astype(jnp.float32),
        b.astype(jnp.float32), c.astype(jnp.float32), s0.astype(jnp.float32),
    )
    return y.astype(xdt.dtype), s_fin


def ssd_chunked_ref(
    xdt: jax.Array,    # (BH, S, P)
    loga: jax.Array,   # (BH, S)
    b: jax.Array,      # (BH, S, N)
    c: jax.Array,      # (BH, S, N)
    chunk: int = 128,
    s0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD in pure jnp — the same matmul decomposition as the Pallas
    kernel, expressed as a lax.scan over chunks (XLA path for CPU dry-runs
    and the compile-time-friendly default for long sequences)."""
    bh, s, p = xdt.shape
    n = b.shape[-1]
    # chunk scan slices along S: pin inputs to batch/head-sharded layout so
    # every chunk step is device-local (see repro.dist.context)
    from repro.dist.context import constrain_scan_inputs
    xdt = constrain_scan_inputs(xdt)
    loga = constrain_scan_inputs(loga)
    b = constrain_scan_inputs(b)
    c = constrain_scan_inputs(c)
    q = min(chunk, s)
    rem = (-s) % q
    if rem:
        xdt = jnp.pad(xdt, ((0, 0), (0, rem), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, rem)))
        b = jnp.pad(b, ((0, 0), (0, rem), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, rem), (0, 0)))
    nc = xdt.shape[1] // q
    xdt_c = xdt.reshape(bh, nc, q, p).swapaxes(0, 1).astype(jnp.float32)
    loga_c = loga.reshape(bh, nc, q).swapaxes(0, 1).astype(jnp.float32)
    b_c = b.reshape(bh, nc, q, n).swapaxes(0, 1).astype(jnp.float32)
    c_c = c.reshape(bh, nc, q, n).swapaxes(0, 1).astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((bh, n, p), jnp.float32)

    li = jnp.arange(q)[:, None]
    lj = jnp.arange(q)[None, :]

    def step(state, inputs):
        x_i, la_i, b_i, c_i = inputs
        cum = jnp.cumsum(la_i, axis=-1)                       # (BH, Q)
        total = cum[:, -1]
        scores = jnp.einsum("zqn,zkn->zqk", c_i, b_i)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])
        l_mask = jnp.where(li >= lj, decay, 0.0)
        y = jnp.einsum("zqk,zkp->zqp", scores * l_mask, x_i)
        y = y + jnp.einsum("zqn,znp->zqp", c_i * jnp.exp(cum)[..., None], state)
        b_scaled = b_i * jnp.exp(total[:, None, None] - cum[..., None])
        state = jnp.exp(total)[:, None, None] * state + jnp.einsum(
            "zqn,zqp->znp", b_scaled, x_i
        )
        return state, y

    s_fin, ys = jax.lax.scan(step, s0, (xdt_c, loga_c, b_c, c_c))
    y = ys.swapaxes(0, 1).reshape(bh, nc * q, p)[:, :s]
    return y.astype(xdt.dtype), s_fin


def ssd_decode_step(
    state: jax.Array,  # (BH, N, P)
    xdt: jax.Array,    # (BH, P)
    loga: jax.Array,   # (BH,)
    b: jax.Array,      # (BH, N)
    c: jax.Array,      # (BH, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent token step (decode path — O(1) per token)."""
    state = jnp.exp(loga)[:, None, None] * state + jnp.einsum(
        "bn,bp->bnp", b, xdt
    )
    y = jnp.einsum("bn,bnp->bp", c, state)
    return y, state
