"""Jit'd wrapper for the chunked SSD scan kernel (pads S to chunk multiple)."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_chunked_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_scan(
    xdt: jax.Array,    # (BH, S, P)
    loga: jax.Array,   # (BH, S)
    b: jax.Array,      # (BH, S, N)
    c: jax.Array,      # (BH, S, N)
    chunk: int = 128,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (BH,S,P), final_state (BH,N,P))."""
    if interpret is None:
        interpret = not _on_tpu()
    bh, s, p = xdt.shape
    q = min(chunk, s)
    rem = (-s) % q
    if rem:
        # Padded steps use loga=0 (a=1, no decay) and xdt=0/B=0 so they do
        # not perturb the carried state; padded y rows are sliced off.
        xdt = jnp.pad(xdt, ((0, 0), (0, rem), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, rem)))
        b = jnp.pad(b, ((0, 0), (0, rem), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, rem), (0, 0)))
    y, sfin = ssd_chunked_pallas(xdt, loga, b, c, chunk=q, interpret=interpret)
    return y[:, :s], sfin
