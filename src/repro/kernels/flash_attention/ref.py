"""Pure-jnp oracle for tiled flash attention (GQA, optional causal)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Skv, D)
    v: jax.Array,          # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Softmax attention with KV-head grouping (repeat) — the oracle.

    ``q_offset`` positions the query block inside the kv sequence for
    causal masking (decode: q_offset = cache_len - Sq).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        skv = k.shape[2]
        q_pos = jnp.arange(sq) + q_offset
        kv_pos = jnp.arange(skv)
        mask = q_pos[:, None] >= kv_pos[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Skv, D)
    v: jax.Array,          # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    q_offset: int = 0,
    block_q: int = 512,
) -> jax.Array:
    """Flash-style attention in pure jnp: lax.map over query chunks so the
    (Sq x Skv) score matrix is never materialized — peak transient is
    (B, H, block_q, Skv). This is the XLA path long-sequence prefill uses on
    the CPU dry-run (identical FLOPs to the Pallas kernel)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    bq = min(block_q, sq)
    rem = (-sq) % bq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, rem), (0, 0)))
    nq = qp.shape[2] // bq
    qp = qp.reshape(b, hkv, group, nq, bq, d)
    kv_pos = jnp.arange(skv)

    def one_chunk(iq):
        qc = jax.lax.dynamic_index_in_dim(qp, iq, axis=3, keepdims=False)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qc.astype(jnp.float32), k.astype(jnp.float32)
        ) * sm_scale
        if causal:
            q_pos = iq * bq + jnp.arange(bq) + q_offset
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
        # probabilities in the model dtype: scores/max/sum stay f32 for
        # stability; storing/backpropping p at bf16 halves the dominant
        # attention HBM traffic (§Perf qwen3 iteration; TPU-standard)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(q.dtype))

    out = jax.lax.map(one_chunk, jnp.arange(nq))          # (nq,B,Hkv,g,bq,D)
    out = jnp.moveaxis(out, 0, 3)                          # (B,Hkv,g,nq,bq,D)
    out = out.reshape(b, hq, nq * bq, d)[:, :, :sq]
    return out.astype(q.dtype)
