"""Pallas TPU kernel: flash attention (online softmax), GQA-native.

Grid = (B * Hq, Sq/bq, Skv/bk) with the KV dimension innermost; running max
(m), normalizer (l) and the f32 output accumulator live in VMEM scratch and
persist across the KV grid steps (canonical Pallas revisiting pattern).
GQA costs nothing: the K/V BlockSpec index_map folds the query head index
onto its KV head (h_kv = h_q // group) — no repeat/copy materialized.

Causal blocks strictly above the diagonal are skipped with pl.when (no MXU
work, no VMEM traffic for the P*V matmul); the diagonal block applies an
iota mask. Tiles default to (bq, bk) = (256, 256): MXU-aligned (multiples
of 128) and ~2 MiB VMEM at D=128/f32 accumulators.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,        # (1, bq, D)
    k_ref,        # (1, bk, D)
    v_ref,        # (1, bk, D)
    o_ref,        # (1, bq, D)
    m_scr,        # (bq,) f32
    l_scr,        # (bq,) f32
    acc_scr,      # (bq, D) f32
    *,
    causal: bool,
    sm_scale: float,
    bq: int,
    bk: int,
    nk: int,
    q_offset: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Global positions of this tile.
    q_lo = iq * bq + q_offset          # first query's kv-space position
    k_lo = ik * bk

    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if causal:
            q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # Skip tiles strictly above the diagonal (no query attends there).
        pl.when(k_lo <= q_lo + bq - 1)(compute)
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Skv, D)
    v: jax.Array,          # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    q_offset: int = 0,
    interpret: bool = True,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "caller pads to tile multiples"
    nq, nk = sq // bq, skv // bk

    # Flatten (B, Hq): grid dim 0; K/V index_maps fold onto the KV head.
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_head(bh):
        # bh = batch * Hq + h  ->  batch * Hkv + h // group
        return (bh // hq) * hkv + (bh % hq) // group

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=sm_scale,
        bq=bq, bk=bk, nk=nk, q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
