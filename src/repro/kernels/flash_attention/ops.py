"""Jit'd wrapper for the flash-attention Pallas kernel.

Pads sequence lengths to tile multiples (padded KV columns are masked out by
making them "future" positions in causal mode, or by an explicit length
mask), picks interpret mode off-TPU, and exposes one call used by all
attention layers in the model zoo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k",
                     "q_offset", "interpret"),
)
def flash_attention(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Skv, D)
    v: jax.Array,          # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    sq, skv = q.shape[2], k.shape[2]
    bq = min(block_q, max(sq, 1))
    bk = min(block_k, max(skv, 1))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    if not causal and kp.shape[2] != skv:
        # Non-causal path: padded KV columns must not receive weight. Add a
        # -inf bias by appending masked K rows via a sentinel: we instead
        # fall back to masking with causal=False handled through q_offset
        # trickery being unavailable — push padded keys far "in the future"
        # and enable causal with a huge offset is wrong; easiest correct
        # route: mask inside by extending to causal=False only when
        # divisible. Callers use tile-multiple shapes for non-causal.
        raise ValueError("non-causal flash requires Skv % block_k == 0")
    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, sm_scale=sm_scale,
        block_q=bq, block_k=bk, q_offset=q_offset,
        interpret=interpret,
    )
    return out[:, :, :sq, :]
