from repro.kernels.sgns import ops, ref
from repro.kernels.sgns.ops import sgns_lifetime_batch

__all__ = ["ops", "ref", "sgns_lifetime_batch"]
