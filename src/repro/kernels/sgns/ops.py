"""Jit'd wrapper around the fused SGNS Pallas kernel.

Handles the time-axis padding the kernel wants (T -> T + 2w so windows are
pure dynamic_slices) and exposes the same call signature as the pure-jnp
reference (``ref.sgns_lifetime_batch_ref``).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sgns.kernel import on_tpu, sgns_lifetime_pallas


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def sgns_lifetime_batch(
    ctx: jax.Array,    # (G, W, T, d) f32
    out: jax.Array,    # (G, W, T, d) f32
    neg: jax.Array,    # (G, T, K, d) f32
    valid: jax.Array,  # (G, W, T) bool
    lr: jax.Array,     # () f32
    window: int,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused lifetime update for G groups. Returns (ctx, out, neg, loss(G,))."""
    if interpret is None:
        interpret = not on_tpu()
    g_cnt, w_cnt, t_len, dim = ctx.shape
    w = window
    pad = ((0, 0), (0, 0), (w, w), (0, 0))
    ctx_p = jnp.pad(ctx, pad)
    out_p = jnp.pad(out, pad)
    valid_p = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, 0), (w, w)))
    lr_arr = jnp.full((1, 1), lr, jnp.float32)
    ctx_p, out_p, neg_o, loss = sgns_lifetime_pallas(
        ctx_p, out_p, neg, valid_p, lr_arr,
        window=w, t_len=t_len, interpret=interpret,
    )
    return (
        ctx_p[:, :, w : w + t_len, :],
        out_p[:, :, w : w + t_len, :],
        neg_o,
        loss,
    )
