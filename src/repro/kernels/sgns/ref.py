"""Pure-jnp oracle for the fused SGNS lifetime kernel.

Semantics (must match kernel.py bit-for-bit up to float associativity):
for each position p of a lifetime of W walks:

    contexts  C = ctx_buf[:, p-w..p+w (excl p), :]      (W*2w, d)  phi_in rows
    targets/negs T = [out_buf[:, p, :] ; neg_buf[p]]    (W+K, d)   phi_out rows
    logits = clip(C @ T^T, +-6)  (word2vec MAX_EXP)
    g      = (Y - sigmoid(logits)) * masks * lr
    C += g @ T ;  T += g^T @ C_old

All updates are applied to the VMEM-resident local buffers; the caller
writes deltas back to the global matrices (paper Improvement-I).
This file is the single source of truth the Pallas kernel is tested against
(shape/dtype sweeps in tests/test_kernels_sgns.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

MAX_EXP = 6.0


def sgns_lifetime_ref(
    ctx_buf: jax.Array,   # (W, T, d) f32
    out_buf: jax.Array,   # (W, T, d) f32
    neg_buf: jax.Array,   # (T, K, d) f32
    valid: jax.Array,     # (W, T) bool
    lr: jax.Array,        # () f32
    window: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Reference lifetime update. Returns updated buffers + summed loss."""
    w_cnt, t_len, dim = ctx_buf.shape
    k = neg_buf.shape[1]
    offs = jnp.concatenate(
        [jnp.arange(-window, 0), jnp.arange(1, window + 1)]
    ).astype(jnp.int32)
    n_ctx = offs.shape[0]

    def step(carry, p):
        ctx_buf, out_buf, neg_buf, loss = carry
        idx = p + offs
        in_bounds = (idx >= 0) & (idx < t_len)
        idx_c = jnp.clip(idx, 0, t_len - 1)

        c_rows = ctx_buf[:, idx_c, :]                       # (W, 2w, d)
        c_valid = in_bounds[None, :] & jnp.take_along_axis(
            valid, jnp.broadcast_to(idx_c[None, :], (w_cnt, n_ctx)), axis=1
        )
        tgt = out_buf[:, p, :]
        tgt_valid = valid[:, p]
        negs = neg_buf[p]

        t_rows = jnp.concatenate([tgt, negs], axis=0)       # (W+K, d)
        c_flat = c_rows.reshape(w_cnt * n_ctx, dim)
        logits = jnp.clip(c_flat @ t_rows.T, -MAX_EXP, MAX_EXP)
        walk_of_row = jnp.repeat(jnp.arange(w_cnt), n_ctx)
        y = jax.nn.one_hot(walk_of_row, w_cnt + k, dtype=jnp.float32)
        sig = jax.nn.sigmoid(logits)
        row_mask = (c_valid.reshape(-1) & tgt_valid[walk_of_row]).astype(jnp.float32)
        col_mask = jnp.concatenate(
            [tgt_valid.astype(jnp.float32), jnp.ones((k,), jnp.float32)]
        )
        g = (y - sig) * row_mask[:, None] * col_mask[None, :]

        eps = 1e-7
        pair_loss = -(y * jnp.log(sig + eps) + (1 - y) * jnp.log(1 - sig + eps))
        loss = loss + jnp.sum(pair_loss * row_mask[:, None] * col_mask[None, :])

        d_c = (g @ t_rows) * lr
        d_t = (g.T @ c_flat) * lr

        ctx_buf = ctx_buf.at[:, idx_c, :].add(d_c.reshape(w_cnt, n_ctx, dim))
        out_buf = out_buf.at[:, p, :].add(d_t[:w_cnt])
        neg_buf = neg_buf.at[p].add(d_t[w_cnt:])
        return (ctx_buf, out_buf, neg_buf, loss), None

    (ctx_buf, out_buf, neg_buf, loss), _ = jax.lax.scan(
        step, (ctx_buf, out_buf, neg_buf, jnp.float32(0.0)),
        jnp.arange(t_len, dtype=jnp.int32),
    )
    return ctx_buf, out_buf, neg_buf, loss


def sgns_lifetime_batch_ref(ctx, out, neg, valid, lr, window):
    """vmapped-over-groups reference: shapes (G, W, T, d) etc."""
    return jax.vmap(
        lambda c, o, n, v: sgns_lifetime_ref(c, o, n, v, lr, window)
    )(ctx, out, neg, valid)
