"""Pallas TPU kernel: fused SGNS lifetime update (paper §4.2-I/II on MXU).

One grid program processes one *lifetime* (a group of W = multi_windows
walks). The three local buffers — context rows (phi_in), target rows and the
negative-sample rows (phi_out) — are VMEM-resident for the whole lifetime:
loaded once, updated in-place across all T positions, stored once. This is
the TPU mapping of the paper's "local buffers reduce cache-line
ping-ponging": HBM traffic is one read + one write per row per lifetime
regardless of how many windows touch the row.

Per position the fused pipeline runs on values in VMEM/VREGs:
    logits (W*(2w+1) x (W+K) MXU matmul) -> clamp(+-6) -> sigmoid ->
    gradient -> SGD update of both buffers.

Window addressing uses dynamic_slice on a (T + 2w)-padded time axis (no
gathers/scatters — Mosaic-friendly); the window's center row is masked out
instead of excluded, which is mathematically identical.

VMEM budget per program (W=2, T=100+2w, d=128, K=5, f32):
  ctx/out: 2*120*128*4 = 123 KiB each; neg: 100*5*128*4 = 256 KiB -> ~0.5 MiB.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_EXP = 6.0


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _sgns_kernel(
    ctx_ref,    # (1, W, Tp, d)  phi_in rows, time-padded by w on both sides
    out_ref,    # (1, W, Tp, d)  phi_out rows (same padding)
    neg_ref,    # (1, T, K, d)
    valid_ref,  # (1, W, Tp) int32 (0/1)
    lr_ref,     # (1, 1) f32
    ctx_o_ref, out_o_ref, neg_o_ref, loss_ref,
    *, window: int, t_len: int,
):
    w = window
    ctx = ctx_ref[0]
    out = out_ref[0]
    neg = neg_ref[0]
    valid = valid_ref[0]
    lr = lr_ref[0, 0]

    w_cnt, t_pad, dim = ctx.shape
    k = neg.shape[1]
    span = 2 * w + 1
    n_rows = w_cnt * span

    # Row bookkeeping (static): which walk each context row belongs to, and
    # whether it is the (masked-out) center of its window.
    walk_of_row = jnp.repeat(jnp.arange(w_cnt, dtype=jnp.int32), span)
    is_center = jnp.tile(
        (jnp.arange(span, dtype=jnp.int32) == w), (w_cnt,)
    )
    y = jax.nn.one_hot(walk_of_row, w_cnt + k, dtype=jnp.float32)

    def body(p, carry):
        ctx, out, neg, loss = carry
        # padded-window slice: rows p..p+2w of the padded time axis
        c_win = jax.lax.dynamic_slice(ctx, (0, p, 0), (w_cnt, span, dim))
        v_win = jax.lax.dynamic_slice(valid, (0, p), (w_cnt, span))
        tgt = jax.lax.dynamic_slice(out, (0, p + w, 0), (w_cnt, 1, dim))[:, 0]
        tgt_valid = jax.lax.dynamic_slice(valid, (0, p + w), (w_cnt, 1))[:, 0]
        negs = jax.lax.dynamic_slice(neg, (p, 0, 0), (1, k, dim))[0]

        t_rows = jnp.concatenate([tgt, negs], axis=0)           # (W+K, d)
        c_flat = c_win.reshape(n_rows, dim)
        logits = jnp.clip(
            jnp.dot(c_flat, t_rows.T, preferred_element_type=jnp.float32),
            -MAX_EXP, MAX_EXP,
        )
        sig = jax.nn.sigmoid(logits)
        row_mask = (
            (v_win.reshape(-1) != 0)
            & ~is_center
            & (tgt_valid[walk_of_row] != 0)
        ).astype(jnp.float32)
        col_mask = jnp.concatenate(
            [(tgt_valid != 0).astype(jnp.float32), jnp.ones((k,), jnp.float32)]
        )
        g = (y - sig) * row_mask[:, None] * col_mask[None, :]

        eps = 1e-7
        pair_loss = -(y * jnp.log(sig + eps) + (1 - y) * jnp.log(1 - sig + eps))
        loss = loss + jnp.sum(pair_loss * row_mask[:, None] * col_mask[None, :])

        d_c = jnp.dot(g, t_rows, preferred_element_type=jnp.float32) * lr
        d_t = jnp.dot(g.T, c_flat, preferred_element_type=jnp.float32) * lr

        ctx = jax.lax.dynamic_update_slice(
            ctx, c_win + d_c.reshape(w_cnt, span, dim), (0, p, 0)
        )
        out = jax.lax.dynamic_update_slice(
            out, (tgt + d_t[:w_cnt])[:, None, :], (0, p + w, 0)
        )
        neg = jax.lax.dynamic_update_slice(
            neg, (negs + d_t[w_cnt:])[None], (p, 0, 0)
        )
        return ctx, out, neg, loss

    ctx, out, neg, loss = jax.lax.fori_loop(
        0, t_len, body, (ctx, out, neg, jnp.float32(0.0))
    )
    ctx_o_ref[0] = ctx
    out_o_ref[0] = out
    neg_o_ref[0] = neg
    loss_ref[0] = loss


def sgns_lifetime_pallas(
    ctx_pad: jax.Array,   # (G, W, T+2w, d)
    out_pad: jax.Array,   # (G, W, T+2w, d)
    neg: jax.Array,       # (G, T, K, d)
    valid_pad: jax.Array, # (G, W, T+2w) int32
    lr: jax.Array,        # (1, 1) f32
    *, window: int, t_len: int, interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    # Auto-detect like ops.py: compiled on TPU, interpreter elsewhere.
    # (A literal `interpret=True` default silently ran the interpreter on
    # TPU for direct callers.)
    if interpret is None:
        interpret = not on_tpu()
    g_cnt, w_cnt, t_pad, dim = ctx_pad.shape
    k = neg.shape[2]
    grid = (g_cnt,)
    kernel = functools.partial(_sgns_kernel, window=window, t_len=t_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w_cnt, t_pad, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, w_cnt, t_pad, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, t_len, k, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, w_cnt, t_pad), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, 1), lambda g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w_cnt, t_pad, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, w_cnt, t_pad, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, t_len, k, dim), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g_cnt, w_cnt, t_pad, dim), jnp.float32),
            jax.ShapeDtypeStruct((g_cnt, w_cnt, t_pad, dim), jnp.float32),
            jax.ShapeDtypeStruct((g_cnt, t_len, k, dim), jnp.float32),
            jax.ShapeDtypeStruct((g_cnt,), jnp.float32),
        ],
        interpret=interpret,
    )(ctx_pad, out_pad, neg, valid_pad, lr)
