"""Process-wide telemetry switchboard.

One mutable configuration shared by the registry, the tracer and the
flight recorder, so a single ``configure(enabled=False)`` (or
``REPRO_TELEMETRY=0`` in the environment) turns the WHOLE substrate into
cheap no-ops. The zero-numerical-footprint contract of the subsystem
(DESIGN.md §13) is enforced structurally — telemetry only ever records
host-side scalars that the runtime already computed — but the off switch
additionally buys back the (small) host bookkeeping cost, and the
``obs_overhead`` benchmark measures exactly that on/off delta.

Sinks:

* ``jsonl_path`` — every closed span / event is appended as one JSON
  line (the live event stream; ``None`` disables it);
* ``flight_dir`` — directory for flight-recorder crash dumps (``None``
  keeps the ring in memory only; set ``REPRO_FLIGHT_DIR`` or call
  ``configure(flight_dir=...)`` to get on-disk postmortems).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional

_LOCK = threading.Lock()

_STATE: Dict[str, Any] = {
    "enabled": os.environ.get("REPRO_TELEMETRY", "1").strip() not in
    ("0", "false", "off", ""),
    "jsonl_path": os.environ.get("REPRO_TELEMETRY_JSONL") or None,
    "flight_dir": os.environ.get("REPRO_FLIGHT_DIR") or None,
}


def enabled() -> bool:
    return _STATE["enabled"]


def flight_dir() -> Optional[str]:
    return _STATE["flight_dir"]


def jsonl_path() -> Optional[str]:
    return _STATE["jsonl_path"]


def configure(*, enabled: Optional[bool] = None,
              jsonl_path: Optional[str] = None,
              flight_dir: Optional[str] = None,
              clear_sinks: bool = False) -> Dict[str, Any]:
    """Reconfigure the process-wide telemetry state; returns the previous
    state (pass its fields back to restore — see ``obs.override``)."""
    with _LOCK:
        prev = dict(_STATE)
        if clear_sinks:
            _STATE["jsonl_path"] = None
            _STATE["flight_dir"] = None
        if enabled is not None:
            _STATE["enabled"] = bool(enabled)
        if jsonl_path is not None:
            _STATE["jsonl_path"] = jsonl_path
        if flight_dir is not None:
            _STATE["flight_dir"] = flight_dir
    return prev


def emit_jsonl(obj: Dict[str, Any]) -> None:
    """Append one record to the JSONL event stream (no-op when the sink is
    unset or telemetry is off). Failures to write never propagate into the
    runtime — telemetry must not be able to crash training."""
    path = _STATE["jsonl_path"]
    if not path or not _STATE["enabled"]:
        return
    try:
        line = json.dumps(obj, default=str)
        with _LOCK:
            with open(path, "a") as f:
                f.write(line + "\n")
    except OSError:
        pass
