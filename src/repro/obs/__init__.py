"""Unified runtime telemetry (DESIGN.md §13).

Four pieces behind one switch:

* ``metrics``  — process-wide registry of counters / gauges / bounded-
  window histograms (``inc`` / ``set_gauge`` / ``observe``);
* ``trace``    — nested ``trace_span`` phase timing that shares fields
  with ``common.logging.log_context``;
* ``recorder`` — bounded ring of recent spans/events, dumped to disk as
  a postmortem when a fault / divergence / retry path fails;
* ``export``   — Prometheus text snapshot + per-run RUN_TELEMETRY.json.

The whole substrate is host-side bookkeeping over scalars the runtime
already pulled: telemetry on vs off is bit-identical (property-tested),
and ``REPRO_TELEMETRY=0`` / ``configure(enabled=False)`` turns every
entry point into a flag check.
"""

from __future__ import annotations

import contextlib
from typing import Iterator

from repro.obs.config import configure, emit_jsonl, enabled  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    inc,
    observe,
    prometheus_snapshot,
    set_gauge,
    set_gauges,
)
from repro.obs.recorder import (  # noqa: F401
    dump_flight_record,
    load_flight_record,
    recent,
)
from repro.obs.trace import (  # noqa: F401
    ambient_fields,
    current_span,
    span_event,
    span_stack,
    trace_span,
)
from repro.obs.export import (  # noqa: F401
    load_run_telemetry,
    run_telemetry,
    write_run_telemetry,
)

from repro.obs import config as _config
from repro.obs import recorder as _recorder


@contextlib.contextmanager
def override(**kwargs) -> Iterator[None]:
    """Temporarily reconfigure telemetry (tests / benches):

        with obs.override(enabled=False):
            ...  # telemetry fully off inside the block
    """
    prev = configure(**kwargs)
    try:
        yield
    finally:
        configure(enabled=prev["enabled"], clear_sinks=True)
        if prev["jsonl_path"]:
            configure(jsonl_path=prev["jsonl_path"])
        if prev["flight_dir"]:
            configure(flight_dir=prev["flight_dir"])


def reset() -> None:
    """Clear the registry and the flight-recorder ring (test isolation)."""
    REGISTRY.reset()
    _recorder.clear()
