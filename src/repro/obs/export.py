"""Run-telemetry export: the per-run ``RUN_TELEMETRY.json`` summary.

One JSON document per run — the metrics snapshot plus run identity —
written at the end of a streaming run or a bench, consumed by
``benchmarks/run.py`` (the ``obs_overhead`` row embeds one) and uploaded
by the CI ``bench-artifacts`` job. The schema is deliberately flat and
versioned so CI-side consumers can assert on it without importing repro.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

from repro.obs import metrics as _metrics
from repro.obs.metrics import prometheus_snapshot  # re-export  # noqa: F401

SCHEMA = "repro.run_telemetry.v1"

#: Required top-level keys — the round-trip test and CI assert on these.
REQUIRED_KEYS = ("schema", "run", "counters", "gauges", "histograms")


def run_telemetry(run: Optional[Dict[str, Any]] = None,
                  registry: Optional[_metrics.MetricsRegistry] = None
                  ) -> Dict[str, Any]:
    """Build the RUN_TELEMETRY document from a registry snapshot."""
    snap = (registry or _metrics.REGISTRY).snapshot()
    return {
        "schema": SCHEMA,
        "run": dict(run or {}),
        "counters": snap["counters"],
        "gauges": snap["gauges"],
        "histograms": snap["histograms"],
    }


def write_run_telemetry(path: str,
                        run: Optional[Dict[str, Any]] = None,
                        registry: Optional[_metrics.MetricsRegistry] = None
                        ) -> Dict[str, Any]:
    doc = run_telemetry(run, registry)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
    return doc


def load_run_telemetry(path: str) -> Dict[str, Any]:
    """Load + validate a RUN_TELEMETRY.json; raises ValueError on a
    document that doesn't match the schema."""
    with open(path) as f:
        doc = json.load(f)
    missing = [k for k in REQUIRED_KEYS if k not in doc]
    if missing:
        raise ValueError(f"RUN_TELEMETRY missing keys: {missing}")
    if doc["schema"] != SCHEMA:
        raise ValueError(f"unknown RUN_TELEMETRY schema: {doc['schema']!r}")
    for k in ("counters", "gauges", "histograms"):
        if not isinstance(doc[k], dict):
            raise ValueError(f"RUN_TELEMETRY[{k!r}] must be an object")
    return doc
