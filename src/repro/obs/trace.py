"""Span tracer: nested, contextvar-scoped phase timing.

``with trace_span("walk.round", round=r):`` opens a span; on close its
wall time lands in the ``span.walk.round.s`` histogram, the closed-span
record is appended to the flight recorder ring and the JSONL event
stream, and — because the span body runs inside
``common.logging.log_context(**fields)`` — every log line emitted inside
the span carries the span's fields. Spans nest: a child records its
parent's name, and ``current_span()`` exposes the innermost frame so
point events (``span_event``) can attach to it.

Thread isolation comes free from the contextvar: a prefetch thread
starts with an empty span stack and cannot corrupt the driver thread's
nesting (property-tested in tests/test_obs.py).

The tracer is host-side only and time-based only — it never touches
device values, so it cannot perturb compiled computations. With
telemetry disabled ``trace_span`` short-circuits to a bare ``yield``
(one flag check, no clock reads, no contextvar writes).
"""

from __future__ import annotations

import contextlib
import contextvars
import time
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.common.logging import current_context_fields, get_logger, \
    log_context
from repro.obs import config as _config
from repro.obs import metrics as _metrics

_log = get_logger("repro.obs")

_SPAN_STACK: contextvars.ContextVar[Tuple[Dict[str, Any], ...]] = (
    contextvars.ContextVar("repro_span_stack", default=()))

#: Monotonically-increasing span id (uniqueness only; no ordering claims
#: across threads).
_NEXT_ID = [0]


def current_span() -> Optional[Dict[str, Any]]:
    """The innermost open span frame in this thread/context, or None."""
    stack = _SPAN_STACK.get()
    return stack[-1] if stack else None


def span_stack() -> Tuple[Dict[str, Any], ...]:
    """The full open-span stack (outermost first)."""
    return _SPAN_STACK.get()


def ambient_fields() -> Dict[str, Any]:
    """Merged fields of every open span, outer→inner (inner wins).

    This is what the flight recorder stamps onto point events so a
    fault fired deep inside ``refresh.splice`` still carries the round
    and graph_version of the enclosing spans.
    """
    fields: Dict[str, Any] = {}
    for frame in _SPAN_STACK.get():
        fields.update(frame["fields"])
    return fields


@contextlib.contextmanager
def trace_span(name: str, **fields: Any) -> Iterator[Optional[Dict[str, Any]]]:
    """Open a named span around the body.

    On exit (normal or exceptional) the closed-span record goes to the
    flight recorder and the JSONL stream, and the duration is recorded
    in the ``span.<name>.s`` histogram. An exception marks the record
    ``ok=False`` with the error type, then propagates.
    """
    if not _config.enabled():
        yield None
        return
    _NEXT_ID[0] += 1
    stack = _SPAN_STACK.get()
    frame: Dict[str, Any] = {
        "kind": "span",
        "id": _NEXT_ID[0],
        "name": name,
        "parent": stack[-1]["name"] if stack else None,
        "fields": dict(fields),
        "t_start": time.time(),
        "depth": len(stack),
    }
    token = _SPAN_STACK.set(stack + (frame,))
    t0 = time.perf_counter()
    try:
        with log_context(**fields):
            yield frame
        frame["ok"] = True
    except BaseException as e:
        frame["ok"] = False
        frame["error"] = type(e).__name__
        raise
    finally:
        frame["wall_s"] = time.perf_counter() - t0
        _SPAN_STACK.reset(token)
        _metrics.observe(f"span.{name}.s", frame["wall_s"])
        from repro.obs import recorder as _recorder
        _recorder.record(frame)
        # Spans share the structured-log formatter: the close line runs
        # inside the span's own log_context so it carries the fields.
        if _log.isEnabledFor(10):  # logging.DEBUG
            with log_context(**fields):
                _log.debug("span %s wall=%.6fs ok=%s", name,
                           frame["wall_s"], frame.get("ok"))


def span_event(name: str, **fields: Any) -> None:
    """Record a point event (no duration) attached to the current span.

    Events land in the flight recorder and JSONL stream stamped with the
    merged fields of every enclosing span AND the ambient ``log_context``
    frames, so ``span_event("heal", reason=...)`` inside ``walk.round``
    carries the round for free — and a ``log_context(shard=...)`` block
    (no span) still stamps the shard.
    """
    if not _config.enabled():
        return
    record = {
        "kind": "event",
        "name": name,
        "t": time.time(),
        "fields": {**current_context_fields(), **ambient_fields(),
                   **fields},
        "span": (current_span() or {}).get("name"),
    }
    from repro.obs import recorder as _recorder
    _recorder.record(record)
