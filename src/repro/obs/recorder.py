"""Flight recorder: a bounded ring of recent spans/events, dumped to
disk when something dies.

Every closed span and point event is appended to a process-wide ring
(default 512 records — a few rounds of a streaming run). On failure —
``FaultInjector.fire``, a ``DivergenceError`` verdict, a WAL replay
retry, ``run_with_restarts`` catching a crash — the failing layer calls
``dump_flight_record(reason, ...)`` which writes the ring, the metrics
snapshot, and the failure context to
``<flight_dir>/flight_<reason>_<seq>.json``: a self-contained postmortem
(DESIGN.md §12 runbook) that replaces grepping raw logs.

Dumps only happen when a flight directory is configured
(``REPRO_FLIGHT_DIR`` or ``obs.configure(flight_dir=...)``) — the
fault-injection test suites exercise hundreds of deliberate crashes and
must not litter the working tree. The in-memory ring always runs (when
telemetry is enabled) so a late ``configure`` still captures history.
"""

from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs import config as _config
from repro.obs import metrics as _metrics

DEFAULT_RING = 512

_LOCK = threading.Lock()
_RING: collections.deque = collections.deque(maxlen=DEFAULT_RING)
_DUMP_SEQ = [0]


def record(rec: Dict[str, Any]) -> None:
    """Append one span/event record to the ring and the JSONL stream."""
    if not _config.enabled():
        return
    with _LOCK:
        _RING.append(rec)
    _config.emit_jsonl(rec)


def recent(n: Optional[int] = None) -> List[Dict[str, Any]]:
    """The most recent records, oldest first."""
    with _LOCK:
        items = list(_RING)
    return items if n is None else items[-n:]


def clear() -> None:
    with _LOCK:
        _RING.clear()


def resize(capacity: int) -> None:
    """Resize the ring, keeping the most recent records."""
    global _RING
    with _LOCK:
        _RING = collections.deque(_RING, maxlen=max(int(capacity), 1))


def dump_flight_record(reason: str, **context: Any) -> Optional[str]:
    """Write the ring + metrics snapshot + failure context to disk.

    Returns the dump path, or ``None`` when no flight directory is
    configured / telemetry is off. Never raises: a postmortem writer
    that can itself crash the process is worse than no postmortem.
    """
    if not _config.enabled():
        return None
    flight_dir = _config.flight_dir()
    if not flight_dir:
        return None
    try:
        from repro.common.logging import current_context_fields
        from repro.obs import trace as _trace
        open_spans = [
            {"name": f["name"], "fields": f["fields"], "depth": f["depth"]}
            for f in _trace.span_stack()]
        # log_context frames include every open span's fields (trace_span
        # pushes through the same contextvar) plus bare log_context blocks
        # like recover_shard_loss's shard=.
        ambient = {**current_context_fields(), **_trace.ambient_fields()}
        with _LOCK:
            _DUMP_SEQ[0] += 1
            seq = _DUMP_SEQ[0]
            ring = list(_RING)
        dump = {
            "schema": "repro.flight_record.v1",
            "reason": reason,
            "t": time.time(),
            "context": {**ambient, **{k: v for k, v in context.items()
                                      if v is not None}},
            "open_spans": open_spans,
            "ring": ring,
            "metrics": _metrics.REGISTRY.snapshot(),
        }
        os.makedirs(flight_dir, exist_ok=True)
        safe = "".join(c if (c.isalnum() or c in "_-") else "_"
                       for c in reason)
        path = os.path.join(flight_dir, f"flight_{safe}_{seq:04d}.json")
        with open(path, "w") as f:
            json.dump(dump, f, indent=1, default=str)
        return path
    except Exception:
        return None


def load_flight_record(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
