"""Process-wide metrics registry: counters, gauges, bounded-window
histograms.

Three metric kinds, one naming scheme (DESIGN.md §13: dotted
``layer.signal`` names, e.g. ``walk.supersteps``, ``ingest.latency_s``,
``span.ckpt.write.s``):

* ``Counter`` — monotonically-increasing totals (events, bytes, steps);
* ``Gauge``   — last-write-wins instantaneous values (pool sizes, EMAs);
* ``Histogram`` — a BOUNDED sliding-window reservoir of recent
  observations with lifetime count/sum/min/max. The window is the single
  percentile substrate in the repo: ``ingest.staleness()``'s p50/p90/p99
  latency keys are computed from one of these (the same
  ``np.percentile`` math the ingest driver used to hand-roll over a
  bespoke deque), and every ``trace_span`` duration lands in a
  ``span.<name>.s`` histogram.

The registry is deliberately host-only and lock-cheap: recording a value
is a dict lookup + a float add under the GIL. Nothing in this module may
ever touch a ``jax.Array`` — callers pull device scalars to host first
(and only where the runtime already did), which is what keeps the
telemetry-on/telemetry-off bit-identity property structural rather than
hoped-for.
"""

from __future__ import annotations

import collections
import math
import threading
from typing import Any, Dict, Iterable, Optional

import numpy as np

from repro.obs import config as _config

DEFAULT_WINDOW = 256


class Reservoir:
    """Bounded sliding window of the most recent observations.

    The percentile substrate shared by ``Histogram`` and the ingest
    driver's staleness accounting: keeps the last ``window`` values in a
    deque (O(1) add, O(window) percentile) — percentiles over recent
    behaviour, not over the whole run, which is what an SLO wants.
    """

    __slots__ = ("_values",)

    def __init__(self, window: int = DEFAULT_WINDOW):
        self._values: collections.deque = collections.deque(
            maxlen=max(int(window), 1))

    def add(self, value: float) -> None:
        self._values.append(float(value))

    def values(self) -> np.ndarray:
        return np.asarray(self._values, np.float64)

    def percentile(self, q: float) -> Optional[float]:
        if not self._values:
            return None
        return float(np.percentile(self.values(), q))

    def __len__(self) -> int:
        return len(self._values)

    @property
    def window(self) -> int:
        return self._values.maxlen


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Lifetime count/sum/min/max plus a bounded percentile window."""

    __slots__ = ("name", "count", "sum", "min", "max", "reservoir")

    def __init__(self, name: str = "", window: int = DEFAULT_WINDOW):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.reservoir = Reservoir(window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.reservoir.add(v)

    def values(self) -> np.ndarray:
        """Window contents (the percentile substrate)."""
        return self.reservoir.values()

    def percentile(self, q: float) -> Optional[float]:
        return self.reservoir.percentile(q)

    def summary(self) -> Dict[str, Any]:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "window": self.reservoir.window,
        }


class MetricsRegistry:
    """Name → metric map with get-or-create accessors.

    ``attach`` registers an externally-owned metric object (the ingest
    driver owns its latency histogram — its window must follow the
    driver's config, and a fresh driver must not inherit a dead one's
    samples — but the registry still exports it).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            m = self._counters.get(name)
            if m is None:
                m = self._counters[name] = Counter(name)
            return m

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            m = self._gauges.get(name)
            if m is None:
                m = self._gauges[name] = Gauge(name)
            return m

    def histogram(self, name: str, window: int = DEFAULT_WINDOW) -> Histogram:
        with self._lock:
            m = self._histograms.get(name)
            if m is None:
                m = self._histograms[name] = Histogram(name, window)
            return m

    def attach(self, name: str, hist: Histogram) -> Histogram:
        """Register (or replace) an externally-owned histogram."""
        with self._lock:
            hist.name = name
            self._histograms[name] = hist
            return hist

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    # -- export ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": {n: c.value for n, c in self._counters.items()},
                "gauges": {n: g.value for n, g in self._gauges.items()
                           if g.value is not None},
                "histograms": {n: h.summary()
                               for n, h in self._histograms.items()},
            }


#: The process-wide default registry (module-level helpers target it).
REGISTRY = MetricsRegistry()


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def prometheus_snapshot(registry: MetricsRegistry = REGISTRY,
                        prefix: str = "repro") -> str:
    """Prometheus text-exposition snapshot of the registry.

    Histograms export ``_count``/``_sum`` plus window quantiles as
    labelled gauges (a true cumulative-bucket export needs fixed bucket
    bounds the runtime cannot know a priori; the bounded-window quantiles
    are what operators actually alert on)."""
    snap = registry.snapshot()
    lines = []
    for name, value in sorted(snap["counters"].items()):
        m = f"{prefix}_{_sanitize(name)}"
        lines += [f"# TYPE {m} counter", f"{m} {value:g}"]
    for name, value in sorted(snap["gauges"].items()):
        m = f"{prefix}_{_sanitize(name)}"
        lines += [f"# TYPE {m} gauge", f"{m} {value:g}"]
    for name, summ in sorted(snap["histograms"].items()):
        m = f"{prefix}_{_sanitize(name)}"
        lines.append(f"# TYPE {m} summary")
        lines.append(f"{m}_count {summ.get('count', 0):g}")
        lines.append(f"{m}_sum {summ.get('sum', 0.0):g}")
        for q in (50, 90, 99):
            v = summ.get(f"p{q}")
            if v is not None:
                lines.append(f'{m}{{quantile="0.{q}"}} {v:g}')
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Gated module-level helpers — the instrumentation call surface.
# One flag check + one dict lookup when on; one flag check when off.
# ---------------------------------------------------------------------------


def inc(name: str, v: float = 1.0) -> None:
    if _config.enabled():
        REGISTRY.counter(name).inc(v)


def set_gauge(name: str, v: float) -> None:
    if _config.enabled():
        REGISTRY.gauge(name).set(v)


def observe(name: str, v: float, window: int = DEFAULT_WINDOW) -> None:
    if _config.enabled():
        REGISTRY.histogram(name, window).observe(v)


def set_gauges(prefix: str, values: Iterable[float]) -> None:
    """Per-shard convenience: ``set_gauges("walk.occ", [a, b])`` sets
    ``walk.occ.shard0`` and ``walk.occ.shard1``."""
    if _config.enabled():
        for i, v in enumerate(values):
            REGISTRY.gauge(f"{prefix}.shard{i}").set(v)
