"""Train an assigned LM architecture (reduced size) with the fault-tolerant
runtime: checkpoints, injected failure, automatic restart-and-resume.

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 60
"""

import argparse
import tempfile

from repro.configs import get_config
from repro.models.zoo import reduce_config
from repro.runtime.trainer import FailureInjector, Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=25,
                    help="inject a simulated node failure at this step")
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(steps=args.steps, ckpt_every=10,
                             ckpt_dir=ckpt_dir, batch=args.batch,
                             seq_len=args.seq)
        injector = FailureInjector(fail_at_steps=(args.fail_at,))
        trainer = Trainer(cfg, tcfg, injector=injector)
        out = trainer.run_with_restarts()

    m = out["metrics"]
    print(f"arch={args.arch} (reduced) steps={out['final_step']} "
          f"restarts={out['restarts']}")
    print(f"loss: {m[0]['loss']:.3f} -> {m[-1]['loss']:.3f}")
    print(f"straggler stats: {out['straggler_stats']}")


if __name__ == "__main__":
    main()
