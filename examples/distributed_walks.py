"""Distributed information-centric walks: MPGP vs hash partitioning.

Shows the two §3 claims live: constant-size InCoM messages, and the
cross-shard message reduction from proximity-aware partitioning.

  PYTHONPATH=src python examples/distributed_walks.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpgp import hash_partition, mpgp_partition
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, batch_stats, run_walk_batch
from repro.graph.generators import rmat_graph


def main() -> None:
    graph = rmat_graph(4096, 10, seed=1).with_edge_cm()
    machines = 4
    spec = WalkSpec(max_len=60, min_len=10, mu=0.995, info_mode="incom",
                    reg_start=16)
    sources = jnp.arange(1024, dtype=jnp.int32) % graph.num_nodes
    policy = make_policy("huge")

    for name, part in (
        ("MPGP (proximity-aware)", mpgp_partition(graph, machines,
                                                  gamma=2.0).assignment),
        ("hash (locality-blind)", hash_partition(graph, machines).assignment),
    ):
        st = run_walk_batch(graph, sources, jax.random.PRNGKey(0), policy,
                            spec, jnp.asarray(part))
        stats = batch_stats(st)
        per_msg = stats["msg_bytes"] / max(stats["msg_count"], 1)
        print(f"{name:24s} crossings={stats['msg_count']:6d}  "
              f"bytes/msg={per_msg:5.1f}  mean_len={stats['mean_len']:.1f}  "
              f"measured==analytic: "
              f"{stats['msg_bytes'] == stats['msg_bytes_analytic']}")

    print("\nInCoM message = 80 B constant (walker_id, steps, node, H, L, "
          "E(H), E(L), E(HL), E(H^2), E(L^2))")
    print("full-path message at L=60 would be 24 + 8*60 = 504 B")


if __name__ == "__main__":
    main()
