"""End-to-end dynamic-graph driver: embed, churn, refresh incrementally.

Loads (generates) a graph, trains DistGER embeddings with the streaming
pipeline, applies a batch of edge inserts/deletes through the delta-CSR
overlay, absorbs it with the incremental refresh (corpus-recovered
affected vertices -> subset re-walk -> in-place DSGL fine-tune), and
reports link-prediction AUC on the MUTATED graph before and after the
refresh — the stale-embedding gap the refresh closes at a fraction of a
full recompute.

  PYTHONPATH=src python examples/incremental_updates.py [--nodes 2048]
"""

import argparse

import numpy as np

from repro.core.api import EmbedConfig, embed_graph, refresh_embedding
from repro.graph.generators import churn_batch, rmat_graph


def _auc(graph, phi, rng, n_pairs=2000):
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    n = graph.num_nodes
    src = np.repeat(np.arange(n), np.diff(indptr))
    pos_idx = rng.choice(len(src), size=min(n_pairs, len(src)),
                         replace=False)
    pos = np.stack([src[pos_idx], indices[pos_idx]], 1)
    adj = {(int(a), int(b)) for a, b in zip(src, indices)}
    neg = []
    while len(neg) < len(pos):
        a, b = rng.integers(0, n, 2)
        if a != b and (int(a), int(b)) not in adj:
            neg.append((a, b))
    neg = np.array(neg)
    s_pos = (phi[pos[:, 0]] * phi[pos[:, 1]]).sum(-1)
    s_neg = (phi[neg[:, 0]] * phi[neg[:, 1]]).sum(-1)
    diff = s_pos[:, None] - s_neg[None, :]
    return float((diff > 0).mean() + 0.5 * (diff == 0).mean())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2048)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--churn", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    graph = rmat_graph(args.nodes, 10, seed=args.seed)
    cfg = EmbedConfig(dim=args.dim, epochs=1, lr=0.05, delta=1e-3,
                      max_len=40, min_len=10, window=6, negatives=4,
                      seed=args.seed)

    # --- embed the base graph (state handle => vertex-keyed walk RNG) -----
    phi0, _, state = embed_graph(graph, cfg, num_shards=args.shards,
                                 return_state=True)
    print(f"|V|={args.nodes}  |E|={graph.num_edges // 2}  "
          f"rounds={state.refresher.pipeline.controller.rounds}")

    # --- churn: localized inserts + deletes through the delta overlay -----
    batch = churn_batch(graph, args.churn, seed=args.seed + 1)
    print(f"churn: +{len(batch.insert)} / -{len(batch.delete)} edges "
          f"({100 * args.churn:.1f}% of |E|)")

    # --- incremental refresh ---------------------------------------------
    phi1, _, stats = refresh_embedding(state, batch)
    mutated = state.graph
    print(f"refresh: affected {stats.affected} vertices "
          f"({100 * stats.affected_frac:.1f}% of |V|), "
          f"{stats.rewalk_supersteps} re-walk supersteps, "
          f"{stats.extra_rounds} extra rounds, "
          f"{stats.fine_tune_steps} fine-tune steps, "
          f"{stats.wall_s:.1f}s")

    # --- quality on the MUTATED graph ------------------------------------
    auc_stale = _auc(mutated, phi0, np.random.default_rng(7))
    auc_fresh = _auc(mutated, phi1, np.random.default_rng(7))
    print(f"link-prediction AUC on mutated graph: "
          f"stale {auc_stale:.4f} -> refreshed {auc_fresh:.4f}")


if __name__ == "__main__":
    main()
