"""End-to-end driver (paper §6.4 protocol): link prediction.

Uniformly remove 50% of edges as positive test pairs, train DistGER
embeddings on the remaining graph (a few hundred DSGL steps), score pairs
by phi(u)·phi(v), report AUC against equal-sized non-edge negatives.

  PYTHONPATH=src python examples/link_prediction.py [--nodes 4096]
"""

import argparse

import numpy as np

from repro.core.api import EmbedConfig, embed_graph
from repro.graph.csr import build_csr
from repro.graph.generators import rmat_edges


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2048)
    ap.add_argument("--degree", type=int, default=10)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    edges = rmat_edges(args.nodes, args.nodes * args.degree // 2,
                       seed=args.seed)
    edges = edges[edges[:, 0] != edges[:, 1]]
    edges = np.unique(np.sort(edges, axis=1), axis=0)

    # --- 50/50 train/test edge split (paper protocol) ----------------------
    perm = rng.permutation(len(edges))
    half = len(edges) // 2
    test_pos = edges[perm[:half]]
    train_edges = edges[perm[half:]]
    graph = build_csr(train_edges, args.nodes, undirected=True)
    print(f"|V|={args.nodes}  train |E|={len(train_edges)}  "
          f"test pairs={len(test_pos)}")

    # --- train -------------------------------------------------------------
    cfg = EmbedConfig(dim=args.dim, epochs=1, lr=0.05, delta=1e-4,
                      max_len=40, min_len=10, window=8, negatives=5,
                      seed=args.seed)
    phi_in, phi_out = embed_graph(graph, cfg, num_shards=args.shards)

    # --- evaluate ------------------------------------------------------------
    adj = set(map(tuple, np.sort(edges, axis=1).tolist()))
    neg = []
    while len(neg) < len(test_pos):
        a, b = rng.integers(0, args.nodes, 2)
        if a != b and (min(a, b), max(a, b)) not in adj:
            neg.append((a, b))
    test_neg = np.asarray(neg)

    s_pos = (phi_in[test_pos[:, 0]] * phi_in[test_pos[:, 1]]).sum(-1)
    s_neg = (phi_in[test_neg[:, 0]] * phi_in[test_neg[:, 1]]).sum(-1)
    labels = np.concatenate([np.ones_like(s_pos), np.zeros_like(s_neg)])
    scores = np.concatenate([s_pos, s_neg])
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    n_pos, n_neg = len(s_pos), len(s_neg)
    auc = (ranks[labels == 1].sum() - n_pos * (n_pos + 1) / 2) / (
        n_pos * n_neg)
    print(f"link-prediction AUC = {auc:.4f}   "
          f"(paper Table 4 reports 0.92-0.98 on real graphs)")


if __name__ == "__main__":
    main()
