"""Serve a reduced LM with batched prefill + continuous decode slots.

  PYTHONPATH=src python examples/serve_lm.py --arch yi-6b --requests 6
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.zoo import init_params, reduce_config
from repro.runtime.server import Request, Server, ServerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, ServerConfig(batch_slots=4, max_len=96))

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=6 + i % 4)
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    done = server.serve(reqs)
    dt = time.time() - t0
    total = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.output.tolist()}")


if __name__ == "__main__":
    main()
