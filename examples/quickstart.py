"""Quickstart: embed a graph with DistGER in five lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.api import EmbedConfig, embed_graph
from repro.graph.generators import rmat_graph


def main() -> None:
    graph = rmat_graph(2_000, 10, seed=0)

    # Information-oriented random walks (HuGE termination) + DSGL learner,
    # partitioned across 2 shards with hotness-block synchronization.
    phi_in, phi_out = embed_graph(
        graph,
        EmbedConfig(dim=64, epochs=1, lr=0.05, delta=1e-4,
                    max_len=40, min_len=10),
        num_shards=2,
    )

    print(f"graph: |V|={graph.num_nodes} |E|={graph.num_edges}")
    print(f"embeddings: {phi_in.shape}, norm μ="
          f"{np.linalg.norm(phi_in, axis=1).mean():.3f}")
    # nearest neighbors of node 0 in embedding space
    sims = phi_in @ phi_in[0]
    top = np.argsort(-sims)[1:6]
    print(f"nearest neighbors of node 0: {top.tolist()}")


if __name__ == "__main__":
    main()
