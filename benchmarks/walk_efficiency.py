"""Fig. 10(a) + §3.1 complexity claim: per-superstep cost of the walk engine
must be O(1) in walk length for InCoM and grow for the full-path baseline.

We time the jitted engine at increasing path-buffer lengths; the full-path
mode recomputes H (O(L^2) lane-work) and R over the H-series each step,
InCoM does constant work. Also reports adaptive walk-length stats (the
-63% L / -18% r corpus reduction of §6.5)."""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.core.shard_engine import run_walk_sharded
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, batch_stats, run_walk_batch
from repro.graph.generators import rmat_graph


def _time_mode(graph, mode: str, max_len: int, n_walkers: int = 256,
               reps: int = 3) -> float:
    spec = WalkSpec(max_len=max_len, min_len=8, mu=-1.0, info_mode=mode,
                    fixed_len=max_len, reg_start=16)
    sources = jnp.arange(n_walkers, dtype=jnp.int32) % graph.num_nodes
    policy = make_policy("huge")
    st = run_walk_batch(graph, sources, jax.random.PRNGKey(0), policy, spec)
    jax.block_until_ready(st.path)              # compile + warm
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        st = run_walk_batch(graph, sources, jax.random.PRNGKey(r + 1),
                            policy, spec)
        jax.block_until_ready(st.path)
        best = min(best, time.perf_counter() - t0)
    supersteps = int(st.supersteps)
    return best / max(supersteps, 1)


_SHARD_SPEC = WalkSpec(max_len=80, min_len=8, mu=0.995, info_mode="incom",
                       reg_start=16)


def _time_engine(graph, runner, n_walkers: int = 512, reps: int = 3) -> Dict:
    """Supersteps/s + measured/analytic traffic for one engine execution."""
    sources = jnp.arange(n_walkers, dtype=jnp.int32) % graph.num_nodes
    st = runner(sources, jax.random.PRNGKey(0))
    jax.block_until_ready(st.path)              # compile + warm
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        st = runner(sources, jax.random.PRNGKey(r + 1))
        jax.block_until_ready(st.path)
        best = min(best, time.perf_counter() - t0)
    s = batch_stats(st)
    return {
        "supersteps_per_s": s["supersteps"] / max(best, 1e-9),
        "msg_count": s["msg_count"],
        "msg_bytes_measured": s["msg_bytes"],
        "msg_bytes_analytic": s["msg_bytes_analytic"],
        "bytes_per_msg": s["msg_bytes"] / max(s["msg_count"], 1),
    }


def _time_sharded(graph, part, k: int, n_walkers: int = 512,
                  reps: int = 5, engine: str = "replicated") -> Dict:
    policy = make_policy("huge")
    part_j = jnp.asarray(part, jnp.int32)
    rec = _time_engine(
        graph,
        lambda src, key: run_walk_sharded(graph, src, key, policy,
                                          _SHARD_SPEC, part_j, k,
                                          engine=engine),
        n_walkers, reps)
    rec["engine"] = engine
    if engine == "local":
        # Per-shard balance + partition-local memory columns (paper
        # Eq. 14-15 model: CSR bytes/shard ~ (|V| + |E|)/k).
        sources = jnp.arange(n_walkers, dtype=jnp.int32) % graph.num_nodes
        _, stats = run_walk_sharded(
            graph, sources, jax.random.PRNGKey(1), policy, _SHARD_SPEC,
            part_j, k, engine="local", with_stats=True)
        rec["csr_bytes_per_shard"] = max(stats["csr_bytes_per_shard"])
        rec["peak_lane_occupancy"] = stats["peak_lane_occupancy"]
        rec["pool_slots"] = stats["pool_slots"]
        rec["owned_nodes"] = stats["owned_nodes"]
        # wire volume per shard: measured message bytes averaged over k
        rec["msg_bytes_per_shard"] = rec["msg_bytes_measured"] / k
    return rec


def _time_dense(graph, n_walkers: int = 512, reps: int = 3) -> Dict:
    policy = make_policy("huge")
    return _time_engine(
        graph,
        lambda src, key: run_walk_batch(graph, src, key, policy, _SHARD_SPEC),
        n_walkers, reps)


def _overlap_efficiency(quick: bool = True) -> Dict:
    """Walk→train overlap: streamed pipeline wall vs fully serialized wall
    on the identical workload (same walks, same train schedule)."""
    from repro.core.api import EmbedConfig, make_walk_plan
    from repro.core.dsgl import DSGLConfig
    from repro.core.mpgp import mpgp_partition
    from repro.runtime.trainer import StreamingEmbedPipeline

    n = 1024 if quick else 4096
    g = rmat_graph(n, 10, seed=3).with_edge_cm()
    cfg = EmbedConfig(dim=32, epochs=1, max_len=40, min_len=10, window=6,
                      negatives=4, delta=1e-3)
    policy, spec, rounds = make_walk_plan(cfg)
    rounds["max_rounds"] = 4 if quick else 8
    dcfg = DSGLConfig(dim=32, window=6, negatives=4, seed=0, multi_windows=2)
    part = mpgp_partition(g, 2).assignment
    out = {}
    # First pass of each mode pays all jit compiles; time the second.
    for mode, overlap in (("streamed", True), ("serialized", False)):
        best = float("inf")
        for rep in range(2):
            pipe = StreamingEmbedPipeline(
                g, policy, spec, dict(rounds), dcfg,
                assignment=part, num_shards=2, overlap=overlap)
            res = pipe.run()
            if rep > 0:
                best = min(best, res["wall_s"])
        out[f"wall_{mode}_s"] = best
        out["rounds"] = res["rounds"]
        out["train_steps"] = res["steps"]
    out["overlap_efficiency"] = (
        out["wall_serialized_s"] / max(out["wall_streamed_s"], 1e-9))
    return out


def run(quick: bool = True) -> Dict:
    g = rmat_graph(2048, 10, seed=3).with_edge_cm()
    lens = (32, 64, 128) if quick else (32, 64, 128, 256, 512)
    rec: Dict = {"per_superstep_s": {}}
    for mode in ("incom", "fullpath"):
        rec["per_superstep_s"][mode] = {
            L: _time_mode(g, mode, L) for L in lens
        }
    # O(1) vs O(L): cost growth ratio from the shortest to longest buffer
    inc = rec["per_superstep_s"]["incom"]
    ful = rec["per_superstep_s"]["fullpath"]
    rec["growth_incom"] = inc[lens[-1]] / inc[lens[0]]
    rec["growth_fullpath"] = ful[lens[-1]] / ful[lens[0]]

    # adaptive-length stats (info termination vs routine L=80)
    spec = WalkSpec(max_len=80, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    sources = jnp.arange(512, dtype=jnp.int32) % g.num_nodes
    st = run_walk_batch(g, sources, jax.random.PRNGKey(9),
                        make_policy("huge"), spec)
    lengths = np.asarray(st.info.L)
    rec["adaptive_mean_len"] = float(lengths.mean())
    rec["routine_len"] = 80
    rec["len_reduction_pct"] = 100.0 * (1 - lengths.mean() / 80.0)

    # --- partition-sharded BSP engine: k-scaling, measured traffic ---------
    # "k1_dense" is the engine's k=1 fast path (run_walk_batch, no exchange
    # machinery); "k1_bsp" runs the full BSP loop on one shard, so the
    # difference is the measured cost of message packing + the collective.
    # "k{N}_local" rows run the partition-local compacted engine (CSR
    # slices + lane pools + packed exchange) and carry the per-shard
    # memory/balance columns; "k4" keeps the replicated engine for
    # trajectory continuity with earlier BENCH_walk files.
    from repro.core.mpgp import mpgp_partition
    part4 = mpgp_partition(g, 4, gamma=2.0).assignment
    # Walker-load-aware variant: Eq. 15 capacity on DEGREE mass with a
    # tight gamma, so the partition spreads edge mass (and with it walker
    # occupancy) instead of letting two shards absorb the whole rich club.
    part4_deg = mpgp_partition(g, 4, gamma=1.15,
                               tau_weight="degree").assignment
    n = g.num_nodes
    full_csr_bytes = int(
        (g.indptr.shape[0] + g.indices.shape[0]
         + (g.edge_cm.shape[0] if g.edge_cm is not None else 0)) * 4)
    rec["full_csr_bytes"] = full_csr_bytes
    rec["sharded"] = {
        "k1_dense": _time_dense(g),
        "k1_bsp": _time_sharded(g, np.zeros(n, np.int32), 1),
        "k4": _time_sharded(g, part4, 4),
        "k1_local": _time_sharded(g, np.zeros(n, np.int32), 1,
                                  engine="local"),
        "k2_local": _time_sharded(g, part4 % 2, 2, engine="local"),
        "k4_local": _time_sharded(g, part4, 4, engine="local"),
        "k4_local_degree_tau": _time_sharded(g, part4_deg, 4,
                                             engine="local"),
        "k8_local": _time_sharded(g, np.arange(n) % 8, 8, engine="local"),
        "k16_local": _time_sharded(g, np.arange(n) % 16, 16,
                                   engine="local"),
    }

    # --- walk→train overlap (fused streaming pipeline) ---------------------
    rec["overlap"] = _overlap_efficiency(quick)
    save("walk_efficiency", rec)
    return rec
