"""Fig. 10(a) + §3.1 complexity claim: per-superstep cost of the walk engine
must be O(1) in walk length for InCoM and grow for the full-path baseline.

We time the jitted engine at increasing path-buffer lengths; the full-path
mode recomputes H (O(L^2) lane-work) and R over the H-series each step,
InCoM does constant work. Also reports adaptive walk-length stats (the
-63% L / -18% r corpus reduction of §6.5)."""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.core.transition import make_policy
from repro.core.walker import WalkSpec, run_walk_batch
from repro.graph.generators import rmat_graph


def _time_mode(graph, mode: str, max_len: int, n_walkers: int = 256,
               reps: int = 3) -> float:
    spec = WalkSpec(max_len=max_len, min_len=8, mu=-1.0, info_mode=mode,
                    fixed_len=max_len, reg_start=16)
    sources = jnp.arange(n_walkers, dtype=jnp.int32) % graph.num_nodes
    policy = make_policy("huge")
    st = run_walk_batch(graph, sources, jax.random.PRNGKey(0), policy, spec)
    jax.block_until_ready(st.path)              # compile + warm
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        st = run_walk_batch(graph, sources, jax.random.PRNGKey(r + 1),
                            policy, spec)
        jax.block_until_ready(st.path)
        best = min(best, time.perf_counter() - t0)
    supersteps = int(st.supersteps)
    return best / max(supersteps, 1)


def run(quick: bool = True) -> Dict:
    g = rmat_graph(2048, 10, seed=3).with_edge_cm()
    lens = (32, 64, 128) if quick else (32, 64, 128, 256, 512)
    rec: Dict = {"per_superstep_s": {}}
    for mode in ("incom", "fullpath"):
        rec["per_superstep_s"][mode] = {
            L: _time_mode(g, mode, L) for L in lens
        }
    # O(1) vs O(L): cost growth ratio from the shortest to longest buffer
    inc = rec["per_superstep_s"]["incom"]
    ful = rec["per_superstep_s"]["fullpath"]
    rec["growth_incom"] = inc[lens[-1]] / inc[lens[0]]
    rec["growth_fullpath"] = ful[lens[-1]] / ful[lens[0]]

    # adaptive-length stats (info termination vs routine L=80)
    spec = WalkSpec(max_len=80, min_len=8, mu=0.995, info_mode="incom",
                    reg_start=16)
    sources = jnp.arange(512, dtype=jnp.int32) % g.num_nodes
    st = run_walk_batch(g, sources, jax.random.PRNGKey(9),
                        make_policy("huge"), spec)
    lengths = np.asarray(st.info.L)
    rec["adaptive_mean_len"] = float(lengths.mean())
    rec["routine_len"] = 80
    rec["len_reduction_pct"] = 100.0 * (1 - lengths.mean() / 80.0)
    save("walk_efficiency", rec)
    return rec
