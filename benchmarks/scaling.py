"""Fig. 6/7 analog: scalability — shard count sweep on a fixed graph, and
graph-size sweep (R-MAT, fixed degree 10, the paper's §6.3 synthetic setup)."""

from __future__ import annotations

from typing import Dict

from benchmarks.common import save, timer
from repro.core.api import EmbedConfig, embed_graph
from repro.graph.generators import rmat_graph


def run(quick: bool = True) -> Dict:
    rec: Dict = {"shards": {}, "sizes": {}}
    cfg = EmbedConfig(dim=32, epochs=1, max_len=30, min_len=8)

    g = rmat_graph(2048 if quick else 16384, 10, seed=1)
    for m in (1, 2, 4):
        with timer() as t:
            embed_graph(g, cfg, num_shards=m)
        rec["shards"][m] = t["seconds"]

    for n in ((512, 2048, 8192) if quick else (4096, 16384, 65536, 262144)):
        g = rmat_graph(n, 10, seed=2)
        with timer() as t:
            embed_graph(g, cfg, num_shards=2)
        rec["sizes"][n] = t["seconds"]

    save("scaling", rec)
    return rec
