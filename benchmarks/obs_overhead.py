"""Telemetry overhead benchmark (DESIGN.md §13).

The observability substrate promises two things: zero NUMERICAL footprint
(property-tested in tests/test_obs.py — phi and the ring are bit-identical
with telemetry on vs off) and near-zero WALL footprint (<3% on the hot
loops, budgeted in §13). This benchmark measures the second promise:

* end-to-end — the full streaming walk→train pipeline, best-of-reps wall
  with telemetry fully on vs fully off (same process, same compiled
  kernels, so the delta is pure host-side bookkeeping);
* micro — ns/call of the gated no-op path (`obs.inc` with telemetry
  off), the cost every hot-loop site pays when the switch is thrown.

It also produces the per-run RUN_TELEMETRY.json artifact from the
telemetry-on run — the same export CI uploads — so the schema stays
exercised by a real pipeline, not just unit fixtures.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro import obs


def _build(nodes: int, degree: int, dim: int):
    from repro.core.api import EmbedConfig, make_walk_plan
    from repro.core.dsgl import DSGLConfig
    from repro.graph.generators import rmat_graph

    graph = rmat_graph(nodes, degree, seed=7)
    cfg = dataclasses.replace(EmbedConfig(dim=dim, seed=3),
                              rng_mode="vertex")
    policy, spec, rounds = make_walk_plan(cfg)
    return graph, policy, spec, rounds, DSGLConfig(dim=dim, seed=3)


def _noop_ns_per_call(calls: int = 200_000) -> float:
    """Cost of one gated telemetry call with the switch off — what every
    instrumented hot-loop site pays in production when telemetry is
    disabled."""
    with obs.override(enabled=False):
        t0 = time.perf_counter()
        for _ in range(calls):
            obs.inc("bench.noop")
        dt = time.perf_counter() - t0
    return dt / calls * 1e9


def run(quick: bool = True, telemetry_path: Optional[str] = None) -> dict:
    import jax

    nodes, degree, dim = (256, 7, 16) if quick else (2048, 10, 64)
    reps = 3 if quick else 5
    graph, policy, spec, rounds, dsgl = _build(nodes, degree, dim)

    from repro.runtime.trainer import StreamingEmbedPipeline

    def one_run(enabled: bool) -> float:
        with obs.override(enabled=enabled):
            p = StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl)
            t0 = time.perf_counter()
            p.run()
            return time.perf_counter() - t0

    one_run(True)                                 # compile + warm caches
    best_on = min(one_run(True) for _ in range(reps))
    best_off = min(one_run(False) for _ in range(reps))
    overhead_pct = 100.0 * (best_on - best_off) / best_off

    # The RUN_TELEMETRY artifact: a fresh registry, one telemetry-on run,
    # exported through the same writer CI consumes.
    obs.reset()
    with obs.override(enabled=True):
        p = StreamingEmbedPipeline(graph, policy, spec, rounds, dsgl)
        t0 = time.perf_counter()
        res = p.run()
        wall = time.perf_counter() - t0
        telemetry = obs.run_telemetry(run={
            "bench": "obs_overhead",
            "nodes": int(nodes), "degree": int(degree), "dim": int(dim),
            "wall_s": float(wall),
            "rounds": int(res.get("rounds", 0)),
            "global_step": int(res.get("steps", 0)),
            "jax_backend": jax.default_backend(),
        })
    if telemetry_path:
        obs.write_run_telemetry(telemetry_path, run=telemetry["run"])

    return {
        "nodes": nodes,
        "dim": dim,
        "reps": reps,
        "wall_on_s": best_on,
        "wall_off_s": best_off,
        "overhead_pct": overhead_pct,
        "noop_ns_per_call": _noop_ns_per_call(),
        "spans_recorded": len(obs.recent()),
        "telemetry": telemetry,
    }
