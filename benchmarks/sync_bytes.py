"""§4.2-III: hotness-block vs full synchronization byte volume across
vocabulary sizes (the O(ocn_max d m) vs O(|V| d m) claim), using real
occurrence distributions from sampled corpora."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import save
from repro.core.api import EmbedConfig, sample_corpus
from repro.core.corpus import FrequencyOrder
from repro.core.sync import sync_cost_model
from repro.graph.generators import rmat_graph


def run(quick: bool = True) -> Dict:
    rec: Dict = {"per_graph": {}}
    d, m = 128, 8
    for n in (1024, 4096) if quick else (1024, 4096, 16384, 65536):
        g = rmat_graph(n, 10, seed=6)
        corpus = sample_corpus(g, EmbedConfig(max_len=30, min_len=8))
        order = FrequencyOrder.from_ocn(corpus.ocn)
        starts, _ = order.hotness_blocks()
        hot, full = sync_cost_model(n, d, m, len(starts))
        rec["per_graph"][n] = {
            "blocks": int(len(starts)),
            "hotness_bytes_per_period": hot,
            "full_bytes_per_period": full,
            "reduction_x": full / max(hot, 1),
        }
    save("sync_bytes", rec)
    return rec
