"""Fault-tolerant serving benchmark: throughput, tail latency,
availability under churn + swaps + injected faults (DESIGN.md §14).

The numbers a read path must put on the table:

1. **Throughput / latency** — queries/s and p50/p99 response latency of
   the slot-pool wave scheduler on a steady query stream (mixed pair
   scoring and top-K), against the version the ingest loop keeps
   refreshing.

2. **Availability under chaos** — the full lifecycle under a scripted
   fault schedule: continuous churn through ``IngestDriver`` (each drain
   publishes a new snapshot → atomic swap), a refresh retry storm (the
   server rides it out on the stale version), one torn candidate step
   directory (invisible to the loader — the newest VALID snapshot
   swaps), and one swap-window fault drill (the offer dies; the active
   version keeps serving). Reported: availability (served / admitted —
   the ISSUE 10 floor is >= 99%), the served-version mix, the
   fresh/stale mix, and shed accounting per reason.

3. **Oracle bit-identity** — after the run, EVERY response is re-scored
   by the NumPy oracle of the exact version it was stamped with; one
   mismatched bit fails the benchmark. This is the swap-atomicity proof
   at the fleet level: no response ever mixes two versions.

Repo-root ``BENCH_serve.json`` is emitted by
``benchmarks.run --only serve``.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import save
from repro.graph.generators import churn_batch, rmat_graph
from repro.runtime.faults import FaultInjector
from repro.runtime.ingest import IngestConfig, IngestDriver
from repro.runtime.serve import (EmbedServer, ServeConfig, oracle_scores,
                                 oracle_topk)
from repro.runtime.trainer import StreamingEmbedPipeline


def _plan(dim: int, seed: int = 3):
    import dataclasses

    from repro.core.api import EmbedConfig, make_walk_plan
    from repro.core.dsgl import DSGLConfig

    cfg = dataclasses.replace(
        EmbedConfig(dim=dim, epochs=1, lr=0.05, delta=1e-3, max_len=40,
                    min_len=10, window=6, negatives=4, seed=seed),
        rng_mode="vertex")
    policy, spec, rounds = make_walk_plan(cfg)
    dsgl = DSGLConfig(dim=dim, epochs=1, lr=0.05, window=6, negatives=4,
                      seed=seed)
    return policy, spec, rounds, dsgl


def run(quick: bool = True) -> Dict:
    import os
    import tempfile

    n = 512 if quick else 2048
    dim = 32
    churn_rounds = 4 if quick else 8
    queries_per_round = 64 if quick else 256

    g = rmat_graph(n, 10, seed=3)
    policy, spec, rounds, dsgl = _plan(dim)
    pipe = StreamingEmbedPipeline(g, policy, spec, rounds, dsgl)
    pipe.run()

    rng = np.random.default_rng(11)

    with tempfile.TemporaryDirectory() as root:
        # Chaos schedule: a refresh retry storm on the third drain (two
        # failed attempts, then success inside max_retries), a refresh
        # DEATH on the fourth (all four attempts fail -> drain raises,
        # the server moves to refresh_state="failed" and serves stale
        # until the operator-retry drain succeeds), and one swap-window
        # fault drill on the server's third offer.
        ingest_faults = FaultInjector(
            plan={"refresh": (2, 3, 5, 6, 7, 8)})
        serve_faults = FaultInjector(plan={"swap": (2,)})
        srv = EmbedServer(ServeConfig(batch_slots=32),
                          faults=serve_faults)
        drv = IngestDriver(os.path.join(root, "ing"), pipe,
                           cfg=IngestConfig(apply_every=1, max_retries=3,
                                            backoff_s=0.0),
                           faults=ingest_faults, server=srv)

        # One torn candidate: a step directory with garbage and no
        # manifest, numerically newer than anything committed yet. The
        # loader must never surface it; committed steps keep swapping.
        torn = os.path.join(drv.ckpt_dir, "step_00000099")
        os.makedirs(torn)
        with open(os.path.join(torn, "phi_in.npy"), "wb") as f:
            f.write(b"\x93NUMPY torn candidate")

        # phi of every version actually swapped in, for the post-hoc
        # oracle audit of each response.
        phis = {srv.active_version(): srv.active_phi()}
        qids = []
        topk_qids = set()
        t0 = time.perf_counter()
        refresh_deaths = 0
        for r in range(churn_rounds):
            try:
                # apply_every=1: submit absorbs (drain + publish)
                # inline; publish swallows serve-side drill failures.
                drv.submit(churn_batch(drv.pipeline.graph, frac=0.02,
                                       seed=100 + r))
            except Exception:
                # Refresh death (retries exhausted): the batch stays
                # durable in the WAL, the server serves the last good
                # version STALE, and the operator-retry drain below
                # absorbs it. Queries issued in between are the stale-ok
                # rung of the ladder, stamped as such.
                refresh_deaths += 1
                for _ in range(queries_per_round // 4):
                    qid = srv.submit(int(rng.integers(0, n)), k=8)
                    if qid is not None:
                        topk_qids.add(qid)
                        qids.append(qid)
                srv.drain()
                drv.drain()
            v = srv.active_version()
            if v not in phis:
                phis[v] = srv.active_phi()
            for _ in range(queries_per_round):
                u = int(rng.integers(0, n))
                if rng.random() < 0.5:
                    cand = rng.integers(0, n, size=int(rng.integers(1, 9)))
                    qid = srv.submit(u, candidates=cand)
                else:
                    qid = srv.submit(u, k=8)
                    if qid is not None:
                        topk_qids.add(qid)
                if qid is not None:
                    qids.append(qid)
                if len(qids) % 16 == 0:
                    srv.tick()
            srv.drain()
        wall = time.perf_counter() - t0

        stats = srv.stats()
        # --- oracle audit: every response vs its stamped version --------
        mismatches = 0
        for qid in qids:
            resp = srv.responses[qid]
            phi = phis.get(resp.served_version)
            if phi is None or (resp.ids.size
                               and resp.ids.max() >= phi.shape[0]):
                mismatches += 1      # unknown version / foreign id space
                continue
            want = oracle_scores(phi, resp.u, resp.ids)
            if not np.array_equal(resp.scores, want):
                mismatches += 1
        # Top-K responses additionally must BE the oracle's top-K set.
        topk_checked = topk_mismatches = 0
        for qid in sorted(topk_qids)[: 128]:
            resp = srv.responses[qid]
            phi = phis.get(resp.served_version)
            if phi is None:
                topk_mismatches += 1
                continue
            vals, ids = oracle_topk(phi, resp.u, 8)
            topk_checked += 1
            if not (np.array_equal(resp.ids, ids)
                    and np.array_equal(resp.scores, vals)):
                topk_mismatches += 1

        rec = {
            "num_nodes": n,
            "dim": dim,
            "churn_rounds": churn_rounds,
            "queries_offered": stats["offered_total"],
            "queries_admitted": stats["admitted"],
            "queries_served": stats["served"],
            "availability": stats["availability"],
            "queries_per_s": stats["served"] / max(wall, 1e-9),
            "latency_p50_s": stats["latency_p50_s"],
            "latency_p99_s": stats["latency_p99_s"],
            "swaps": stats["swaps"],
            "shed": stats["shed"],
            "served_by_version": {str(k): v for k, v in
                                  stats["served_by_version"].items()},
            "served_by_freshness": stats["served_by_freshness"],
            "ingest_retries": drv.retries,
            "refresh_deaths": refresh_deaths,
            "swap_faults_fired": len(serve_faults.fired),
            "refresh_faults_fired": len(ingest_faults.fired),
            "oracle_mismatches": mismatches,
            "oracle_topk_mismatches": topk_mismatches,
            "oracle_topk_checked": topk_checked,
            "oracle_bit_identical": bool(mismatches == 0
                                         and topk_mismatches == 0),
            "wall_s": wall,
        }
    save("serve", rec)
    print(f"serve: {rec['queries_per_s']:.0f} q/s p99="
          f"{rec['latency_p99_s'] * 1e3:.2f}ms availability="
          f"{rec['availability']:.4f} swaps={rec['swaps']} "
          f"bit_identical={rec['oracle_bit_identical']}", flush=True)
    return rec
