"""Fig. 9 analog: multi-label-style node classification. Real labeled
graphs (Flickr/Youtube) are not bundled; we plant communities (SBM) and
classify membership from embeddings with one-vs-rest logistic regression
(numpy implementation — no sklearn in the container)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import save
from repro.core.api import EmbedConfig, embed_graph
from repro.graph.csr import build_csr


def sbm_graph(n_per: int, k: int, p_in: float, p_out: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = n_per * k
    labels = np.repeat(np.arange(k), n_per)
    rows, cols = [], []
    for i in range(k):
        for j in range(i, k):
            p = p_in if i == j else p_out
            a = np.arange(i * n_per, (i + 1) * n_per)
            b = np.arange(j * n_per, (j + 1) * n_per)
            mask = rng.random((n_per, n_per)) < p
            if i == j:
                mask = np.triu(mask, 1)
            r, c = np.nonzero(mask)
            rows.append(a[r]); cols.append(b[c])
    src = np.concatenate(rows); dst = np.concatenate(cols)
    edges = np.stack([src, dst], 1)
    return build_csr(edges, n, undirected=True), labels


def _logreg_ovr(x, y, k, epochs=200, lr=0.5):
    """Tiny one-vs-rest logistic regression (full-batch GD)."""
    n, d = x.shape
    w = np.zeros((k, d)); b = np.zeros(k)
    y1 = np.eye(k)[y]
    for _ in range(epochs):
        z = x @ w.T + b
        p = 1 / (1 + np.exp(-z))
        g = (p - y1) / n
        w -= lr * (g.T @ x)
        b -= lr * g.sum(0)
    return w, b


def _f1_scores(y_true, y_pred, k):
    micro_tp = (y_pred == y_true).sum()
    micro = micro_tp / len(y_true)          # accuracy == micro-F1 (single label)
    f1s = []
    for c in range(k):
        tp = ((y_pred == c) & (y_true == c)).sum()
        fp = ((y_pred == c) & (y_true != c)).sum()
        fn = ((y_pred != c) & (y_true == c)).sum()
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(2 * prec * rec / max(prec + rec, 1e-9))
    return float(micro), float(np.mean(f1s))


def run(quick: bool = True) -> Dict:
    g, labels = sbm_graph(128 if quick else 512, 4, 0.08, 0.005, seed=8)
    cfg = EmbedConfig(dim=32, epochs=1, lr=0.05, delta=1e-4,
                      max_len=40, min_len=10)
    phi, _ = embed_graph(g, cfg)
    rng = np.random.default_rng(0)
    rec: Dict = {"ratios": {}}
    n = len(labels)
    for ratio in (0.1, 0.5, 0.9):
        idx = rng.permutation(n)
        n_tr = max(int(ratio * n), 8)
        tr, te = idx[:n_tr], idx[n_tr:]
        w, b = _logreg_ovr(phi[tr], labels[tr], 4)
        pred = np.argmax(phi[te] @ w.T + b, -1)
        micro, macro = _f1_scores(labels[te], pred, 4)
        rec["ratios"][ratio] = {"micro_f1": micro, "macro_f1": macro}
    save("classification", rec)
    return rec
